"""Beyond-paper: the precision-aware technique applied to an assigned LM.

Quantises a reduced gemma-2b per the structural sensitivity policy
(embeddings/norms pinned, projections int8), verifies output agreement vs
full precision, and reports the weight-byte reduction that drives the
roofline memory/collective terms at scale.

    PYTHONPATH=src python examples/precision_sweep_lm.py
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.quantized import default_lm_policy, quantize_lm_params, quantized_fraction


def main():
    cfg = get_config("gemma-2b").smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)}

    base = T.forward(params, batch, cfg)
    policy = default_lm_policy(cfg)
    qparams = quantize_lm_params(params, policy)
    quant = T.forward(qparams, batch, cfg)

    base_p = jax.nn.softmax(base, axis=-1)
    quant_p = jax.nn.softmax(quant, axis=-1)
    tvd = float(0.5 * jnp.abs(base_p - quant_p).sum(-1).mean())
    agree = float(jnp.mean(jnp.argmax(base, -1) == jnp.argmax(quant, -1)))
    frac = quantized_fraction(qparams)
    print(f"quantised int8 weight fraction : {frac*100:.1f}% of parameter elements")
    print(f"top-1 agreement fp32 vs W8     : {agree*100:.1f}%")
    print(f"mean TV distance               : {tvd:.4f}")
    # random-init logits are near-uniform, so argmax agreement is a noisy
    # metric at smoke scale; 0.8 catches real divergence (trained detectors
    # are held to <2.5pp accuracy in benchmarks/bench_table2).
    assert agree > 0.8, "int8 weight-only quantisation diverged"
    print("OK")


if __name__ == "__main__":
    main()
