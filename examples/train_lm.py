"""End-to-end LM training driver example (~100M-param class, CPU-scaled).

Exercises the full production loop — sharded params, grad-accumulation train
step, prefetching loader, checkpoint/restart, preemption hook — on a reduced
OLMoE-style MoE (the paper's quantisation/pruning targets generalised to an
assigned arch).  The loss must fall; the script asserts it.

    PYTHONPATH=src python examples/train_lm.py --steps 60
    # full driver (any assigned arch):
    PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --smoke --steps 200
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="olmoe-1b-7b")
    ap.add_argument("--scale", type=float, default=2.0, help="width multiplier (2.0 ~ 5M params; raise toward 100M off-container)")
    args = ap.parse_args()

    losses = train_main(
        [
            "--arch", args.arch,
            "--smoke",
            "--scale", str(args.scale),
            "--steps", str(args.steps),
            "--batch", "8",
            "--seq", "128",
            "--lr", "2e-3",
            "--warmup", "5",
            "--ckpt-every", "25",
            "--ckpt-dir", "artifacts/ckpt_example",
        ]
    )
    first, last = losses[0], float(np.mean(losses[-10:]))
    assert last < first * 0.8, f"loss did not fall: {first:.3f} -> {last:.3f}"
    print(f"OK: loss fell {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    sys.exit(main())
