"""Quickstart: the paper's full pipeline in one script.

Synthesises UAV/background audio, extracts MFCC features, trains the
1D-F-CNN, scores layer sensitivity (eq. 2), runs all four precision modes,
applies the serialisation-aware structured prune (Table I), and reports the
cycle-model latency (eqs. 9-10).

    PYTHONPATH=src python examples/quickstart.py [--n 600] [--epochs 6]
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core import timing_model as TM
from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.data import acoustic, features
from repro.models import cnn1d
from repro.training import loop
from repro.training.detector_artifact import sensitivity_policy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1000)
    ap.add_argument("--epochs", type=int, default=10)
    args = ap.parse_args()

    print("== 1. synthetic acoustic corpus ==")
    ds = acoustic.make_dataset(args.n, seed=0, snr_range=(-12, 18), p_clean=0.08)
    print(f"   {args.n} windows of {features.WINDOW_S}s @ {features.SR}Hz, {ds.labels.mean()*100:.0f}% UAV")

    print("== 2. MFCC-20 feature vectors (1x1096) ==")
    feats = features.batch_features(ds.audio, "mfcc20")

    print("== 3. train 1D-F-CNN (Adam + early stopping) ==")
    n_tr = int(args.n * 0.7)
    n_va = int(args.n * 0.15)
    res = loop.train_detector(
        feats[:n_tr], ds.labels[:n_tr],
        feats[n_tr : n_tr + n_va], ds.labels[n_tr : n_tr + n_va],
        cnn1d.CANONICAL, epochs=args.epochs, batch=64, verbose=True,
    )
    test_x, test_y = feats[n_tr + n_va :], ds.labels[n_tr + n_va :]

    print("== 4. precision sweep (the multi-precision datapath) ==")
    for prec in Precision:
        m = loop.evaluate_logits(
            loop.predict(res.params, test_x, res.cfg, policy=PrecisionPolicy.uniform(prec)), test_y
        )
        print(f"   {prec.value:5s}: acc={m.accuracy*100:.2f}%  f1={m.f1*100:.2f}%")

    print("== 5. sensitivity-driven mixed precision (eqs. 2-3) ==")
    det = {"params": res.params, "cfg": res.cfg, "feats": feats, "labels": ds.labels}
    pol = sensitivity_policy(det)
    m = loop.evaluate_logits(loop.predict(res.params, test_x, res.cfg, policy=pol), test_y)
    print(f"   mixed: acc={m.accuracy*100:.2f}%  rules={pol.to_json()}")

    print("== 6. structured pruning (Table I) ==")
    pruned, pcfg, spec = cnn1d.prune_model(res.params, res.cfg)
    mp = loop.evaluate_logits(
        np.asarray(cnn1d.forward_pruned(pruned, jax.numpy.asarray(test_x), pcfg, spec)), test_y
    )
    print(f"   flatten {spec.flatten_before} -> {spec.flatten_after} ({spec.reduction*100:.1f}%), acc={mp.accuracy*100:.2f}%")

    print("== 7. cycle-accurate latency (eqs. 9-10) ==")
    for pruned_flag in (False, True):
        lat = TM.shield8_latency(pruned=pruned_flag)
        print(f"   {'pruned' if pruned_flag else 'unpruned'}: {lat['seconds']*1e3:.1f} ms @100MHz (paper deployed: 116 ms)")


if __name__ == "__main__":
    main()
