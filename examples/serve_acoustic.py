"""Continuous UAV monitoring with temporal tracking (the title's use case).

Streams a synthetic 60 s acoustic scene (UAV pass + bird/aircraft clutter)
through the trained detector window-by-window; the TemporalTracker smooths
scores and emits onset/offset events.

    PYTHONPATH=src python examples/serve_acoustic.py
"""
from __future__ import annotations

import numpy as np

from repro.data import acoustic, features
from repro.models import cnn1d
from repro.serving.tracker import TemporalTracker
from repro.training import loop
from repro.training.detector_artifact import get_detector


def synth_scene(seconds: float = 60.0, seed: int = 3):
    """A scene: background everywhere, a UAV pass in [20s, 38s)."""
    rng = np.random.default_rng(seed)
    n_win = int(seconds / features.WINDOW_S)
    windows, truth = [], []
    for i in range(n_win):
        t = i * features.WINDOW_S
        uav = 20.0 <= t < 38.0
        x = acoustic.synth_uav(rng) if uav else acoustic.synth_background(rng)
        x = acoustic.add_noise_snr(x, rng.uniform(0, 15), rng)
        windows.append(x)
        truth.append(uav)
    return np.stack(windows), np.asarray(truth)


def main():
    det = get_detector("mfcc20")
    windows, truth = synth_scene()
    feats = features.batch_features(windows, "mfcc20")
    logits = loop.predict(det["params"], feats, det["cfg"])
    probs = np.exp(logits[:, 1]) / np.exp(logits).sum(axis=1)

    tracker = TemporalTracker(ema_alpha=0.4, enter_threshold=0.65, exit_threshold=0.35)
    print("t(s)  p_uav  ema    state")
    for i, p in enumerate(probs):
        st = tracker.update(float(p))
        flag = "TRACK" if st["active"] else ""
        if i % 5 == 0 or st["active"]:
            print(f"{i*0.8:5.1f}  {p:.2f}  {st['smoothed']:.2f}  {flag}")
    events = tracker.finalize()
    print(f"\n{len(events)} event(s); ground truth: one UAV pass at 20.0-38.0s")
    for e in events:
        print(
            f"  onset={e.onset_idx*0.8:.1f}s offset={e.offset_idx*0.8:.1f}s "
            f"peak={e.peak_score:.2f} mean={e.mean_score:.2f}"
        )


if __name__ == "__main__":
    main()
