"""Simulated-device bootstrap (single home for the XLA_FLAGS dance).

Sharded-batch dispatch on CPU needs N simulated XLA devices, and
``--xla_force_host_platform_device_count`` only takes effect if it is in
``XLA_FLAGS`` *before* the first jax import.  This module is deliberately
jax-import-free so drivers and benches can call it at module-load time;
everything that needs the override (``launch/monitor``,
``benchmarks/bench_serving``) routes through here instead of hand-rolling
the env append.
"""
from __future__ import annotations

import os
import sys


def force_host_device_count(n: int) -> bool:
    """Request ``n`` simulated host devices; returns whether the flag landed.

    No-ops (returns False) when jax is already imported — too late for the
    flag to matter — or when a device-count override is already present
    (e.g. an outer harness set its own; never fight it).
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if n <= 1 or "jax" in sys.modules or "xla_force_host_platform_device_count" in flags:
        return False
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip()
    )
    return True


def shards_from_argv(argv: list[str] | None = None) -> int | None:
    """Extract a ``--shards`` value from raw argv before argparse exists.

    Understands both ``--shards N`` and ``--shards=N``; returns None when
    absent or malformed (argparse will produce the real error later).
    """
    args = sys.argv[1:] if argv is None else list(argv)
    for i, a in enumerate(args):
        try:
            if a == "--shards" and i + 1 < len(args):
                return int(args[i + 1])
            if a.startswith("--shards="):
                return int(a.split("=", 1)[1])
        except ValueError:
            return None
    return None
