"""RWKV6 "Finch" block (arXiv:2404.05892): attention-free time-mix with
data-dependent per-channel decay + channel-mix FFN.

Faithfulness notes (DESIGN.md §Arch-applicability):
* data-dependent decay w_t = exp(-exp(w0 + lora_w(x'_t))) — the headline
  RWKV6 feature — is implemented exactly; its parameters are numerically
  sensitive (double exponential) and the sensitivity framework pins them
  fp32.
* token-shift interpolation uses the learned static mix (mu) per projection;
  RWKV6's *dynamic* (LoRA) token-shift mixing is implemented for the decay
  path where it matters and static elsewhere (documented simplification).
* The WKV recurrence runs as a time-step ``lax.scan``; state is
  (B, H, N, N) with N = head_dim = 64.  Decode carries that state — O(1) in
  context length, which is why rwkv6-7b *runs* the long_500k cell.

Training-time FLOPs of the recurrence are invisible to XLA's cost model
(while-loop body counted once); the roofline module adds the analytic
correction (see benchmarks/roofline.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models.layers import PSpec, qeinsum, rmsnorm, rmsnorm_specs


def rwkv6_specs(cfg: ArchConfig) -> dict:
    d, f, r = cfg.d_model, cfg.d_ff, cfg.rwkv_lora_rank
    mix = lambda: PSpec((d,), ("embed",), init="zeros", dtype="float32")
    return {
        "tm_norm": rmsnorm_specs(d),
        "mu_r": mix(), "mu_k": mix(), "mu_v": mix(), "mu_g": mix(), "mu_w": mix(),
        "w0": PSpec((d,), ("embed",), init="zeros", dtype="float32"),
        "w_lora_a": PSpec((d, r), ("embed", None), dtype="float32"),
        "w_lora_b": PSpec((r, d), (None, "embed"), dtype="float32", init="zeros"),
        "wr": PSpec((d, d), ("embed", "heads")),
        "wk": PSpec((d, d), ("embed", "heads")),
        "wv": PSpec((d, d), ("embed", "heads")),
        "wg": PSpec((d, d), ("embed", "heads")),
        "wo": PSpec((d, d), ("heads", "embed")),
        "u": PSpec((d,), ("embed",), init="zeros", dtype="float32"),  # bonus
        "ln_x": rmsnorm_specs(d),
        "cm_norm": rmsnorm_specs(d),
        "cm_mu_k": mix(), "cm_mu_r": mix(),
        "cm_k": PSpec((d, f), ("embed", "mlp")),
        "cm_v": PSpec((f, d), ("mlp", "embed")),
        "cm_r": PSpec((d, d), ("embed", "heads")),
    }


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros / carried state at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _wkv_scan(r, k, v, w, u, state0):
    """WKV recurrence.  r,k,v,w: (B, T, H, N); state: (B, H, N, N).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)        (current-token bonus u)
    """

    def step(S, xs):
        rt, kt, vt, wt = xs  # (B, H, N)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (r, k, v, w))  # (T, B, H, N)
    S, outs = jax.lax.scan(step, state0, xs)
    return S, outs.transpose(1, 0, 2, 3)  # (B, T, H, N)


def rwkv6_fwd(p, x: jax.Array, cfg: ArchConfig, state: dict | None = None, emit_state: bool = False):
    """Full-sequence RWKV6 block.  state (decode/prefill carry):
    {"tm_shift": (B,1,D), "wkv": (B,H,N,N), "cm_shift": (B,1,D)}."""
    b, t, d = x.shape
    n = cfg.rwkv_head_dim
    hh = d // n
    st = state or {}

    # ---- time mix ----
    h = rmsnorm(p["tm_norm"], x, cfg.norm_eps)
    hs = _shift(h, st.get("tm_shift"))
    r = qeinsum("btd,de->bte", _mix(h, hs, p["mu_r"]), p["wr"])
    k = qeinsum("btd,de->bte", _mix(h, hs, p["mu_k"]), p["wk"])
    v = qeinsum("btd,de->bte", _mix(h, hs, p["mu_v"]), p["wv"])
    g = jax.nn.silu(qeinsum("btd,de->bte", _mix(h, hs, p["mu_g"]), p["wg"]))
    # data-dependent decay (Finch): w = exp(-exp(w0 + lora(mix_w)))
    xw = _mix(h, hs, p["mu_w"]).astype(jnp.float32)
    dlog = p["w0"] + jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    w = jnp.exp(-jnp.exp(jnp.clip(dlog, -8.0, 4.0)))  # (B, T, D) in (0,1)

    shape4 = (b, t, hh, n)
    rr, kk, vv, ww = (z.astype(jnp.float32).reshape(shape4) for z in (r, k, v, w))
    hax = ("batch", "seq", "heads", "head_dim")
    rr, kk, vv, ww = (constrain(z, hax) for z in (rr, kk, vv, ww))
    u = p["u"].reshape(hh, n)
    s0 = st.get("wkv")
    if s0 is None:
        s0 = jnp.zeros((b, hh, n, n), jnp.float32)
    s0 = constrain(s0, ("batch", "heads", "head_dim", None))
    S, wkv = _wkv_scan(rr, kk, vv, ww, u, s0)
    wkv = constrain(wkv, hax)
    # RWKV6 normalises the wkv output with *GroupNorm over heads* — per-head
    # statistics need no cross-head reduction, so the normalisation stays
    # head-sharded under TP (no per-layer full-d all-gather).
    var = jnp.mean(jnp.square(wkv), axis=-1, keepdims=True)
    wkv = wkv * jax.lax.rsqrt(var + cfg.norm_eps)
    out = (wkv.reshape(b, t, d) * p["ln_x"]["scale"]).astype(x.dtype) * g
    x = x + qeinsum("btd,de->bte", out, p["wo"])
    # pin the residual stream back to the replicated-embed domain: without
    # this the sharded branch output leaks into the residual and every
    # downstream full-d op re-gathers the whole activation (measured: 6
    # full-activation all-gathers per layer in the baseline dry-run).
    x = constrain(x, ("batch", "seq", "embed"))

    # ---- channel mix ----
    c = rmsnorm(p["cm_norm"], x, cfg.norm_eps)
    cs = _shift(c, st.get("cm_shift"))
    ck = jnp.square(jax.nn.relu(qeinsum("btd,df->btf", _mix(c, cs, p["cm_mu_k"]), p["cm_k"])))
    ck = constrain(ck, ("batch", "seq", "mlp"))
    cv = qeinsum("btf,fd->btd", ck, p["cm_v"])
    cr = jax.nn.sigmoid(qeinsum("btd,de->bte", _mix(c, cs, p["cm_mu_r"]), p["cm_r"]))
    x = x + cr * cv
    x = constrain(x, ("batch", "seq", "embed"))

    if emit_state:
        new_state = {"tm_shift": h[:, -1:], "wkv": S, "cm_shift": c[:, -1:]}
        return x, new_state
    return x, None


def rwkv6_decode(p, x: jax.Array, state: dict, cfg: ArchConfig):
    """Single-token step: same math with T=1 (the scan degenerates)."""
    return rwkv6_fwd(p, x, cfg, state=state, emit_state=True)


def rwkv6_state_shapes(cfg: ArchConfig, batch: int) -> dict:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    return {
        "tm_shift": jax.ShapeDtypeStruct((batch, 1, d), jnp.dtype(cfg.act_dtype)),
        "wkv": jax.ShapeDtypeStruct((batch, d // n, n, n), jnp.float32),
        "cm_shift": jax.ShapeDtypeStruct((batch, 1, d), jnp.dtype(cfg.act_dtype)),
    }
