"""LM-scale precision-aware quantisation — the paper's technique as a
first-class framework feature.

``quantize_lm_params`` walks a transformer parameter tree and converts
selected weight matrices to ``QTensor`` (int8 payload + per-channel scale)
per a ``PrecisionPolicy``; ``qeinsum`` (models/layers.py) dispatches on the
leaf type, so the same model code runs full-precision or mixed-precision.

Policy defaults follow the sensitivity framework's structural priors, which
eq. (2) scoring reproduces empirically (see tests):
  * embeddings / unembedding, norms, routers, SSM decay + dt params,
    RWKV decay LoRA — pinned high precision;
  * attention projections and FFN/expert matrices — int8.

On TPU this is weight-only quantisation (W8): HBM traffic for weights drops
2x vs bf16 (the roofline memory term), and weight all-gathers shrink the
collective term.  Activation (A8) quantisation uses PACT as in the paper's
8-bit modes; the Pallas quant_matmul kernel is the W8A8 execution path.
"""
from __future__ import annotations

import re
from typing import Mapping

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.core.quantization import QTensor, int8_symmetric, int8_symmetric_keep

#: parameter-name glob patterns that must stay high-precision (structural pins)
SENSITIVE_PATTERNS = (
    "*embed*", "*lm_head*", "*norm*", "*scale*", "*router*",
    "*a_log*", "*dt_bias*", "*d_skip*", "*mamba/w_in*",  # mamba2 decay/dt/dynamics

    "*w0*", "*w_lora*", "*mu_*", "*/u",  # rwkv6 decay/mix
    "*conv_w*", "*conv_b*", "*alpha*", "*frontend*",
)


def default_lm_policy(cfg: ArchConfig, low: Precision = Precision.INT8) -> PrecisionPolicy:
    rules = {pat: Precision.BF16 for pat in SENSITIVE_PATTERNS}
    return PrecisionPolicy(rules=rules, default=low)


def quantize_lm_params(params, policy: PrecisionPolicy | None = None, cfg: ArchConfig | None = None):
    """Returns a parameter tree where int8-eligible weights are QTensor."""
    if policy is None:
        policy = default_lm_policy(cfg) if cfg is not None else PrecisionPolicy()

    def walk(tree, path):
        if isinstance(tree, Mapping):
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in tree.items()}
        if isinstance(tree, QTensor) or tree.ndim < 2:
            return tree
        prec = policy.precision_for(path)
        if prec == Precision.INT8 or prec == Precision.FXP8:
            if tree.ndim >= 3:
                if tree.ndim == 4 and path.rsplit("/", 1)[-1] in ("wq", "wk", "wv"):
                    # stacked multi-head projections (layer, embed, heads,
                    # head_dim): an output channel is a (head, head_dim)
                    # pair, so only the embed contraction axis is reduced —
                    # one scale per layer per head per lane.  Reducing over
                    # heads too (the old keep_axes=(0, -1)) shared one scale
                    # across all heads and cost olmoe ~8pp of argmax
                    # agreement.  The 4-D guard keeps rwkv6's headless
                    # (layer, d, d) wk/wv on the generic stacked rule.
                    return int8_symmetric_keep(tree, keep_axes=(0, 2, 3))
                # stacked (scan) weights: keep the layer axis AND the
                # output-channel axis so lax.scan can slice per layer
                return int8_symmetric_keep(tree, keep_axes=(0, tree.ndim - 1))
            return int8_symmetric(tree, axis=tree.ndim - 1)
        return tree

    return walk(params, "")


def quantized_fraction(qparams) -> float:
    """Fraction of parameter *bytes* now stored as int8."""
    total = 0
    q = 0
    for leaf in jax.tree_util.tree_leaves(
        qparams, is_leaf=lambda t: isinstance(t, QTensor)
    ):
        if isinstance(leaf, QTensor):
            n = int(np.prod(leaf.q.shape))
            q += n
            total += n
        else:
            total += int(np.prod(leaf.shape)) * jax.dtypes.canonicalize_dtype(leaf.dtype).itemsize
    return q / max(total, 1)


def abstract_quantized(aparams, logical, policy: PrecisionPolicy):
    """ShapeDtypeStruct + logical-axes trees for the quantised model (used by
    the dry-run's quantised perf variant)."""
    import jax.numpy as jnp

    def walk(tree, ltree, path):
        if isinstance(tree, Mapping):
            out_a, out_l = {}, {}
            for k in tree:
                out_a[k], out_l[k] = walk(tree[k], ltree[k], f"{path}/{k}")
            return out_a, out_l
        if len(tree.shape) >= 2 and policy.precision_for(path) in (
            Precision.INT8,
            Precision.FXP8,
        ):
            scale_shape = tuple(
                1 if i != len(tree.shape) - 1 else tree.shape[-1]
                for i in range(len(tree.shape))
            )
            qt = QTensor(
                q=jax.ShapeDtypeStruct(tree.shape, jnp.int8),
                scale=jax.ShapeDtypeStruct(scale_shape, jnp.float32),
                axis=len(tree.shape) - 1,
            )
            lt = QTensor(q=ltree, scale=tuple(None for _ in scale_shape), axis=len(tree.shape) - 1)
            return qt, lt
        return tree, ltree

    return walk(aparams, logical, "")
