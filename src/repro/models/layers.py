"""Shared LM building blocks: param specs, norms, RoPE, attention, MLPs.

Conventions
-----------
* Params are nested dicts of arrays.  Every layer declares its parameters as
  ``PSpec`` (shape + logical sharding axes + init), from which real init,
  abstract init (dry-run), and sharding trees all derive.
* ``qeinsum`` is the precision-aware matmul: weights may be ``QTensor``
  (int8 + scale) per the precision policy — the LM-scale face of the paper's
  multi-precision datapath.  int8 weights halve/quarter HBM traffic; the
  dequant is a fused convert on the MXU path.
* Attention supports: GQA, RoPE, causal + sliding-window masks, dense or
  KV-chunked (online-softmax) computation, prefill cache emission, single-
  token decode against linear or ring (windowed) caches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.quantization import QTensor
from repro.distributed.sharding import constrain, kv_seq_axis


class PSpec(NamedTuple):
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones
    dtype: Optional[str] = None  # override cfg.param_dtype


def init_from_specs(rng: jax.Array, specs: Any, cfg: ArchConfig):
    """Materialise a PSpec tree into real parameters."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=lambda x: isinstance(x, PSpec))
    keys = jax.random.split(rng, len(leaves))
    vals = []
    for key, s in zip(keys, leaves):
        dt = jnp.dtype(s.dtype or cfg.param_dtype)
        if s.init == "zeros":
            vals.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            vals.append(jnp.ones(s.shape, dt))
        else:
            fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            vals.append((jax.random.normal(key, s.shape, jnp.float32) / np.sqrt(fan_in)).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_from_specs(specs: Any, cfg: ArchConfig):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or cfg.param_dtype)),
        specs,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


def logical_from_specs(specs: Any):
    return jax.tree_util.tree_map(
        lambda s: s.logical, specs, is_leaf=lambda x: isinstance(x, PSpec)
    )


def stack_specs(specs: Any, n: int, axis_name: str = "layers"):
    """Prepend a stacked 'layers' axis to every PSpec (scan-over-layers)."""
    return jax.tree_util.tree_map(
        lambda s: PSpec((n,) + s.shape, (axis_name,) + s.logical, s.init, s.dtype),
        specs,
        is_leaf=lambda x: isinstance(x, PSpec),
    )


# ---------------------------------------------------------------------------
# precision-aware matmul
# ---------------------------------------------------------------------------


def qeinsum(spec: str, x: jax.Array, w, **kw) -> jax.Array:
    """einsum that accepts QTensor weights (weight-only int8 execution)."""
    if isinstance(w, QTensor):
        w = (w.q.astype(x.dtype) * w.scale.astype(x.dtype))
    return jnp.einsum(spec, x, w, **kw)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_specs(d: int) -> dict:
    return {"scale": PSpec((d,), ("embed",), init="ones", dtype="float32")}


def rmsnorm(p, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half : 2 * half]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if 2 * half != dh:  # odd head_dim tail passes through
        rot = jnp.concatenate([rot, x[..., 2 * half :]], axis=-1)
    return rot


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

ATTN_CHUNK = 1024  # KV-chunked (online softmax) path beyond this seq length


def attn_specs(cfg: ArchConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "norm": rmsnorm_specs(d),
        "wq": PSpec((d, h, dh), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, kv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((h, dh, d), ("heads", "head_dim", "embed")),
    }


@dataclasses.dataclass(frozen=True)
class AttnCacheSpec:
    length: int  # buffer length (== window for ring caches)
    ring: bool


def attn_cache_shape(cfg: ArchConfig, batch: int, max_seq: int, window: Optional[int]):
    """Cache buffer spec: windowed layers get ring buffers of window length —
    for gemma3's long_500k decode this is the difference between a 1k and a
    512k KV buffer on 5/6 of the layers."""
    if window is not None and window < max_seq:
        return AttnCacheSpec(length=window, ring=True)
    return AttnCacheSpec(length=max_seq, ring=False)


def _qkv(p, x, cfg: ArchConfig, positions):
    q = qeinsum("bsd,dhk->bshk", x, p["wq"])
    k = qeinsum("bsd,dhk->bshk", x, p["wk"])
    v = qeinsum("bsd,dhk->bshk", x, p["wv"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def _dense_attention(q, k, v, cfg: ArchConfig, window, causal: bool):
    """Materialised-scores path for short sequences."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores *= 1.0 / np.sqrt(dh)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= j <= i
    if window is not None:
        mask &= j > i - window
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, dh)


def _chunked_attention(q, k, v, cfg: ArchConfig, window, causal: bool):
    """KV-chunked online-softmax attention: memory O(S * chunk), not O(S^2)."""
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    c = ATTN_CHUNK
    n_chunks = (s + c - 1) // c
    pad = n_chunks * c - s
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = kp.reshape(b, n_chunks, c, kvh, dh).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(b, n_chunks, c, kvh, dh).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(b, s, kvh, g, dh)
    scale = 1.0 / np.sqrt(dh)
    i_pos = jnp.arange(s)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, idx = xs
        j_pos = idx * c + jnp.arange(c)
        sc = jnp.einsum("bskgd,btkd->bkgst", qg, kc).astype(jnp.float32) * scale
        mask = j_pos[None, :] < s + 0 * i_pos[:, None]  # drop padded kv
        if causal:
            mask &= j_pos[None, :] <= i_pos[:, None]
        if window is not None:
            mask &= j_pos[None, :] > i_pos[:, None] - window
        sc = jnp.where(mask, sc, -1e30)
        m_new = jnp.maximum(m, sc.max(axis=-1, keepdims=True))
        p = jnp.exp(sc - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1, keepdims=True)
        acc_new = acc * corr.astype(acc.dtype) + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(vc.dtype), vc
        ).astype(acc.dtype)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s, 1), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, dh), jnp.float32)
    if cfg.unroll_attn:
        carry = (m0, l0, a0)  # unrolled for exact HLO cost accounting (dry-run)
        for idx in range(n_chunks):
            carry, _ = body(carry, (kp[idx], vp[idx], jnp.asarray(idx)))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kp, vp, jnp.arange(n_chunks)))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dh)


def attn_fwd(
    p,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    window: Optional[int] = None,
    positions: Optional[jax.Array] = None,
    emit_cache: Optional[AttnCacheSpec] = None,
):
    """Full-sequence attention block (pre-norm, residual).  Returns
    (y, cache | None) where cache = {k, v} trimmed/rolled per the spec."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, positions)
    if s <= ATTN_CHUNK:
        out = _dense_attention(q, k, v, cfg, window, cfg.causal)
    else:
        out = _chunked_attention(q, k, v, cfg, window, cfg.causal)
    y = qeinsum("bshk,hkd->bsd", out, p["wo"])
    y = constrain(y, ("batch", "seq", "embed"))
    cache = None
    if emit_cache is not None:
        L = emit_cache.length
        if emit_cache.ring:
            # last L positions, laid out so slot = pos % L
            shift = (s % L) if s >= L else 0
            cache = {
                "k": jnp.roll(k[:, -L:], shift, axis=1) if s >= L else _pad_to(k, L),
                "v": jnp.roll(v[:, -L:], shift, axis=1) if s >= L else _pad_to(v, L),
            }
        else:
            cache = {"k": _pad_to(k, L), "v": _pad_to(v, L)}
        ksa = kv_seq_axis(k.shape[2])
        cache = {
            n: constrain(t, ("batch", ksa, "kv_heads", "head_dim"))
            for n, t in cache.items()
        }
    return x + y, cache


def _pad_to(t: jax.Array, L: int) -> jax.Array:
    s = t.shape[1]
    if s == L:
        return t
    if s > L:
        return t[:, :L]
    return jnp.pad(t, ((0, 0), (0, L - s), (0, 0), (0, 0)))


def attn_decode(
    p,
    x: jax.Array,  # (B, 1, D)
    cache: dict,  # {"k": (B, L, Hkv, Dh), "v": ...}
    pos: jax.Array,  # scalar int32: absolute position of the new token
    cfg: ArchConfig,
    *,
    window: Optional[int] = None,
    spec: AttnCacheSpec,
):
    """Single-token decode with linear or ring cache. Returns (y, new_cache)."""
    b = x.shape[0]
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    positions = jnp.full((1, 1), pos, jnp.int32)
    q, k, v = _qkv(p, h, cfg, positions)  # (B, 1, H/Hkv, Dh)
    L = spec.length
    slot = (pos % L) if spec.ring else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    ksa = kv_seq_axis(ck.shape[2])
    ck = constrain(ck, ("decode_batch", ksa, "kv_heads", "head_dim"))
    cv = constrain(cv, ("decode_batch", ksa, "kv_heads", "head_dim"))
    hq, kvh, dh = q.shape[2], ck.shape[2], q.shape[3]
    g = hq // kvh
    qg = q.reshape(b, kvh, g, dh)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, ck).astype(jnp.float32) / np.sqrt(dh)
    t = jnp.arange(L)
    if spec.ring:
        # absolute position stored in slot s: largest value <= pos congruent s mod L
        abs_pos = pos - ((pos - t) % L)
        valid = abs_pos >= 0
        if window is not None:
            valid &= abs_pos > pos - window
    else:
        valid = t <= pos
        if window is not None:
            valid &= t > pos - window
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(cv.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", w, cv).reshape(b, 1, hq, dh)
    y = qeinsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return x + y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    base = {"norm": rmsnorm_specs(d)}
    if cfg.mlp_kind in ("swiglu", "geglu"):
        base.update(
            wi_gate=PSpec((d, f), ("embed", "mlp")),
            wi_up=PSpec((d, f), ("embed", "mlp")),
            wo=PSpec((f, d), ("mlp", "embed")),
        )
    else:  # gelu
        base.update(
            wi=PSpec((d, f), ("embed", "mlp")),
            wo=PSpec((f, d), ("mlp", "embed")),
        )
    return base


def mlp_fwd(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    if cfg.mlp_kind in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
        g = act(qeinsum("bsd,df->bsf", h, p["wi_gate"]))
        u = qeinsum("bsd,df->bsf", h, p["wi_up"])
        ff = constrain(g * u, ("batch", "seq", "mlp"))
        y = qeinsum("bsf,fd->bsd", ff, p["wo"])
    else:
        ff = jax.nn.gelu(qeinsum("bsd,df->bsf", h, p["wi"]))
        ff = constrain(ff, ("batch", "seq", "mlp"))
        y = qeinsum("bsf,fd->bsd", ff, p["wo"])
    return x + constrain(y, ("batch", "seq", "embed"))
