"""Mamba2 SSM block (SSD parameterisation) for the zamba2-7b hybrid.

Structure per layer (d_inner = expand * d_model, heads = d_inner/P, P = head
dim, N = ssm_state):
    in_proj: x -> [z, xc, B, C, dt]
    causal conv1d (k=4) over xc, silu
    selective scan with scalar-per-head decay a_t = exp(-softplus(dt) e^{A})
    y = C^T S + D x, gated by silu(z), out_proj back to d_model.

The time recurrence is a ``lax.scan`` (state (B, H, N, P)); decode carries
(conv_state, ssm_state) — O(1) in context, so zamba2 runs the long_500k
cell.  As with RWKV6, scan-body FLOPs get an analytic correction in the
roofline module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models.layers import PSpec, qeinsum, rmsnorm, rmsnorm_specs


def _dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def mamba2_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in, nh, p_, n = _dims(cfg)
    k = cfg.conv_kernel
    return {
        "norm": rmsnorm_specs(d),
        "w_in": PSpec((d, 2 * d_in + 2 * n + nh), ("embed", "ssm_heads")),
        "conv_w": PSpec((k, d_in), ("conv_kernel", "ssm_heads"), dtype="float32"),
        "conv_b": PSpec((d_in,), ("ssm_heads",), init="zeros", dtype="float32"),
        "a_log": PSpec((nh,), ("ssm_heads",), init="zeros", dtype="float32"),
        "d_skip": PSpec((nh,), ("ssm_heads",), init="ones", dtype="float32"),
        "dt_bias": PSpec((nh,), ("ssm_heads",), init="zeros", dtype="float32"),
        "out_norm": rmsnorm_specs(d_in),
        "w_out": PSpec((d_in, d), ("ssm_heads", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, state: jax.Array | None):
    """Depthwise causal conv over time.  x: (B, T, C), w: (K, C).
    state: (B, K-1, C) trailing context from the previous segment."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1) :, :]
    return out + b[None, None, :], new_state


def _ssm_scan(xbcdt, cfg: ArchConfig, state0):
    """Selective scan.  Inputs per step: x (B,H,P), B/C (B,N), dt (B,H).
    S_t = a_t S_{t-1} + dt_t * (B_t ⊗ x_t);  y_t = C_t^T S_t + D x_t."""
    x, bmat, cmat, dt, a, d_skip = xbcdt

    def step(S, xs):
        xt, bt, ct, at, dtt = xs  # (B,H,P) (B,N) (B,N) (B,H) (B,H)
        dBx = jnp.einsum("bn,bhp->bhnp", bt, xt) * dtt[..., None, None]
        S = at[..., None, None] * S + dBx
        y = jnp.einsum("bn,bhnp->bhp", ct, S)
        return S, y

    xs = (
        x.transpose(1, 0, 2, 3),
        bmat.transpose(1, 0, 2),
        cmat.transpose(1, 0, 2),
        a.transpose(1, 0, 2),
        dt.transpose(1, 0, 2),
    )
    S, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3) + d_skip[None, None, :, None] * x
    return S, y


def mamba2_fwd(p, x: jax.Array, cfg: ArchConfig, state: dict | None = None, emit_state: bool = False):
    """state: {"conv": (B, K-1, d_in), "ssm": (B, H, N, P)}."""
    b, t, d = x.shape
    d_in, nh, pdim, n = _dims(cfg)
    st = state or {}
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    proj = qeinsum("btd,de->bte", h, p["w_in"])
    z, xc, bmat, cmat, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    xc, conv_state = _causal_conv(
        xc.astype(jnp.float32), p["conv_w"], p["conv_b"], st.get("conv")
    )
    xc = jax.nn.silu(xc)
    xc = constrain(xc, ("batch", "seq", "ssm_heads"))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,H)
    a = jnp.exp(-dt * jnp.exp(p["a_log"]))  # (B,T,H) in (0,1)
    xh = xc.reshape(b, t, nh, pdim)
    s0 = st.get("ssm")
    if s0 is None:
        s0 = jnp.zeros((b, nh, n, pdim), jnp.float32)
    S, y = _ssm_scan(
        (xh, bmat.astype(jnp.float32), cmat.astype(jnp.float32), dt, a, p["d_skip"]),
        cfg,
        s0,
    )
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = qeinsum("bte,ed->btd", y, p["w_out"])
    x = x + constrain(out, ("batch", "seq", "embed"))
    if emit_state:
        return x, {"conv": conv_state, "ssm": S}
    return x, None


def mamba2_decode(p, x: jax.Array, state: dict, cfg: ArchConfig):
    return mamba2_fwd(p, x, cfg, state=state, emit_state=True)


def mamba2_state_shapes(cfg: ArchConfig, batch: int) -> dict:
    d_in, nh, pdim, n = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_kernel - 1, d_in), jnp.float32),
        "ssm": jax.ShapeDtypeStruct((batch, nh, n, pdim), jnp.float32),
    }
