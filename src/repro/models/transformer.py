"""Generic LM assembly: pattern-based blocks over a shared scanned datapath.

One module drives all ten assigned architectures.  An ``ArchConfig.pattern``
names the block kinds in one repeating group; the depth is ``n_groups``
repetitions.  Execution follows the paper's sequential-datapath idea: one
compiled group body is reused across the depth via ``lax.scan`` over
layer-stacked parameters (``stack_mode="unroll"`` exists for the dry-run,
where exact per-layer HLO cost accounting matters more than program size).

Entry points:
  forward(params, batch, cfg)                 full-seq logits (train / encoder)
  forward_with_cache(params, batch, cfg, L)   prefill -> (last_logits, caches)
  decode_step(params, token, caches, pos, cfg)  single-token serve step
  loss_fn / train metrics helpers
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models.layers import PSpec


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def _block_specs(kind: str, cfg: ArchConfig) -> dict:
    if kind in ("attn", "local"):
        return {"attn": L.attn_specs(cfg), "mlp": L.mlp_specs(cfg)}
    if kind == "moe":
        return {"attn": L.attn_specs(cfg), "moe": MOE.moe_specs(cfg)}
    if kind == "shared_attn":
        return {}  # weights live in params["shared"]
    if kind in ("mamba2", "mamba2_shared"):
        return {"mamba": M2.mamba2_specs(cfg)}
    if kind == "rwkv6":
        return {"rwkv": R6.rwkv6_specs(cfg)}
    raise ValueError(f"unknown block kind {kind!r}")


def build_specs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    group = {f"pos{i}": _block_specs(k, cfg) for i, k in enumerate(cfg.pattern)}
    specs: dict = {
        "embed": {"tok": PSpec((cfg.vocab, d), ("vocab", "embed"))},
        "groups": L.stack_specs(group, cfg.n_groups),
        "final_norm": L.rmsnorm_specs(d),
    }
    if "shared_attn" in cfg.pattern or "mamba2_shared" in cfg.pattern:
        specs["shared"] = {"attn": L.attn_specs(cfg), "mlp": L.mlp_specs(cfg)}
    if not cfg.tie_embeddings:
        specs["lm_head"] = PSpec((d, cfg.vocab), ("embed", "vocab"))
    if cfg.frontend == "audio_frames":
        specs["frontend"] = {
            "proj": PSpec((cfg.frontend_dim, d), ("frontend", "embed")),
            "norm": L.rmsnorm_specs(d),
        }
    elif cfg.frontend == "vision_patches":
        specs["frontend"] = {
            "norm_in": L.rmsnorm_specs(cfg.frontend_dim),
            "proj1": PSpec((cfg.frontend_dim, d), ("frontend", "embed")),
            "proj2": PSpec((d, d), ("embed", "embed")),
        }
    return specs


def init_params(rng: jax.Array, cfg: ArchConfig):
    return L.init_from_specs(rng, build_specs(cfg), cfg)


def abstract_params(cfg: ArchConfig):
    return L.abstract_from_specs(build_specs(cfg), cfg)


def logical_axes(cfg: ArchConfig):
    return L.logical_from_specs(build_specs(cfg))


def param_count(cfg: ArchConfig) -> int:
    tree = abstract_params(cfg)
    n = sum(int(np.prod(t.shape)) for t in jax.tree_util.tree_leaves(tree))
    if "shared_attn" in cfg.pattern:
        pass  # shared weights counted once already
    return n


def active_param_count(cfg: ArchConfig) -> int:
    """Params touched per token (MoE: top_k of n_experts)."""
    n = param_count(cfg)
    if cfg.n_experts and cfg.top_k:
        tree = abstract_params(cfg)
        e_params = 0
        for sub in _find_subtrees(tree["groups"], "moe"):
            for name in ("wi_gate", "wi_up", "wo"):
                e_params += int(np.prod(sub[name].shape))
        n -= int(e_params * (1 - cfg.top_k / cfg.n_experts))
    return n


def _find_subtrees(tree, key):
    out = []
    if isinstance(tree, dict):
        for k, v in tree.items():
            if k == key and isinstance(v, dict):
                out.append(v)
            elif isinstance(v, dict):
                out.extend(_find_subtrees(v, key))
    return out


# ---------------------------------------------------------------------------
# embedding / frontend
# ---------------------------------------------------------------------------


def embed_fwd(params, batch: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.frontend == "audio_frames":
        h = L.qeinsum("bsf,fd->bsd", batch["frames"].astype(jnp.dtype(cfg.act_dtype)), params["frontend"]["proj"])
        h = L.rmsnorm(params["frontend"]["norm"], h, cfg.norm_eps)
    else:
        if cfg.sharded_embed_gather:
            from repro.distributed.embedding import embedding_gather

            tok = embedding_gather(params["embed"]["tok"], batch["tokens"])
        else:
            tok = jnp.take(params["embed"]["tok"], batch["tokens"], axis=0)
        if cfg.scale_embed:
            tok = tok * jnp.asarray(np.sqrt(cfg.d_model), tok.dtype)
        h = tok
        if cfg.frontend == "vision_patches" and "patches" in batch:  # prefill/train only
            f = params["frontend"]
            pe = L.rmsnorm(f["norm_in"], batch["patches"].astype(tok.dtype), cfg.norm_eps)
            pe = jax.nn.gelu(L.qeinsum("bpf,fd->bpd", pe, f["proj1"]))
            pe = L.qeinsum("bpd,de->bpe", pe, f["proj2"])
            h = jnp.concatenate([pe, tok], axis=1)
    return constrain(h.astype(jnp.dtype(cfg.act_dtype)), ("batch", "seq", "embed"))


def unembed(params, h: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = L.qeinsum("bsd,vd->bsv", h, params["embed"]["tok"])
    else:
        logits = L.qeinsum("bsd,dv->bsv", h, params["lm_head"])
    return constrain(logits.astype(jnp.float32), ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# block dispatch (full-sequence)
# ---------------------------------------------------------------------------


def _window_for(kind: str, cfg: ArchConfig) -> Optional[int]:
    return cfg.window if kind == "local" else None


def block_fwd(kind, p, x, cfg: ArchConfig, shared, cache_len: Optional[int] = None):
    """Full-seq block.  Returns (x, cache_or_none); cache emitted only when
    ``cache_len`` is given (prefill)."""
    window = _window_for(kind, cfg)
    if kind in ("attn", "local", "moe", "shared_attn"):
        ap = shared["attn"] if kind == "shared_attn" else p["attn"]
        emit = None
        if cache_len is not None:
            emit = L.attn_cache_shape(cfg, x.shape[0], cache_len, window)
        x, cache = L.attn_fwd(ap, x, cfg, window=window, emit_cache=emit)
        if kind == "moe":
            x = MOE.moe_block(p["moe"], x, cfg)
        elif kind == "shared_attn":
            x = L.mlp_fwd(shared["mlp"], x, cfg)
        else:
            x = L.mlp_fwd(p["mlp"], x, cfg)
        return x, cache
    if kind == "mamba2":
        x, st = M2.mamba2_fwd(p["mamba"], x, cfg, emit_state=cache_len is not None)
        return x, st
    if kind == "mamba2_shared":
        # zamba2: a mamba block followed by the *shared* attention+MLP block
        x, st = M2.mamba2_fwd(p["mamba"], x, cfg, emit_state=cache_len is not None)
        emit = None
        if cache_len is not None:
            emit = L.attn_cache_shape(cfg, x.shape[0], cache_len, None)
        x, kv = L.attn_fwd(shared["attn"], x, cfg, window=None, emit_cache=emit)
        x = L.mlp_fwd(shared["mlp"], x, cfg)
        if cache_len is not None:
            return x, {"mamba": st, "attn": kv}
        return x, None
    if kind == "rwkv6":
        x, st = R6.rwkv6_fwd(p["rwkv"], x, cfg, emit_state=cache_len is not None)
        return x, st
    raise ValueError(kind)


def block_decode(kind, p, x, cache, pos, cfg: ArchConfig, shared, max_seq: int):
    window = _window_for(kind, cfg)
    if kind in ("attn", "local", "moe", "shared_attn"):
        ap = shared["attn"] if kind == "shared_attn" else p["attn"]
        spec = L.attn_cache_shape(cfg, x.shape[0], max_seq, window)
        x, cache = L.attn_decode(ap, x, cache, pos, cfg, window=window, spec=spec)
        if kind == "moe":
            x = MOE.moe_block(p["moe"], x, cfg)
        elif kind == "shared_attn":
            x = L.mlp_fwd(shared["mlp"], x, cfg)
        else:
            x = L.mlp_fwd(p["mlp"], x, cfg)
        return x, cache
    if kind == "mamba2":
        return M2.mamba2_decode(p["mamba"], x, cache, cfg)
    if kind == "mamba2_shared":
        x, st = M2.mamba2_decode(p["mamba"], x, cache["mamba"], cfg)
        spec = L.attn_cache_shape(cfg, x.shape[0], max_seq, None)
        x, kv = L.attn_decode(shared["attn"], x, cache["attn"], pos, cfg, window=None, spec=spec)
        x = L.mlp_fwd(shared["mlp"], x, cfg)
        return x, {"mamba": st, "attn": kv}
    if kind == "rwkv6":
        return R6.rwkv6_decode(p["rwkv"], x, cache, cfg)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stacked execution (scan = sequential shared datapath; unroll = dry-run)
# ---------------------------------------------------------------------------


def _group_fwd(cfg: ArchConfig, shared, cache_len):
    def body(gp, x):
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, c = block_fwd(kind, gp[f"pos{i}"], x, cfg, shared, cache_len)
            if cache_len is not None:
                caches[f"pos{i}"] = c if c is not None else {}
        return x, caches

    return body


def run_stack(params, x, cfg: ArchConfig, cache_len: Optional[int] = None):
    shared = params.get("shared")
    body = _group_fwd(cfg, shared, cache_len)
    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.stack_mode == "scan":
        def step(carry, gp):
            y, caches = body(gp, carry)
            return y, caches
        x, caches = jax.lax.scan(step, x, params["groups"])
    else:
        caches_list = []
        for gi in range(cfg.n_groups):
            gp = jax.tree_util.tree_map(lambda t, gi=gi: t[gi], params["groups"])
            x, c = body(gp, x)
            caches_list.append(c)
        caches = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches_list)
            if cache_len is not None
            else None
        )
    return x, caches


def run_stack_decode(params, x, caches, pos, cfg: ArchConfig, max_seq: int):
    shared = params.get("shared")

    def body(gp_and_cache, x):
        gp, gcache = gp_and_cache
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, c = block_decode(kind, gp[f"pos{i}"], x, gcache[f"pos{i}"], pos, cfg, shared, max_seq)
            new_caches[f"pos{i}"] = c
        return x, new_caches

    if cfg.stack_mode == "scan":
        def step(carry, xs):
            y, nc = body(xs, carry)
            return y, nc
        x, new_caches = jax.lax.scan(step, x, (params["groups"], caches))
    else:
        ncs = []
        for gi in range(cfg.n_groups):
            gp = jax.tree_util.tree_map(lambda t, gi=gi: t[gi], params["groups"])
            gc = jax.tree_util.tree_map(lambda t, gi=gi: t[gi], caches)
            x, nc = body((gp, gc), x)
            ncs.append(nc)
        new_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ncs)
    return x, new_caches


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def forward(params, batch: dict, cfg: ArchConfig, *, last_only: bool = False) -> jax.Array:
    h = embed_fwd(params, batch, cfg)
    h, _ = run_stack(params, h, cfg)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    if last_only:
        h = h[:, -1:]
    return unembed(params, h, cfg)


def forward_with_cache(params, batch: dict, cfg: ArchConfig, max_seq: int):
    """Prefill: returns (last-token logits, caches sized for max_seq decode)."""
    h = embed_fwd(params, batch, cfg)
    h, caches = run_stack(params, h, cfg, cache_len=max_seq)
    h = L.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    return unembed(params, h, cfg), caches


def decode_step(params, token: jax.Array, caches, pos: jax.Array, cfg: ArchConfig, max_seq: int):
    """One serve step: token (B, 1) int32 (or frame/patch stub), absolute
    position ``pos``; returns (logits (B, 1, V), new caches)."""
    h = embed_fwd(params, {"tokens": token}, cfg)
    h = constrain(h, ("decode_batch", "seq", "embed"))
    h, new_caches = run_stack_decode(params, h, caches, pos, cfg, max_seq)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return unembed(params, h, cfg), new_caches


def cache_shapes(cfg: ArchConfig, batch: int, max_seq: int):
    """Abstract cache tree for the dry-run serve step (ShapeDtypeStruct)."""
    act = jnp.dtype(cfg.act_dtype)
    group = {}
    for i, kind in enumerate(cfg.pattern):
        window = _window_for(kind, cfg)
        if kind in ("attn", "local", "moe", "shared_attn"):
            spec = L.attn_cache_shape(cfg, batch, max_seq, window)
            shp = (batch, spec.length, cfg.n_kv_heads, cfg.head_dim)
            group[f"pos{i}"] = {
                "k": jax.ShapeDtypeStruct(shp, act),
                "v": jax.ShapeDtypeStruct(shp, act),
            }
        elif kind == "mamba2":
            group[f"pos{i}"] = M2.mamba2_state_shapes(cfg, batch)
        elif kind == "mamba2_shared":
            spec = L.attn_cache_shape(cfg, batch, max_seq, None)
            shp = (batch, spec.length, cfg.n_kv_heads, cfg.head_dim)
            group[f"pos{i}"] = {
                "mamba": M2.mamba2_state_shapes(cfg, batch),
                "attn": {
                    "k": jax.ShapeDtypeStruct(shp, act),
                    "v": jax.ShapeDtypeStruct(shp, act),
                },
            }
        elif kind == "rwkv6":
            group[f"pos{i}"] = R6.rwkv6_state_shapes(cfg, batch)
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((cfg.n_groups,) + s.shape, s.dtype), group
    )


def cache_logical_axes(cfg: ArchConfig, seq_axis: str = "kv_seq"):
    """Logical sharding axes mirroring cache_shapes.  ``seq_axis`` is
    "kv_seq_model" when kv_heads cannot shard over the model axis (the
    launcher decides by divisibility)."""
    group = {}
    for i, kind in enumerate(cfg.pattern):
        if kind in ("attn", "local", "moe", "shared_attn"):
            ax = ("layers", "decode_batch", seq_axis, "kv_heads", "head_dim")
            group[f"pos{i}"] = {"k": ax, "v": ax}
        elif kind == "mamba2":
            group[f"pos{i}"] = {
                "conv": ("layers", "decode_batch", None, "ssm_heads"),
                "ssm": ("layers", "decode_batch", "ssm_heads", "ssm_state", None),
            }
        elif kind == "mamba2_shared":
            kvax = ("layers", "decode_batch", seq_axis, "kv_heads", "head_dim")
            group[f"pos{i}"] = {
                "mamba": {
                    "conv": ("layers", "decode_batch", None, "ssm_heads"),
                    "ssm": ("layers", "decode_batch", "ssm_heads", "ssm_state", None),
                },
                "attn": {"k": kvax, "v": kvax},
            }
        elif kind == "rwkv6":
            group[f"pos{i}"] = {
                "tm_shift": ("layers", "decode_batch", None, "embed"),
                "wkv": ("layers", "decode_batch", "heads", "head_dim", None),
                "cm_shift": ("layers", "decode_batch", None, "embed"),
            }
    return group


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(params, batch: dict, cfg: ArchConfig) -> jax.Array:
    """Causal-LM (or framewise, for encoders) cross entropy.  Labels of -1
    are masked."""
    logits = forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.frontend == "vision_patches":
        logits = logits[:, -labels.shape[1] :]  # loss over the text positions
    mask = labels >= 0
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)
