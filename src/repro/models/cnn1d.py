"""The 1D-F-CNN (SHIELD8-UAV §III-A, eq. 1) as a pure-JAX functional model.

Three blocks of  o = D_0.2( M_1x2( ReLU( C_1x3(x) ) ) )  followed by dense
layers for binary UAV classification.  The canonical (deployed) MFCC-20
configuration reproduces the paper's flatten size exactly:

    M=1096 --pool/2--> 548 --pool/2--> 274 --pool/2--> 137 frames x 256 ch
    flatten = 137 * 256 = 35,072          (Table I, before pruning)
    pruned  = 136 * 64  =  8,704          (Table I, after pruning)

Every matmul/conv dispatches through the PrecisionPolicy (the multi-
precision datapath), and PACT clip parameters α are learnable per layer.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.core.pruning import PruneSpec, apply_prune_conv, apply_prune_dense, plan_prune
from repro.core.quantization import activation_quantize, quantize_tensor


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    input_len: int = 1096
    channels: tuple[int, ...] = (64, 128, 256)
    kernel: int = 3
    hidden: int = 64
    n_classes: int = 2
    dropout: float = 0.2

    @property
    def n_frames(self) -> int:
        n = self.input_len
        for _ in self.channels:
            n //= 2
        return n

    @property
    def flatten_size(self) -> int:
        return self.n_frames * self.channels[-1]


CANONICAL = CNNConfig()  # flatten 35,072
assert CANONICAL.flatten_size == 35_072


def init_params(rng: jax.Array, cfg: CNNConfig = CANONICAL) -> dict:
    """He-init conv + dense weights; per-layer PACT α initialised at 6."""
    keys = jax.random.split(rng, len(cfg.channels) + 2)
    params: dict = {}
    c_in = 1
    for i, c_out in enumerate(cfg.channels):
        fan_in = cfg.kernel * c_in
        params[f"conv{i}"] = {
            "w": jax.random.normal(keys[i], (cfg.kernel, c_in, c_out)) * np.sqrt(2.0 / fan_in),
            "b": jnp.zeros((c_out,)),
            "alpha": jnp.asarray(6.0),
        }
        c_in = c_out
    params["dense0"] = {
        "w": jax.random.normal(keys[-2], (cfg.flatten_size, cfg.hidden))
        * np.sqrt(2.0 / cfg.flatten_size),
        "b": jnp.zeros((cfg.hidden,)),
        "alpha": jnp.asarray(6.0),
    }
    params["dense1"] = {
        "w": jax.random.normal(keys[-1], (cfg.hidden, cfg.n_classes)) * np.sqrt(2.0 / cfg.hidden),
        "b": jnp.zeros((cfg.n_classes,)),
    }
    return params


def _conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, L, C_in), w: (K, C_in, C_out) -> (B, L, C_out), 'same' padding."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )


def _maxpool2(x: jax.Array) -> jax.Array:
    """M_1x2: max-pool width 2, stride 2 over the length axis."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 1), (1, 2, 1), "VALID"
    )


def forward(
    params: dict,
    x: jax.Array,
    cfg: CNNConfig = CANONICAL,
    *,
    policy: Optional[PrecisionPolicy] = None,
    train: bool = False,
    rng: Optional[jax.Array] = None,
) -> jax.Array:
    """x: (B, M) feature vectors -> (B, n_classes) logits.

    ``policy`` selects the per-layer numeric mode (fake-quant emulation of
    the shared datapath); ``train`` enables dropout (eq. 1's D_0.2).
    """
    policy = policy or PrecisionPolicy()
    h = x[:, :, None].astype(jnp.float32)  # (B, L, 1)
    for i in range(len(cfg.channels)):
        name = f"conv{i}"
        p = params[name]
        prec = policy.precision_for(f"{name}/w")
        w = quantize_tensor(p["w"], prec, axis=2)
        h = _conv1d(h, w) + p["b"]
        h = jax.nn.relu(h)
        if prec.is_integer:
            h = activation_quantize(h, prec, p["alpha"])
        elif prec == Precision.BF16:
            h = activation_quantize(h, prec)
        h = _maxpool2(h)
        if train and cfg.dropout > 0:
            assert rng is not None, "dropout needs rng"
            rng, sub = jax.random.split(rng)
            keep = jax.random.bernoulli(sub, 1.0 - cfg.dropout, h.shape)
            h = jnp.where(keep, h / (1.0 - cfg.dropout), 0.0)
    h = h.reshape(h.shape[0], -1)  # flatten (frames, channels) row-major
    p = params["dense0"]
    prec = policy.precision_for("dense0/w")
    h = h @ quantize_tensor(p["w"], prec, axis=1) + p["b"]
    h = jax.nn.relu(h)
    if prec.is_integer:
        h = activation_quantize(h, prec, p["alpha"])
    elif prec == Precision.BF16:
        h = activation_quantize(h, prec)
    p = params["dense1"]
    prec = policy.precision_for("dense1/w")
    return h @ quantize_tensor(p["w"], prec, axis=1) + p["b"]


# ---------------------------------------------------------------------------
# Structured pruning of the trained model (§III-C)
# ---------------------------------------------------------------------------


def prune_model(params: dict, cfg: CNNConfig = CANONICAL, *, keep: int = 64, trim_frames: int = 1):
    """Prune the final conv block's channels + boundary frame; returns
    (pruned_params, pruned_cfg, PruneSpec).  Canonical config: 35,072→8,704."""
    last = len(cfg.channels) - 1
    spec = plan_prune(params[f"conv{last}"]["w"], cfg.n_frames, keep=keep, trim_frames=trim_frames)
    new = {k: dict(v) for k, v in params.items()}
    w, b = apply_prune_conv(params[f"conv{last}"]["w"], params[f"conv{last}"]["b"], spec)
    new[f"conv{last}"]["w"], new[f"conv{last}"]["b"] = w, b
    new["dense0"]["w"] = apply_prune_dense(
        params["dense0"]["w"], spec, cfg.n_frames, cfg.channels[-1]
    )
    pruned_cfg = dataclasses.replace(cfg, channels=cfg.channels[:-1] + (keep,))
    return new, pruned_cfg, spec


def forward_pruned(
    params: dict, x: jax.Array, cfg: CNNConfig, spec: PruneSpec, **kw
) -> jax.Array:
    """Forward pass for a pruned model: same graph, plus the frame trim
    between the last pool and the flatten."""
    policy = kw.pop("policy", None) or PrecisionPolicy()
    train = kw.pop("train", False)
    rng = kw.pop("rng", None)
    h = x[:, :, None].astype(jnp.float32)
    for i in range(len(cfg.channels)):
        p = params[f"conv{i}"]
        prec = policy.precision_for(f"conv{i}/w")
        w = quantize_tensor(p["w"], prec, axis=2)
        h = _conv1d(h, w) + p["b"]
        h = jax.nn.relu(h)
        if prec.is_integer:
            h = activation_quantize(h, prec, p["alpha"])
        h = _maxpool2(h)
        if train and cfg.dropout > 0:
            rng, sub = jax.random.split(rng)
            keep_m = jax.random.bernoulli(sub, 1.0 - cfg.dropout, h.shape)
            h = jnp.where(keep_m, h / (1.0 - cfg.dropout), 0.0)
    h = h[:, : len(spec.keep_frames), :]  # boundary-frame trim
    h = h.reshape(h.shape[0], -1)
    p = params["dense0"]
    prec = policy.precision_for("dense0/w")
    h = jax.nn.relu(h @ quantize_tensor(p["w"], prec, axis=1) + p["b"])
    if prec.is_integer:
        h = activation_quantize(h, prec, p["alpha"])
    p = params["dense1"]
    return h @ quantize_tensor(p["w"], policy.precision_for("dense1/w"), axis=1) + p["b"]


def calibrate_alphas(params: dict, x: jax.Array, cfg: CNNConfig = CANONICAL, pct: float = 99.9) -> dict:
    """Set each layer's PACT clip α to the ``pct`` percentile of its fp32
    activations on a calibration batch — the deployment analogue of the
    paper's *learned* clipping parameter (eq. 7).  An uncalibrated α either
    clips real signal (too low) or wastes integer levels (too high); this is
    what keeps the 8-bit modes within the paper's <2.5%% accuracy budget."""
    new = {k: dict(v) for k, v in params.items()}
    h = x[:, :, None].astype(jnp.float32)
    for i in range(len(cfg.channels)):
        p = params[f"conv{i}"]
        h = jax.nn.relu(_conv1d(h, p["w"].astype(jnp.float32)) + p["b"])
        new[f"conv{i}"]["alpha"] = jnp.percentile(h, pct)
        h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    p = params["dense0"]
    h = jax.nn.relu(h @ p["w"].astype(jnp.float32) + p["b"])
    new["dense0"]["alpha"] = jnp.percentile(h, pct)
    return new


def export_quantized(params: dict, cfg: CNNConfig = CANONICAL, *, mode: str = "int8"):
    """Export a trained checkpoint as the deployment artifact: weights
    quantised once for ``mode`` ("int8" | "fxp8"), ready for
    ``repro.serving.accelerator.accelerator_forward``.  This is the
    train → quantise once → serve handoff point."""
    from repro.serving.quantized_params import quantize_params

    return quantize_params(params, cfg, mode=mode)


def count_params(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def layer_macs(cfg: CNNConfig = CANONICAL, pruned_flatten: Optional[int] = None) -> dict[str, int]:
    """Per-layer MAC counts — feeds the cycle-accurate timing model (eqs. 9-10)."""
    macs = {}
    length = cfg.input_len
    c_in = 1
    for i, c_out in enumerate(cfg.channels):
        macs[f"conv{i}"] = length * cfg.kernel * c_in * c_out
        length //= 2
        c_in = c_out
    flat = pruned_flatten if pruned_flatten is not None else length * c_in
    macs["dense0"] = flat * cfg.hidden
    macs["dense1"] = cfg.hidden * cfg.n_classes
    return macs
