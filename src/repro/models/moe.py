"""Mixture-of-Experts FFN with capacity-based scatter dispatch (EP-friendly).

Design goals (phi3.5-moe: 16e top-2; olmoe: 64e top-8):
* FLOPs proportional to *activated* experts (capacity-bounded), never dense
  over all experts — otherwise the roofline compute term lies.
* Shardable under GSPMD with experts on the "model" mesh axis: dispatch is a
  scatter into an (E, C, d) buffer and combine a gather back, both of which
  GSPMD lowers to all-to-all-style collectives across the EP axis.
* Router stays high-precision (the sensitivity framework pins it BF16+ —
  router logits are the most quantisation-sensitive tensors in an MoE).

Token-dropping semantics: tokens beyond an expert's capacity
C = ceil(T * top_k / E * capacity_factor) are dropped for that expert
(standard Switch/GShard behaviour); the residual path carries them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain
from repro.models.layers import PSpec, qeinsum, rmsnorm, rmsnorm_specs


def moe_specs(cfg: ArchConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "norm": rmsnorm_specs(d),
        "router": PSpec((d, e), ("embed", None), dtype="float32"),
        "wi_gate": PSpec((e, d, f), ("experts", "embed", "mlp")),
        "wi_up": PSpec((e, d, f), ("experts", "embed", "mlp")),
        "wo": PSpec((e, f, d), ("experts", "mlp", "embed")),
    }


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    c = int(np.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for clean layouts


def moe_fwd(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, S, D) -> (B, S, D) with residual."""
    b, s, d = x.shape
    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    t = b * s
    ht = h.reshape(t, d)
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity(t, cfg)

    logits = jnp.einsum("td,de->te", ht.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (T, k, E)
    flatoh = onehot.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flatoh, axis=0) - flatoh  # exclusive per-expert rank
    pos = (pos_in_e * flatoh).sum(-1).reshape(t, k)  # (T, k)
    eid = gate_idx  # (T, k)
    keep = pos < cap  # capacity-dropped mask

    # scatter tokens into the (E, C, D) dispatch buffer
    buf = jnp.zeros((e, cap, d), h.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k))
    e_flat = jnp.where(keep, eid, e - 1).reshape(-1)
    p_flat = jnp.where(keep, pos, cap - 1).reshape(-1)
    src = jnp.where(keep.reshape(-1, 1), ht[tok_idx.reshape(-1)], 0.0)
    buf = buf.at[e_flat, p_flat].add(src)  # each (e,pos) slot has one real writer
    buf = constrain(buf, ("experts", "expert_capacity", "embed"))

    # expert computation (grouped einsum, experts sharded on "model")
    g = jax.nn.silu(qeinsum("ecd,edf->ecf", buf, p["wi_gate"]))
    u = qeinsum("ecd,edf->ecf", buf, p["wi_up"])
    eo = qeinsum("ecf,efd->ecd", g * u, p["wo"])
    eo = constrain(eo, ("experts", "expert_capacity", "embed"))

    # gather back and combine with gate weights
    out_tk = eo[e_flat, p_flat].reshape(t, k, d)
    out_tk = jnp.where(keep[..., None], out_tk, 0.0)
    out = (out_tk * gate_vals[..., None].astype(out_tk.dtype)).sum(axis=1)
    y = out.reshape(b, s, d).astype(x.dtype)
    return x + constrain(y, ("batch", "seq", "embed"))


def moe_fwd_a2a(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Expert-parallel MoE via shard_map + all_to_all token routing.

    The capacity-scatter path above keeps the (E, C, d) buffer's capacity dim
    *global* — per-device expert compute then scales with global tokens (the
    dry-run measured olmoe at ~0.5% useful FLOPs).  Here tokens are split
    over ("data","model"); each device routes its local T/256 tokens, packs
    per-expert sends of local capacity, all_to_all's them across the model
    (EP) axis, runs its resident experts, and reverses the route — expert
    FLOPs per device = global/chips, and the only collectives are the two
    all_to_alls (+ the router's own psum-free local work).

    Falls back to ``moe_fwd`` when no mesh rules are active (CPU tests).
    """
    from repro.distributed.sharding import active_rules

    rules = active_rules()
    if rules is None or "model" not in rules.mesh.axis_names:
        return moe_fwd(p, x, cfg)
    mesh = rules.mesh
    n_ep = mesh.shape["model"]
    e, k = cfg.n_experts, cfg.top_k
    if e % n_ep != 0:
        return moe_fwd(p, x, cfg)
    b, s, d = x.shape
    tok_axes = tuple(
        a for a in (*rules.mesh_axes_for("batch"), "model") if a in mesh.axis_names
    )
    n_tok_shards = 1
    for a in tok_axes:
        n_tok_shards *= mesh.shape[a]
    t = b * s
    if t % n_tok_shards != 0:
        return moe_fwd(p, x, cfg)
    t_loc = t // n_tok_shards
    cap = max(8, -(-int(t_loc * k / e * cfg.capacity_factor) // 8) * 8)
    e_loc = e // n_ep

    h = rmsnorm(p["norm"], x, cfg.norm_eps)
    ht = h.reshape(t, d)
    xres = x.reshape(t, d)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def local(ht_l, router, wi_g, wi_u, wo):
        # ht_l: (t_loc, d); experts sharded: wi_* (e_loc, d, f)
        logits = jnp.einsum("td,de->te", ht_l.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, -1)
        gv, gi = jax.lax.top_k(probs, k)  # (t_loc, k)
        gv = gv / jnp.maximum(gv.sum(-1, keepdims=True), 1e-9)
        oh = jax.nn.one_hot(gi, e, dtype=jnp.int32).reshape(t_loc * k, e)
        pos = (jnp.cumsum(oh, axis=0) - oh)
        pos = (pos * oh).sum(-1).reshape(t_loc, k)
        keep = pos < cap
        ef = jnp.where(keep, gi, e - 1).reshape(-1)
        pf = jnp.where(keep, pos, cap - 1).reshape(-1)
        send = jnp.zeros((e, cap, d), ht_l.dtype)
        src = jnp.where(
            keep.reshape(-1, 1), ht_l[jnp.arange(t_loc).repeat(k)], 0.0
        )
        send = send.at[ef, pf].add(src)
        # route: (e, cap, d) -> (n_ep, e_loc, cap, d) -> a2a over model
        send = send.reshape(n_ep, e_loc, cap, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0, tiled=False)
        # recv: (n_ep senders, e_loc, cap, d) for MY resident experts
        buf = recv.transpose(1, 0, 2, 3).reshape(e_loc, n_ep * cap, d)  # slots = (sender, cap)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wi_g))
        u = jnp.einsum("ecd,edf->ecf", buf, wi_u)
        eo = jnp.einsum("ecf,efd->ecd", g * u, wo)  # (e_loc, S_slots, d)
        back = eo.transpose(1, 0, 2).reshape(n_ep, cap, e_loc, d).transpose(0, 2, 1, 3)
        out = jax.lax.all_to_all(back, "model", split_axis=0, concat_axis=0, tiled=False)
        # out: (n_ep expert-groups, e_loc, cap, d) == (e, cap, d) back at sender
        out = out.reshape(e, cap, d)
        got = out[ef, pf].reshape(t_loc, k, d)
        got = jnp.where(keep[..., None], got, 0.0)
        return (got * gv[..., None].astype(got.dtype)).sum(axis=1)

    tok_spec = P(tok_axes if len(tok_axes) > 1 else tok_axes[0])
    y = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(tok_spec[0], None),
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=P(tok_spec[0], None),
        check_rep=False,
    )(ht, p["router"], _deq(p["wi_gate"]), _deq(p["wi_up"]), _deq(p["wo"]))
    y = (xres + y.astype(x.dtype)).reshape(b, s, d)
    return constrain(y, ("batch", "seq", "embed"))


def _deq(w):
    from repro.core.quantization import QTensor

    if isinstance(w, QTensor):
        return w.q.astype(jnp.bfloat16) * w.scale.astype(jnp.bfloat16)
    return w


def moe_block(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Dispatch on cfg.moe_impl."""
    if cfg.moe_impl == "a2a":
        return moe_fwd_a2a(p, x, cfg)
    return moe_fwd(p, x, cfg)


def load_balance_loss(logits: jax.Array, gate_idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style auxiliary load-balance loss (exposed for training)."""
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)
    ce = jnp.bincount(gate_idx.reshape(-1), length=n_experts) / gate_idx.size
    return n_experts * jnp.sum(me * ce)
