"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul_ref(x_q, w_q, x_scale, w_scale) -> jax.Array:
    """int8 x int8 -> int32 accumulate -> fp32 dequant."""
    acc = jnp.matmul(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32)
    )
    return acc.astype(jnp.float32) * x_scale.astype(jnp.float32) * w_scale.astype(jnp.float32)


def tanh_ref(x):
    return jnp.tanh(x)


def sigmoid_ref(x):
    return jax.nn.sigmoid(x)


def exp_ref(x):
    return jnp.exp(jnp.clip(x, -30.0, 30.0))


def swish_ref(x):
    return x * jax.nn.sigmoid(x)


def gelu_ref(x):
    # tanh-approximation GELU (the form the CORDIC unit implements)
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def selu_ref(x):
    return jax.nn.selu(x)


def relu_ref(x):
    return jax.nn.relu(x)


def softmax_ref(x, axis=-1):
    return jax.nn.softmax(x, axis=axis)


ACT_REFS = {
    "tanh": tanh_ref,
    "sigmoid": sigmoid_ref,
    "exp": exp_ref,
    "swish": swish_ref,
    "gelu": gelu_ref,
    "selu": selu_ref,
    "relu": relu_ref,
}


def conv1d_q_ref(x, w, b=None):
    """fp32 'same'-padded 1D conv oracle, (B, L, Cin) x (K, Cin, Cout)."""
    out = jax.lax.conv_general_dilated(
        x, w, (1,), "SAME", dimension_numbers=("NWC", "WIO", "NWC")
    )
    return out if b is None else out + b
