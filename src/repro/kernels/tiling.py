"""VMEM-budget-driven block-shape selection for the Pallas kernels.

The kernels used to hardcode 128-sided tiles everywhere, which is only one
point of the compiled-backend design space: a (bm, bn, bk) = (128, 128, 128)
matmul tile uses ~100 KB of VMEM while a v5e core has ~16 MB, and conversely
a large-Cin conv block can silently blow the budget once the halo view and
the weight taps are counted.  This module makes the geometry explicit: each
selector takes the problem shape plus a declared per-core VMEM budget and
returns block shapes that

* respect the hardware granules — the last (lane) dimension is always a
  multiple of 128, the second-to-last (sublane) a multiple of the dtype's
  minimum tile (32 for int8 operands, 8 for fp32) — and
* fit the budget under the Pallas pipeline model: blocked operands and
  outputs are double-buffered (2x their block bytes), scratch accumulators
  are resident once.

Numerical contract: tile choice NEVER changes the int32 accumulators.  Every
output element's accumulator sums exactly the same set of int8 x int8
products regardless of how the grid is cut, and int32 addition is
associative and commutative (wrap-around included), so the accumulator bits
are invariant under any (bl, bm, bn, bk) selection.  ``tests/test_tiling.py``
pins this bitwise across distinct budgets for all three kernels.
"""
from __future__ import annotations

from dataclasses import dataclass

#: MXU/VPU lane width — the last dim of every block is a multiple of this.
LANE = 128

#: minimum sublane multiple per operand byte-width (int8 -> 32, fp32 -> 8)
SUBLANE_INT8 = 32
SUBLANE_FP32 = 8

#: v5e VMEM per core (~16 MB) and the default working budget we declare for
#: one kernel's blocks.  The budget is deliberately half the physical VMEM:
#: the other half covers semaphores, compiler-managed spills and the slack
#: the pipeline needs to overlap grid steps.
VMEM_BYTES_PER_CORE = 16 * 2**20
DEFAULT_VMEM_BUDGET = 8 * 2**20

#: ceiling on any single block side — beyond this, bigger tiles stop paying
#: (the MXU is saturated) and VMEM pressure just grows.
MAX_TILE = 512


def _rup(x: int, b: int) -> int:
    return (x + b - 1) // b * b


def _shrink(v: int, granule: int) -> int:
    """One shrink step: halve towards the granule, never below it."""
    return max(granule, _rup(v // 2, granule) if v // 2 > granule else granule)


@dataclass(frozen=True)
class MatmulTiles:
    bm: int
    bn: int
    bk: int


@dataclass(frozen=True)
class ConvTiles:
    bl: int  # output rows (length-axis tile)
    bn: int  # output channels


@dataclass(frozen=True)
class ElementwiseTiles:
    bm: int
    bn: int


def matmul_vmem_bytes(
    bm: int, bn: int, bk: int, *, has_bias: bool = False, has_clip: bool = False
) -> int:
    """Pipeline-model VMEM bytes for one ``quant_matmul`` grid step.

    Blocked inputs/outputs count twice (double buffering); the int32
    accumulator scratch is resident once.
    """
    x = bm * bk  # int8
    w = bk * bn  # int8
    xs = bm * 4  # (bm, 1) fp32 scale column
    ws = bn * 4  # (1, bn) fp32 scale row
    bias = bn * 4 if has_bias else 0
    clip = 4 if has_clip else 0
    out = bm * bn * 4  # fp32
    acc = bm * bn * 4  # int32 scratch, single-buffered
    return 2 * (x + w + xs + ws + bias + clip + out) + acc


def select_matmul_tiles(
    m: int,
    k: int,
    n: int,
    *,
    budget: int = DEFAULT_VMEM_BUDGET,
    has_bias: bool = False,
    has_clip: bool = False,
) -> MatmulTiles:
    """Pick (bm, bn, bk) for an (M, K) x (K, N) W8A8 matmul.

    Starts from the largest granule-aligned tiles that the problem shape and
    ``MAX_TILE`` allow, then shrinks the side that frees the most VMEM until
    the pipeline footprint fits the budget.  Deterministic in its inputs.
    """
    bm = min(_rup(m, SUBLANE_INT8), MAX_TILE)
    bn = min(_rup(n, LANE), MAX_TILE)
    bk = min(_rup(k, LANE), MAX_TILE)
    while matmul_vmem_bytes(bm, bn, bk, has_bias=has_bias, has_clip=has_clip) > budget:
        # Shrink the dimension whose reduction frees the most bytes; bk is
        # preferred on ties (it only lengthens the in-VMEM K loop, while bm/bn
        # cuts shrink MXU utilisation).
        gains = {
            "bk": _gain_matmul(bm, bn, bk, "bk", has_bias, has_clip),
            "bm": _gain_matmul(bm, bn, bk, "bm", has_bias, has_clip),
            "bn": _gain_matmul(bm, bn, bk, "bn", has_bias, has_clip),
        }
        dim = max(gains, key=lambda d: (gains[d], d == "bk"))
        if gains[dim] <= 0:
            break  # every side is at its granule — smallest legal tiling
        if dim == "bm":
            bm = _shrink(bm, SUBLANE_INT8)
        elif dim == "bn":
            bn = _shrink(bn, LANE)
        else:
            bk = _shrink(bk, LANE)
    return MatmulTiles(bm, bn, bk)


def _gain_matmul(bm, bn, bk, dim, has_bias, has_clip):
    now = matmul_vmem_bytes(bm, bn, bk, has_bias=has_bias, has_clip=has_clip)
    s = {
        "bm": (_shrink(bm, SUBLANE_INT8), bn, bk),
        "bn": (bm, _shrink(bn, LANE), bk),
        "bk": (bm, bn, _shrink(bk, LANE)),
    }[dim]
    return now - matmul_vmem_bytes(*s, has_bias=has_bias, has_clip=has_clip)


def conv_halo_rows(k: int) -> int:
    """Sublane-rounded row count of the halo view (the first rows of the
    next length block that tap ``t`` of the last outputs reads)."""
    return _rup(max(k - 1, 1), SUBLANE_INT8)


def conv_vmem_bytes(
    bl: int,
    bn: int,
    *,
    k: int,
    cin_p: int,
    has_bias: bool = False,
    has_clip: bool = False,
) -> int:
    """Pipeline-model VMEM bytes for one ``conv1d_fused_q`` grid step."""
    xm = bl * cin_p  # int8 main activation block
    xh = conv_halo_rows(k) * cin_p if k > 1 else 0  # int8 halo view
    w = k * cin_p * bn  # int8 weight taps (stationary per step, still blocked)
    xs = 4  # (1, 1) per-sample scale
    ws = bn * 4
    bias = bn * 4 if has_bias else 0
    clip = 4 if has_clip else 0
    out = bl * bn * 4  # fp32 (or int32 accumulator output — same bytes)
    return 2 * (xm + xh + w + xs + ws + bias + clip + out)


def select_conv_tiles(
    b: int,
    l: int,
    cin: int,
    cout: int,
    k: int,
    *,
    budget: int = DEFAULT_VMEM_BUDGET,
    lane: int = LANE,
    has_bias: bool = False,
    has_clip: bool = False,
) -> ConvTiles:
    """Pick (bl, bn) for a (B, L, Cin) x (K, Cin, Cout) fused conv.

    ``Cin`` is not tiled (the taps need the full input-channel extent in
    VMEM), so its padded extent is a fixed term; the selector trades the
    length tile against the output-channel tile.  ``bl`` stays a multiple of
    the halo granule so the halo view's block index is exact.
    """
    cin_p = _rup(cin, lane)
    granule_l = max(SUBLANE_INT8, conv_halo_rows(k) if k > 1 else SUBLANE_INT8)
    bl = min(_rup(l, granule_l), MAX_TILE)
    bn = min(_rup(cout, LANE), MAX_TILE)
    while (
        conv_vmem_bytes(bl, bn, k=k, cin_p=cin_p, has_bias=has_bias, has_clip=has_clip)
        > budget
    ):
        shrunk_bl = _shrink(bl, granule_l)
        shrunk_bn = _shrink(bn, LANE)
        gain_bl = _delta_conv(bl, bn, shrunk_bl, bn, k, cin_p, has_bias, has_clip)
        gain_bn = _delta_conv(bl, bn, bl, shrunk_bn, k, cin_p, has_bias, has_clip)
        if max(gain_bl, gain_bn) <= 0:
            break  # at the smallest legal tiling for this Cin
        if gain_bn >= gain_bl:
            bn = shrunk_bn
        else:
            bl = shrunk_bl
    return ConvTiles(bl, bn)


def _delta_conv(bl, bn, bl2, bn2, k, cin_p, has_bias, has_clip):
    kw = dict(k=k, cin_p=cin_p, has_bias=has_bias, has_clip=has_clip)
    return conv_vmem_bytes(bl, bn, **kw) - conv_vmem_bytes(bl2, bn2, **kw)


def select_elementwise_tiles(
    n_elems: int, *, budget: int = DEFAULT_VMEM_BUDGET
) -> ElementwiseTiles:
    """Pick the (bm, LANE) block for an elementwise fp32 kernel
    (``cordic_activation``): the widest fp32-sublane-aligned row count whose
    double-buffered in+out blocks fit the budget, capped at the problem size.
    """
    rows_needed = _rup(max(1, (n_elems + LANE - 1) // LANE), SUBLANE_FP32)
    bm = min(rows_needed, MAX_TILE)
    # in + out fp32 blocks, both double-buffered
    while 2 * (2 * bm * LANE * 4) > budget and bm > SUBLANE_FP32:
        bm = _shrink(bm, SUBLANE_FP32)
    return ElementwiseTiles(bm, LANE)
