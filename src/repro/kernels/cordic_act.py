"""Pallas TPU kernel: CORDIC-based activation unit (POLARON's AF stage).

The accelerator computes activations with a CORDIC unit ("a CORDIC-based
activation unit supporting Swish, SoftMax, SeLU, GELU, Sigmoid, Tanh and
ReLU").  CORDIC is a shift-add hardware algorithm: hyperbolic rotation-mode
iterations produce (cosh z, sinh z) from which tanh/sigmoid/exp derive.

TPU adaptation (DESIGN.md §2): the shift-add iteration is kept *bit-faithful*
in int32 fixed point (Q15.16) inside VREG ops — `x >> i` etc. — so the kernel
reproduces the numerics the RTL unit would produce, not merely the math.  On
a real TPU one would use the VPU's transcendental ops instead; this kernel
exists to (a) emulate accelerator-exact activation numerics for the accuracy
tables and (b) demonstrate the hardware algorithm as a Pallas program.

Hyperbolic CORDIC needs iterations {1..N} with 4 and 13 repeated to converge
(|z| <= ~1.118); exp uses base-2 range reduction, tanh uses the doubling
identity once (tanh convergence domain then covers |x| <= ~2.23, saturating
beyond), and the other activations derive from those two primitives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import tiling
from repro.kernels.backend import resolve_interpret

F = 16  # fraction bits (Q15.16)
ONE = 1 << F
LN2 = float(np.log(2.0))

# hyperbolic iteration schedule: 1..18 with 4 and 13 repeated
_ITERS = [1, 2, 3, 4, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 13, 14, 15, 16, 17, 18]
_ATANH_TABLE = np.array(
    [round(float(np.arctanh(2.0**-i)) * ONE) for i in _ITERS], np.int32
)
_GAIN = float(np.prod([np.sqrt(1.0 - 2.0 ** (-2 * i)) for i in _ITERS]))
_X0 = round(ONE / _GAIN)  # pre-scaled so x converges to cosh, y to sinh

MODES = ("tanh", "sigmoid", "exp", "swish", "gelu", "selu", "relu")

_SELU_ALPHA = 1.6732632423543772
_SELU_SCALE = 1.0507009873554805


def _cordic_sinh_cosh(z_fx: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Rotation-mode hyperbolic CORDIC on Q15.16 ints.

    Returns (cosh, sinh) in Q15.16.  Valid for |z| <= ~1.118.
    """
    x = jnp.full_like(z_fx, _X0)
    y = jnp.zeros_like(z_fx)
    z = z_fx

    # Unrolled shift-add iterations with *static* shift amounts and angle
    # constants — exactly how the RTL unit is built (one stage per iteration).
    for shift, e in zip(_ITERS, (int(v) for v in _ATANH_TABLE)):
        d_pos = z >= 0
        xs = jax.lax.shift_right_arithmetic(x, shift)
        ys = jax.lax.shift_right_arithmetic(y, shift)
        x, y, z = (
            jnp.where(d_pos, x + ys, x - ys),
            jnp.where(d_pos, y + xs, y - xs),
            jnp.where(d_pos, z - e, z + e),
        )
    return x, y


def _fx(v: jax.Array) -> jax.Array:
    """fp32 -> Q15.16 (round to nearest)."""
    return jnp.round(v * ONE).astype(jnp.int32)


def _fl(v: jax.Array) -> jax.Array:
    """Q15.16 -> fp32."""
    return v.astype(jnp.float32) / ONE


def _exp_core(v: jax.Array) -> jax.Array:
    """exp(v) via base-2 range reduction + CORDIC exp(r) = cosh r + sinh r."""
    v = jnp.clip(v, -30.0, 30.0)
    k = jnp.round(v / LN2)
    r = v - k * LN2  # |r| <= ln2/2 = 0.3466 < 1.118  (convergence domain)
    c, s = _cordic_sinh_cosh(_fx(r))
    return _fl(c + s) * jnp.exp2(k)


def _tanh_core(v: jax.Array) -> jax.Array:
    """tanh via two doublings: tanh(2a) = 2 t / (1 + t^2), a = v/4.

    |a| = |v|/4 <= 1.1 keeps the CORDIC in its convergence domain for
    |v| <= 4.4; beyond that tanh saturates to +-1 (|tanh(4.4)| = 0.99967,
    within Q15.16 LSB of 1).
    """
    a = jnp.clip(v, -4.4, 4.4) * 0.25
    c, s = _cordic_sinh_cosh(_fx(a))
    t = s.astype(jnp.float32) / jnp.maximum(c.astype(jnp.float32), 1.0)
    t = 2.0 * t / (1.0 + t * t)
    t = 2.0 * t / (1.0 + t * t)
    return jnp.where(jnp.abs(v) >= 4.4, jnp.sign(v), t)


def _apply_mode(v: jax.Array, mode: str) -> jax.Array:
    if mode == "tanh":
        return _tanh_core(v)
    if mode == "sigmoid":
        return 0.5 * (1.0 + _tanh_core(0.5 * v))
    if mode == "exp":
        return _exp_core(v)
    if mode == "swish":
        return v * (0.5 * (1.0 + _tanh_core(0.5 * v)))
    if mode == "gelu":
        inner = 0.7978845608028654 * (v + 0.044715 * v**3)
        return 0.5 * v * (1.0 + _tanh_core(inner))
    if mode == "selu":
        neg = _SELU_ALPHA * (_exp_core(jnp.minimum(v, 0.0)) - 1.0)
        return _SELU_SCALE * jnp.where(v > 0, v, neg)
    if mode == "relu":
        return jnp.maximum(v, 0.0)
    raise ValueError(f"unknown CORDIC mode {mode!r}")


def _kernel(x_ref, o_ref, *, mode: str):
    o_ref[...] = _apply_mode(x_ref[...].astype(jnp.float32), mode)


@functools.partial(jax.jit, static_argnames=("mode", "block", "interpret"))
def cordic_activation(
    x: jax.Array,
    mode: str = "tanh",
    *,
    block: tuple[int, int] | None = None,  # None: VMEM-budgeted
    interpret: bool | None = None,
) -> jax.Array:
    """Elementwise CORDIC activation over an arbitrary-shape fp32 tensor.

    The (rows, lanes) block defaults to
    ``tiling.select_elementwise_tiles`` for the flattened element count;
    block choice only changes padding/grid, never the per-element Q15.16
    shift-add numerics (pinned bitwise by ``tests/test_tiling.py``).
    """
    assert mode in MODES, mode
    interpret = resolve_interpret(interpret)
    shape = x.shape
    flat = x.reshape(-1)
    if block is None:
        t = tiling.select_elementwise_tiles(flat.shape[0])
        block = (t.bm, t.bn)
    bm, bn = block
    n = flat.shape[0]
    cols = bn
    rows = _rup(max(1, (n + cols - 1) // cols), bm)
    pad = rows * cols - n
    grid_in = jnp.pad(flat, (0, pad)).reshape(rows, cols)
    out = pl.pallas_call(
        functools.partial(_kernel, mode=mode),
        grid=(rows // bm,),
        in_specs=[pl.BlockSpec((bm, bn), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(grid_in)
    return out.reshape(-1)[:n].reshape(shape)


def cordic_softmax(x: jax.Array, axis: int = -1, interpret: bool | None = None) -> jax.Array:
    """Softmax with CORDIC exponentials (max-subtracted for stability)."""
    m = jax.lax.stop_gradient(jnp.max(x, axis=axis, keepdims=True))
    e = cordic_activation(x - m, "exp", interpret=interpret)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def _rup(x: int, b: int) -> int:
    return (x + b - 1) // b * b
