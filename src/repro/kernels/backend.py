"""Backend autodetection for the Pallas kernels.

Every kernel wrapper takes ``interpret: bool | None``.  ``None`` (the
default everywhere) resolves via :func:`resolve_interpret`: compiled on a
real TPU, interpreter mode on every other backend (CPU containers, GPU
hosts).  This is the single switch that lets the same datapath code run as
the correctness twin in CI and as the compiled pipeline on hardware.
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None = None) -> bool:
    """Resolve an ``interpret`` flag: explicit values win, ``None`` means
    "interpret unless we are actually on a TPU"."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
