"""Pallas TPU kernel: W8A8 quantised matmul with int32 extended accumulation.

This is the TPU mapping of POLARON's multi-precision MAC bank: int8 operands
stream through the MXU, partial sums accumulate in an int32 VMEM scratch
("extended-precision accumulators maintain numerical stability"), and the
final scale-and-shift/dequant happens once per output tile (the accelerator's
"normalisation, scale-and-shift" stage).

Grid is (M/bm, N/bn, K/bk) with K innermost so each (m, n) output tile keeps
its accumulator resident in VMEM across the K loop (weights-stationary within
a tile, exactly the shared-datapath reuse discipline).  Tile sides default to
``kernels.tiling.select_matmul_tiles`` — VMEM-budgeted per problem shape,
rounded to MXU/lane granules (bm to the int8 sublane, bn/bk to the 128
lane).  Because every output element's accumulator sums the same set of
products whatever the grid cut, tile choice never changes the int32
accumulator bits (``tests/test_tiling.py``); ``return_acc=True`` exposes
those raw accumulators as the sign-off surface.

The dequant step doubles as the layer *epilogue*: an optional bias add,
ReLU, and PACT-style clip are applied on the accumulator tile before the
single fp32 store, so a full conv/dense layer needs exactly one HBM write
instead of three (matmul out, bias out, activation out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tiling
from repro.kernels.backend import resolve_interpret


def _kernel(x_ref, w_ref, *rest, act, has_bias, has_clip, return_acc):
    i = 0
    if return_acc:
        xs_ref = ws_ref = b_ref = c_ref = None
    else:
        xs_ref, ws_ref = rest[0], rest[1]
        i = 2
        b_ref = rest[i] if has_bias else None
        i += has_bias
        c_ref = rest[i] if has_clip else None
        i += has_clip
    o_ref, acc_ref = rest[i], rest[i + 1]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        if return_acc:
            o_ref[...] = acc_ref[...]
            return
        y = acc_ref[...].astype(jnp.float32) * xs_ref[...] * ws_ref[...]
        if has_bias:
            y = y + b_ref[...]
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        if has_clip:
            y = jnp.minimum(y, c_ref[0, 0])
        o_ref[...] = y


@functools.partial(
    jax.jit, static_argnames=("act", "bm", "bn", "bk", "interpret", "return_acc")
)
def quant_matmul(
    x_q: jax.Array,  # (M, K) int8
    w_q: jax.Array,  # (K, N) int8
    x_scale: jax.Array,  # (M, 1) or (1, 1) fp32
    w_scale: jax.Array,  # (1, N) or (1, 1) fp32
    bias: jax.Array | None = None,  # (N,) or (1, N) fp32, fused epilogue add
    *,
    act: str | None = None,  # None or "relu", fused on the accumulator tile
    clip: jax.Array | None = None,  # scalar fp32 upper clip (PACT alpha)
    bm: int | None = None,  # None: VMEM-budgeted (tiling.select_matmul_tiles)
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool | None = None,  # None: autodetect (compiled on TPU)
    return_acc: bool = False,  # skip dequant, return raw int32 accumulators
) -> jax.Array:
    """Dequantised fp32 product of int8 operands; pads to tile multiples.

    ``bias``/``act``/``clip`` form the fused epilogue: they are applied to
    the int32 accumulator tile in VMEM right before the one dequant store,
    never as a separate pass over the output in HBM.
    """
    assert act in (None, "relu"), act
    interpret = resolve_interpret(interpret)
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    if bm is None or bn is None or bk is None:
        picked = tiling.select_matmul_tiles(
            m, k, n,
            has_bias=bias is not None and not return_acc,
            has_clip=clip is not None and not return_acc,
        )
        bm = picked.bm if bm is None else bm
        bn = picked.bn if bn is None else bn
        bk = picked.bk if bk is None else bk
    mp, kp, np_ = _rup(m, bm), _rup(k, bk), _rup(n, bn)
    x_q = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
    w_q = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))

    grid = (mp // bm, np_ // bn, kp // bk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    inputs = [x_q, w_q]
    has_bias = bias is not None and not return_acc
    has_clip = clip is not None and not return_acc
    if not return_acc:
        xs = jnp.broadcast_to(x_scale.astype(jnp.float32), (m, 1))
        xs = jnp.pad(xs, ((0, mp - m), (0, 0)), constant_values=1.0)
        ws = jnp.broadcast_to(w_scale.astype(jnp.float32), (1, n))
        ws = jnp.pad(ws, ((0, 0), (0, np_ - n)), constant_values=1.0)
        inputs += [xs, ws]
        in_specs += [
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ]
        if has_bias:
            b = jnp.broadcast_to(bias.astype(jnp.float32).reshape(1, -1), (1, n))
            inputs.append(jnp.pad(b, ((0, 0), (0, np_ - n))))
            in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        if has_clip:
            inputs.append(jnp.asarray(clip, jnp.float32).reshape(1, 1))
            in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)))

    out_dtype = jnp.int32 if return_acc else jnp.float32
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            act=act,
            has_bias=has_bias,
            has_clip=has_clip,
            return_acc=return_acc,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(*inputs)
    return out[:m, :n]


def _rup(x: int, b: int) -> int:
    return (x + b - 1) // b * b
