"""Pallas TPU kernel: W8A8 quantised matmul with int32 extended accumulation.

This is the TPU mapping of POLARON's multi-precision MAC bank: int8 operands
stream through the MXU, partial sums accumulate in an int32 VMEM scratch
("extended-precision accumulators maintain numerical stability"), and the
final scale-and-shift/dequant happens once per output tile (the accelerator's
"normalisation, scale-and-shift" stage).

Grid is (M/bm, N/bn, K/bk) with K innermost so each (m, n) output tile keeps
its accumulator resident in VMEM across the K loop (weights-stationary within
a tile, exactly the shared-datapath reuse discipline).  Tile sides are
multiples of 128 to align with the 128x128 MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _dequant():
        o_ref[...] = acc_ref[...].astype(jnp.float32) * xs_ref[...] * ws_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul(
    x_q: jax.Array,  # (M, K) int8
    w_q: jax.Array,  # (K, N) int8
    x_scale: jax.Array,  # (M, 1) or (1, 1) fp32
    w_scale: jax.Array,  # (1, N) or (1, 1) fp32
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,  # CPU container: interpret mode; False on real TPU
) -> jax.Array:
    """Dequantised fp32 product of int8 operands; pads to tile multiples."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    mp, kp, np_ = _rup(m, bm), _rup(k, bk), _rup(n, bn)
    x_q = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
    w_q = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
    xs = jnp.broadcast_to(x_scale.astype(jnp.float32), (m, 1))
    xs = jnp.pad(xs, ((0, mp - m), (0, 0)), constant_values=1.0)
    ws = jnp.broadcast_to(w_scale.astype(jnp.float32), (1, n))
    ws = jnp.pad(ws, ((0, 0), (0, np_ - n)), constant_values=1.0)

    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, xs, ws)
    return out[:m, :n]


def _rup(x: int, b: int) -> int:
    return (x + b - 1) // b * b
