"""Pallas TPU kernel: fused quantised 1D convolution (im2col-in-VMEM).

The seed datapath lowered conv onto ``quant_matmul`` by materialising an
im2col patch tensor of shape (B*L, K*Cin) in HBM — K copies of every
activation — then paying two more full HBM round-trips for the bias add and
the ReLU.  This kernel keeps the whole layer inside the compute fabric:

* **in-kernel im2col** — each grid step loads one (bl, Cin) activation block
  plus a sublane-rounded halo view (the first rows of the *next* block,
  read straight from the same padded HBM buffer through a second BlockSpec
  with a shifted index map) and forms the K shifted views with static
  slices in VMEM.  No patch tensor and no separate halo tensor ever exist
  in HBM; 1-tap convs skip the halo operand entirely.
* **weight-stationary taps** — the full (K, Cin, bn) weight block sits in
  VMEM for the whole grid step; the K tap matmuls accumulate into one int32
  register tile (the extended-precision accumulator discipline shared with
  ``quant_matmul``).
* **fused epilogue** — dequant, bias add, ReLU and the optional PACT clip
  happen on the accumulator tile, then a single fp32 store.  One HBM write
  per layer instead of three.

Block shapes default to ``kernels.tiling.select_conv_tiles`` — picked per
problem shape from the declared per-core VMEM budget, rounded to MXU/lane
granules.  Tile choice never changes the int32 accumulator bits (pinned by
``tests/test_tiling.py``).

The layout contract matches ``conv1d_q``: activations (B, L, Cin) int8 with
a per-tensor *or per-sample* ((B,)-broadcastable) scale, weights
(K, Cin, Cout) int8 with per-output-channel scales, 'same' zero padding.  ``return_acc=True`` skips the epilogue and
returns the raw int32 accumulators — the bitwise sign-off surface against
the im2col reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quantization import QTensor, fxp8_quantize, int8_symmetric
from repro.kernels import tiling
from repro.kernels.backend import resolve_interpret


def _kernel(xm_ref, *rest, k, bl, act, has_halo, has_bias, has_clip, return_acc):
    i = 0
    if has_halo:
        xh_ref = rest[0]
        i = 1
    w_ref = rest[i]
    i += 1
    if return_acc:
        xs_ref = ws_ref = b_ref = c_ref = None
    else:
        xs_ref, ws_ref = rest[i], rest[i + 1]
        i += 2
        b_ref = rest[i] if has_bias else None
        i += has_bias
        c_ref = rest[i] if has_clip else None
        i += has_clip
    o_ref = rest[i]

    xm = xm_ref[0]  # (bl, Cin) int8 activation block
    if has_halo:
        # First k-1 rows of the next length block, read through the shifted
        # view of the same padded buffer (no HBM halo tensor exists).
        xcat = jnp.concatenate([xm, xh_ref[0, : k - 1]], axis=0)
    else:
        xcat = xm
    # im2col via shifted static slices of the VMEM-resident block: tap t of
    # output row l reads input row l + t (the 'same' pad is already baked
    # into the HBM layout), so each tap is one (bl, Cin) x (Cin, bn) matmul.
    acc = jax.lax.dot(
        xcat[0:bl], w_ref[0], preferred_element_type=jnp.int32
    )
    for t in range(1, k):
        acc += jax.lax.dot(
            xcat[t : t + bl], w_ref[t], preferred_element_type=jnp.int32
        )
    if return_acc:
        o_ref[0] = acc
        return
    y = acc.astype(jnp.float32) * xs_ref[0, 0] * ws_ref[...]
    if has_bias:
        y = y + b_ref[...]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    if has_clip:
        y = jnp.minimum(y, c_ref[0, 0])
    o_ref[0] = y


@functools.partial(
    jax.jit,
    static_argnames=("act", "bl", "bn", "lane", "interpret", "return_acc"),
)
def conv1d_fused_q(
    x_q: jax.Array,  # (B, L, Cin) int8
    w_q: jax.Array,  # (K, Cin, Cout) int8
    x_scale: jax.Array,  # scalar (per-tensor) or (B,)-broadcastable (per-sample) fp32
    w_scale: jax.Array,  # (Cout,)-broadcastable fp32 per-channel weight scale
    bias: jax.Array | None = None,  # (Cout,) fp32, fused epilogue add
    *,
    act: str | None = None,  # None or "relu"
    clip: jax.Array | None = None,  # scalar fp32 upper clip (PACT alpha)
    bl: int | None = None,  # output rows per grid step (None: VMEM-budgeted)
    bn: int | None = None,  # output channels per grid step (None: VMEM-budgeted)
    lane: int = 128,  # Cin padding granule (MXU lane width)
    interpret: bool | None = None,
    return_acc: bool = False,
) -> jax.Array:
    """Fused W8A8 'same' 1D convolution; fp32 out (int32 if ``return_acc``)."""
    assert act in (None, "relu"), act
    interpret = resolve_interpret(interpret)
    b, l, cin = x_q.shape
    k, cin2, cout = w_q.shape
    assert cin == cin2, (x_q.shape, w_q.shape)
    if bl is None or bn is None:
        picked = tiling.select_conv_tiles(
            b, l, cin, cout, k,
            lane=lane,
            has_bias=bias is not None and not return_acc,
            has_clip=clip is not None and not return_acc,
        )
        bl = picked.bl if bl is None else bl
        bn = picked.bn if bn is None else bn
    cin_p, cout_p, lout_p = _rup(cin, lane), _rup(cout, bn), _rup(l, bl)
    nblk = lout_p // bl
    pad_l = (k - 1) // 2
    has_halo = k > 1
    # HBM layout: one padded buffer ('same' zero pad baked in, so input row
    # l0 + t of tap t is a plain shifted read).  The halo is NOT a separate
    # tensor — it is a second BlockSpec view of this same buffer whose index
    # map points one length-block ahead; the trailing pad below gives the
    # last block's halo view somewhere to read.
    hr = tiling.conv_halo_rows(k) if has_halo else 0
    assert not has_halo or bl % hr == 0, (bl, hr)  # exact halo block index
    lp = lout_p + hr
    xp = jnp.pad(
        x_q, ((0, 0), (pad_l, lp - pad_l - l), (0, cin_p - cin))
    )  # (B, Lp, Cin_p) int8
    wp = jnp.pad(w_q, ((0, 0), (0, cin_p - cin), (0, cout_p - cout)))

    in_specs = [pl.BlockSpec((1, bl, cin_p), lambda bb, i, j: (bb, i, 0))]
    inputs: list = [xp]
    if has_halo:
        # Overlapping read of the padded main buffer: block index is in
        # halo-row granules, so step i's halo starts at row (i+1) * bl.
        mult = bl // hr
        in_specs.append(
            pl.BlockSpec((1, hr, cin_p), lambda bb, i, j: (bb, (i + 1) * mult, 0))
        )
        inputs.append(xp)
    in_specs.append(pl.BlockSpec((k, cin_p, bn), lambda bb, i, j: (0, 0, j)))
    inputs.append(wp)
    has_bias = bias is not None and not return_acc
    has_clip = clip is not None and not return_acc
    if not return_acc:
        ws = jnp.broadcast_to(
            w_scale.astype(jnp.float32).reshape(1, -1), (1, cout)
        )
        # Activation scale: one scalar per batch row (a per-tensor scale is
        # broadcast), so each grid step reads its own sample's dequant scale
        # — this is what lets co-batched streams quantise independently.
        xs = jnp.broadcast_to(
            jnp.asarray(x_scale, jnp.float32).reshape(-1, 1), (b, 1)
        )
        inputs += [
            xs,
            jnp.pad(ws, ((0, 0), (0, cout_p - cout)), constant_values=1.0),
        ]
        in_specs += [
            pl.BlockSpec((1, 1), lambda bb, i, j: (bb, 0)),
            pl.BlockSpec((1, bn), lambda bb, i, j: (0, j)),
        ]
        if has_bias:
            bv = jnp.broadcast_to(bias.astype(jnp.float32).reshape(1, -1), (1, cout))
            inputs.append(jnp.pad(bv, ((0, 0), (0, cout_p - cout))))
            in_specs.append(pl.BlockSpec((1, bn), lambda bb, i, j: (0, j)))
        if has_clip:
            inputs.append(jnp.asarray(clip, jnp.float32).reshape(1, 1))
            in_specs.append(pl.BlockSpec((1, 1), lambda bb, i, j: (0, 0)))

    out_dtype = jnp.int32 if return_acc else jnp.float32
    out = pl.pallas_call(
        functools.partial(
            _kernel,
            k=k,
            bl=bl,
            act=act,
            has_halo=has_halo,
            has_bias=has_bias,
            has_clip=has_clip,
            return_acc=return_acc,
        ),
        grid=(b, nblk, cout_p // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bl, bn), lambda bb, i, j: (bb, i, j)),
        out_shape=jax.ShapeDtypeStruct((b, lout_p, cout_p), out_dtype),
        interpret=interpret,
    )(*inputs)
    return out[:, :l, :cout]


def conv1d_fused(
    x: jax.Array,  # (B, L, Cin) fp32
    w: jax.Array,  # (K, Cin, Cout) fp32
    bias: jax.Array | None = None,
    *,
    fxp: bool = False,
    act: str | None = None,
    clip: jax.Array | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Quantise fp32 operands and run the fused conv kernel.

    Uses the same quantisers and axes as ``conv1d_q`` (per-tensor
    activations, per-output-channel weights) so the two paths see bitwise
    identical int8 payloads.
    """
    quant = fxp8_quantize if fxp else int8_symmetric
    xq: QTensor = quant(x, axis=None)
    wq: QTensor = quant(w, axis=2)
    return conv1d_fused_q(
        xq.q,
        wq.q,
        xq.scale,
        wq.scale,
        bias,
        act=act,
        clip=clip,
        interpret=interpret,
    )


def _rup(x: int, b: int) -> int:
    return (x + b - 1) // b * b
