"""jit'd public wrappers over the Pallas kernels.

``conv1d_q`` lowers the 1D convolution onto the quant_matmul kernel via
im2col — convolution and dense layers literally share one MAC datapath,
which is the paper's central architectural idea ("mapping convolutional and
dense layers onto a shared compute fabric").  ``conv1d_fused`` is the
deployed successor: the im2col happens *inside* the kernel (shifted VMEM
loads), with bias/ReLU fused into the dequant epilogue — same numerics, no
(B*L, K*Cin) patch tensor in HBM.  ``conv1d_q`` is kept as the reference
the fused path is signed off against.

All wrappers take ``interpret=None``: autodetect via
``repro.kernels.backend`` (compiled on TPU, interpreter elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import QTensor, fxp8_quantize, int8_symmetric
from repro.kernels.backend import resolve_interpret  # noqa: F401
from repro.kernels.conv1d_fused import conv1d_fused, conv1d_fused_q  # noqa: F401
from repro.kernels.cordic_act import cordic_activation, cordic_softmax  # noqa: F401
from repro.kernels.quant_matmul import quant_matmul  # noqa: F401


def quant_matmul_f32(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    fxp: bool = False,
    act: str | None = None,
    clip: jax.Array | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Quantise fp32 operands (per-tensor act, per-column weight) and multiply
    on the W8A8 kernel, with the optional fused bias/ReLU/clip epilogue."""
    quant = fxp8_quantize if fxp else int8_symmetric
    xq: QTensor = quant(x, axis=None)
    wq: QTensor = quant(w, axis=1)
    return quant_matmul(
        xq.q,
        wq.q,
        xq.scale.reshape(1, 1),
        wq.scale.reshape(1, -1),
        bias,
        act=act,
        clip=clip,
        interpret=interpret,
    )


def _im2col(x: jax.Array, k: int) -> jax.Array:
    """(B, L, C) -> (B*L, k*C) patches under 'same' zero padding."""
    b, l, c = x.shape
    pad = (k - 1) // 2
    xp = jnp.pad(x, ((0, 0), (pad, k - 1 - pad), (0, 0)))
    cols = jnp.stack([xp[:, i : i + l, :] for i in range(k)], axis=2)  # (B, L, k, C)
    return cols.reshape(b * l, k * c)


def conv1d_q(
    x: jax.Array,  # (B, L, Cin) fp32
    w: jax.Array,  # (K, Cin, Cout) fp32
    b: jax.Array | None = None,
    *,
    fxp: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Quantised 'same' 1D convolution on the shared matmul datapath
    (materialised-im2col reference path)."""
    bsz, l, cin = x.shape
    k, cin2, cout = w.shape
    assert cin == cin2
    patches = _im2col(x, k)  # (B*L, K*Cin)
    wmat = w.reshape(k * cin, cout)
    out = quant_matmul_f32(patches, wmat, fxp=fxp, interpret=interpret)
    out = out.reshape(bsz, l, cout)
    return out if b is None else out + b
