"""Gradient compression for the cross-pod (DCN) axis.

At 2+ pods the data-parallel gradient all-reduce crosses the slow DCN links.
Two standard mitigations are implemented:

* ``int8_compress / int8_decompress`` — per-tensor symmetric int8 with an
  fp32 scale (8x wire reduction) and **error feedback** (the quantisation
  residual is carried into the next step), which keeps SGD/Adam convergence
  (Karimireddy et al., 2019).  Used by wrapping the pod-axis psum in
  ``shard_map`` (see launch/train.py) or, in the GSPMD train step, by
  fake-quantising gradients so the all-reduce payload is int8-representable.
* ``topk_compress`` — magnitude top-k sparsification with error feedback.

These are *numerics* modules (pure JAX, unit-tested for the error-feedback
convergence property); the wire-format win shows up in the roofline's
collective term when enabled.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, error: jax.Array):
    """Error-feedback int8: returns (q, scale, new_error)."""
    corrected = g + error
    q, scale = int8_compress(corrected)
    new_error = corrected - int8_decompress(q, scale)
    return q, scale, new_error


def fake_compress_grads(grads: Any) -> Any:
    """Round-trip every gradient tensor through int8 (emulation used inside
    the GSPMD train step: the all-reduce payload becomes int8-exact, and on
    real DCN transports the wire format is int8)."""

    def rt(g):
        if g.ndim < 1 or g.size < 1024:
            return g
        q, s = int8_compress(g)
        return int8_decompress(q, s).astype(g.dtype)

    return jax.tree_util.tree_map(rt, grads)


def topk_compress(g: jax.Array, k_frac: float = 0.01):
    """Magnitude top-k sparsification: returns (values, indices, shape)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    sel = flat[idx]
    return sel, idx, g.shape


def topk_decompress(vals, idx, shape):
    out = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), vals.dtype)
    out = out.at[idx].set(vals)
    return out.reshape(shape)
