"""Optimizers (pure JAX, no optax): Adam/AdamW with fp32 states + schedules.

States are plain pytrees mirroring the parameter tree, so they inherit the
parameter sharding under pjit (and can optionally be ZeRO-1 sharded over the
data axis by the train-step's sharding rules).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class Adam:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: float | None = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamState, params):
        """Returns (new_params, new_state)."""
        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.nu, grads)
        t = step.astype(jnp.float32)
        mhat_scale = 1.0 / (1 - b1**t)
        vhat_scale = 1.0 / (1 - b2**t)
        lr = self._lr(step)

        def upd(p, m, v):
            delta = lr * (m * mhat_scale) / (jnp.sqrt(v * vhat_scale) + self.eps)
            if self.weight_decay:
                delta = delta + lr * self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamState(step=step, mu=mu, nu=nu)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_warmup_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    """Linear warmup -> cosine decay to floor*peak."""

    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr
