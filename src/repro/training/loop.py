"""Training loop for the 1D-F-CNN detector (paper §IV-B).

Adam + cross-entropy + early stopping on validation accuracy, exactly as the
paper describes; reports accuracy/precision/recall/F1 plus the continuous-
monitoring metrics (false-alarm and missed-detection rates) used by
Figs. 4-5.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision_policy import PrecisionPolicy
from repro.models import cnn1d
from repro.training.optimizer import Adam


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


@dataclasses.dataclass
class Metrics:
    accuracy: float
    precision: float
    recall: float
    f1: float
    false_alarm_rate: float  # FP / negatives  (Fig. 5a)
    missed_detection_rate: float  # FN / positives  (Fig. 5b)

    def row(self) -> dict:
        return dataclasses.asdict(self)


def evaluate_logits(logits: np.ndarray, labels: np.ndarray) -> Metrics:
    pred = np.argmax(logits, axis=1)
    tp = int(np.sum((pred == 1) & (labels == 1)))
    tn = int(np.sum((pred == 0) & (labels == 0)))
    fp = int(np.sum((pred == 1) & (labels == 0)))
    fn = int(np.sum((pred == 0) & (labels == 1)))
    acc = (tp + tn) / max(len(labels), 1)
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    f1 = 2 * prec * rec / max(prec + rec, 1e-9)
    far = fp / max(fp + tn, 1)
    mdr = fn / max(fn + tp, 1)
    return Metrics(acc, prec, rec, f1, far, mdr)


@partial(jax.jit, static_argnames=("cfg",))
def _train_step(params, opt_state, x, y, rng, cfg: cnn1d.CNNConfig):
    def loss_fn(p):
        logits = cnn1d.forward(p, x, cfg, train=True, rng=rng)
        return cross_entropy(logits, y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state = _OPT.update(grads, opt_state, params)
    return params, opt_state, loss


_OPT = Adam(lr=1e-3)


@partial(jax.jit, static_argnames=("cfg", "policy_json"))
def _infer(params, x, cfg: cnn1d.CNNConfig, policy_json: Optional[str] = None):
    policy = PrecisionPolicy.from_json(policy_json) if policy_json else None
    return cnn1d.forward(params, x, cfg, policy=policy, train=False)


def predict(params, feats: np.ndarray, cfg, policy: Optional[PrecisionPolicy] = None, batch: int = 256):
    outs = []
    pj = policy.to_json() if policy else None
    for i in range(0, len(feats), batch):
        outs.append(np.asarray(_infer(params, jnp.asarray(feats[i : i + batch]), cfg, pj)))
    return np.concatenate(outs)


@dataclasses.dataclass
class TrainResult:
    params: dict
    cfg: cnn1d.CNNConfig
    history: list[dict]
    best_val_acc: float


def train_detector(
    feats_train: np.ndarray,
    y_train: np.ndarray,
    feats_val: np.ndarray,
    y_val: np.ndarray,
    cfg: cnn1d.CNNConfig,
    *,
    epochs: int = 30,
    batch: int = 64,
    patience: int = 5,
    seed: int = 0,
    verbose: bool = False,
) -> TrainResult:
    """Adam + cross-entropy + early stopping on val accuracy (paper §IV-B)."""
    rng = jax.random.PRNGKey(seed)
    rng, init_rng = jax.random.split(rng)
    params = cnn1d.init_params(init_rng, cfg)
    opt_state = _OPT.init(params)
    n = len(feats_train)
    best = (-1.0, params)
    bad_epochs = 0
    history = []
    order_rng = np.random.default_rng(seed)
    for epoch in range(epochs):
        order = order_rng.permutation(n)
        losses = []
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            rng, sub = jax.random.split(rng)
            params, opt_state, loss = _train_step(
                params, opt_state, jnp.asarray(feats_train[idx]), jnp.asarray(y_train[idx]), sub, cfg
            )
            losses.append(float(loss))
        val_logits = predict(params, feats_val, cfg)
        m = evaluate_logits(val_logits, y_val)
        history.append({"epoch": epoch, "loss": float(np.mean(losses)), "val_acc": m.accuracy})
        if verbose:
            print(f"epoch {epoch}: loss={np.mean(losses):.4f} val_acc={m.accuracy:.4f}")
        if m.accuracy > best[0]:
            best = (m.accuracy, jax.tree_util.tree_map(lambda x: x, params))
            bad_epochs = 0
        else:
            bad_epochs += 1
            if bad_epochs >= patience:
                break
    return TrainResult(params=best[1], cfg=cfg, history=history, best_val_acc=best[0])
