"""Build-once/cache detector artifacts shared by benchmarks and examples.

Trains the 1D-F-CNN per feature set on the synthetic UAV corpus (paper
§IV-A/B), applies the sensitivity-driven precision assignment, and caches
everything under artifacts/detector/.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np

from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.core.sensitivity import assign_precisions, sensitivity_scores
from repro.data import acoustic, features
from repro.models import cnn1d
from repro.training import loop
from repro.training.checkpoint import restore_checkpoint, save_checkpoint

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "detector"

# dataset difficulty chosen so the FP32/MFCC headline lands near the paper's
# ~90% operating point (see EXPERIMENTS.md §Table II)
DATASET = dict(n=2400, seed=7, snr_range=(-12.0, 18.0), p_clean=0.08)
SPLIT = (1800, 300)  # train, val (rest = test)


def _dataset_cached():
    path = ARTIFACTS / "dataset.npz"
    if path.exists():
        z = np.load(path)
        return acoustic.AcousticDataset(z["audio"], z["labels"], z["snr"])
    ds = acoustic.make_dataset(**DATASET)
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path, audio=ds.audio, labels=ds.labels, snr=ds.snr_db)
    return ds


def _features_cached(ds, kind: str) -> np.ndarray:
    path = ARTIFACTS / f"feats_{kind}.npy"
    if path.exists():
        return np.load(path)
    f = features.batch_features(ds.audio, kind)
    np.save(path, f)
    return f


def get_detector(kind: str = "mfcc20", *, epochs: int = 14, force: bool = False):
    """Returns dict(params, cfg, feats, labels, split, metrics, policy)."""
    ds = _dataset_cached()
    feats = _features_cached(ds, kind)
    cfg = cnn1d.CNNConfig(input_len=features.FEATURE_DIMS[kind])
    ck = ARTIFACTS / f"model_{kind}"
    n_tr, n_va = SPLIT
    if ck.exists() and not force:
        params0 = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
        _, params = restore_checkpoint(ck / "step_0000000001", params0)
    else:
        res = loop.train_detector(
            feats[:n_tr], ds.labels[:n_tr],
            feats[n_tr : n_tr + n_va], ds.labels[n_tr : n_tr + n_va],
            cfg, epochs=epochs, batch=64, patience=5,
        )
        params = res.params
        save_checkpoint(ck, 1, params)
    # learned-clipping deployment step (paper eq. 7): calibrate PACT alphas
    import jax.numpy as jnp

    params = cnn1d.calibrate_alphas(params, jnp.asarray(feats[:256]), cfg)
    test_logits = loop.predict(params, feats[n_tr + n_va :], cfg)
    metrics = loop.evaluate_logits(test_logits, ds.labels[n_tr + n_va :])
    return {
        "params": params, "cfg": cfg, "feats": feats, "labels": ds.labels,
        "snr": ds.snr_db, "split": SPLIT, "metrics": metrics, "kind": kind,
    }


def sensitivity_policy(det, n_batch: int = 256) -> PrecisionPolicy:
    """Eq. (2)-(3) scoring on a training batch -> per-layer precision map."""
    import jax.numpy as jnp

    params, cfg, feats, labels = det["params"], det["cfg"], det["feats"], det["labels"]
    x = jnp.asarray(feats[:n_batch])
    y = jnp.asarray(labels[:n_batch])

    def loss(p):
        return loop.cross_entropy(cnn1d.forward(p, x, cfg), y)

    grads = jax.grad(loss)(params)
    flat_p = {f"{k}/w": v["w"] for k, v in params.items()}
    flat_g = {f"{k}/w": v["w"] for k, v in grads.items()}
    scores = sensitivity_scores(flat_p, flat_g)
    rules = assign_precisions(
        scores,
        high_fraction=0.25,
        pinned={"dense1/w": Precision.FP32},  # classifier head stays FP32
    )
    return PrecisionPolicy(rules=rules, default=Precision.INT8)
