"""LM-scale train and serve steps (the jitted programs the dry-run lowers).

train_step: gradient-accumulation scan over microbatches (bounds the
fp32-logit working set under 200k+ vocabs), remat per block group, Adam in
fp32 with states sharded like params, optional int8 gradient compression on
the cross-pod (DCN) axis.

serve steps: prefill (build sharded KV caches) and decode (single token).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.training.optimizer import Adam, AdamState


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    n_micro: int = 8
    compress_pod_grads: bool = False  # int8 + error feedback on the DCN axis


def make_train_step(
    cfg: ArchConfig, opt: Adam, settings: TrainSettings = TrainSettings()
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state: AdamState, batch: dict):
        n_micro = settings.n_micro
        b = jax.tree_util.tree_leaves(batch)[0].shape[0]
        assert b % n_micro == 0, (b, n_micro)

        micro = jax.tree_util.tree_map(
            lambda x: x.reshape(n_micro, b // n_micro, *x.shape[1:]), batch
        )

        def loss_of(p, mb):
            return T.loss_fn(p, mb, cfg)

        def body(gsum, mb):
            l, g = jax.value_and_grad(loss_of)(params, mb)
            gsum = jax.tree_util.tree_map(
                lambda a, x: a + x.astype(jnp.float32), gsum, g
            )
            return gsum, l

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        gsum, losses = jax.lax.scan(body, g0, micro)
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
        if settings.compress_pod_grads:
            from repro.training.compression import fake_compress_grads

            grads = fake_compress_grads(grads)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": losses.mean()}

    return train_step


def make_prefill_step(cfg: ArchConfig, max_seq: int) -> Callable:
    def prefill(params, batch: dict):
        return T.forward_with_cache(params, batch, cfg, max_seq)

    return prefill


def make_decode_step(cfg: ArchConfig, max_seq: int) -> Callable:
    def decode(params, token, caches, pos):
        return T.decode_step(params, token, caches, pos, cfg, max_seq)

    return decode


def make_encoder_step(cfg: ArchConfig) -> Callable:
    """Encoder-only 'serve' = full forward returning framewise logits."""

    def encode(params, batch: dict):
        return T.forward(params, batch, cfg)

    return encode
