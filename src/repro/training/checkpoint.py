"""Fault-tolerant, elastic checkpointing (no orbax offline — built on npz).

Design (mirrors what a 1000-node deployment needs):

* **Sharded, atomic saves**: each leaf is saved as its own .npy inside a
  temp directory that is atomically renamed on completion (a preempted save
  never corrupts the previous checkpoint); a MANIFEST.json carries the tree
  structure, dtypes, shapes, step and config fingerprint.
* **Elastic restore**: leaves are restored as *global* arrays and then
  device_put against the *current* mesh's shardings — a checkpoint written
  on a 16x16 mesh restores onto 2x16x16, 8x8, or 1 CPU device (resharding
  happens at placement).  This is the restart path after a topology change.
* **Retention + preemption hooks**: ``CheckpointManager`` keeps the last K
  checkpoints, exposes ``save_on_signal`` (SIGTERM -> emergency save), and
  ``maybe_restore`` for crash-restart resume.

On a real multi-host cluster each host writes only the shards it owns
(`jax.experimental.multihost_utils`); on this single-process container the
full array is written — the layout and restore path are identical.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import signal
import tempfile
import time
from pathlib import Path
from typing import Any, Callable, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(directory: str | Path, step: int, tree: Any, *, extra: Optional[dict] = None) -> Path:
    """Atomic sharded save of a pytree; returns the final checkpoint path."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:010d}"
    tmp = Path(tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory))
    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "time": time.time(), "extra": extra or {}, "leaves": []}
    try:
        for i, (key, leaf) in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_checkpoint(directory: str | Path) -> Optional[Path]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = sorted(p for p in directory.iterdir() if p.name.startswith("step_"))
    return steps[-1] if steps else None


def restore_checkpoint(path: str | Path, tree_like: Any, *, shardings: Any = None) -> tuple[int, Any]:
    """Restore into the structure of ``tree_like``; if ``shardings`` (a
    matching pytree of NamedSharding) is given, leaves are placed onto the
    *current* mesh — the elastic-rescale path."""
    path = Path(path)
    manifest = json.loads((path / "MANIFEST.json").read_text())
    by_key = {e["key"]: e for e in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (pth, leaf) in enumerate(flat):
        key = "/".join(_path_str(p) for p in pth)
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
        arr = np.load(path / entry["file"])
        expected = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expected:
            raise ValueError(f"leaf {key}: ckpt shape {arr.shape} != expected {expected}")
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return manifest["step"], jax.tree_util.tree_unflatten(treedef, leaves)


@dataclasses.dataclass
class CheckpointManager:
    directory: str | Path
    keep: int = 3
    save_every: int = 100

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> Path:
        path = save_checkpoint(self.directory, step, tree, extra=extra)
        self._gc()
        return path

    def maybe_restore(self, tree_like: Any, shardings: Any = None) -> tuple[int, Any]:
        """Resume from the latest checkpoint if present, else (0, tree_like)."""
        latest = latest_checkpoint(self.directory)
        if latest is None:
            return 0, tree_like
        return restore_checkpoint(latest, tree_like, shardings=shardings)

    def install_preemption_hook(self, get_state: Callable[[], tuple[int, Any]]):
        """SIGTERM -> emergency checkpoint (preemption-safe training)."""

        def handler(signum, frame):
            step, tree = get_state()
            save_checkpoint(self.directory, step, tree, extra={"emergency": True})
            raise SystemExit(143)

        signal.signal(signal.SIGTERM, handler)

    def _gc(self):
        directory = Path(self.directory)
        steps = sorted(p for p in directory.iterdir() if p.name.startswith("step_"))
        for old in steps[: -self.keep]:
            shutil.rmtree(old, ignore_errors=True)
