"""Serialisation-aware structured channel pruning (SHIELD8-UAV §III-C, Table I).

In the paper's sequential accelerator the flatten→dense interface is the
latency bottleneck: the flattened feature vector is streamed element-by-
element (PISO) through the shared datapath, so dense-layer cycles ==
flattened size.  Structured channel pruning *before the flatten* cuts that
dimension 35,072 → 8,704 (75 %), directly cutting serialised cycles.

On TPU there is no PISO serialisation; the same transform instead cuts the
dense layer's FLOPs and bytes by 75 % — the pruning objective is retargeted
at the dominant roofline term (see DESIGN.md §2).  The transform itself is
reproduced exactly: channel importance by L1 norm, top-K channel keep, mask
propagation into the consumer dense layer, plus the boundary-frame trim that
yields the paper's exact 8,704.

Generic FFN-channel pruning for the LM stacks lives here too
(``prune_ffn``), using the same importance rule.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PruneSpec:
    """Result of planning a structured channel prune."""

    keep_channels: np.ndarray  # sorted indices of surviving channels
    keep_frames: np.ndarray  # surviving spatial frames (boundary trim)
    flatten_before: int
    flatten_after: int

    @property
    def reduction(self) -> float:
        return 1.0 - self.flatten_after / self.flatten_before

    def to_dict(self) -> dict:
        """Plain-JSON form so a spec can ride along in configs/artifacts."""
        return {
            "keep_channels": [int(c) for c in self.keep_channels],
            "keep_frames": [int(f) for f in self.keep_frames],
            "flatten_before": int(self.flatten_before),
            "flatten_after": int(self.flatten_after),
        }

    @staticmethod
    def from_dict(d: Mapping) -> "PruneSpec":
        return PruneSpec(
            keep_channels=np.asarray(d["keep_channels"], np.int64),
            keep_frames=np.asarray(d["keep_frames"], np.int64),
            flatten_before=int(d["flatten_before"]),
            flatten_after=int(d["flatten_after"]),
        )

    @property
    def cache_key(self) -> tuple:
        """Hashable identity (numpy members make the dataclass unhashable)."""
        return (
            tuple(int(c) for c in self.keep_channels),
            tuple(int(f) for f in self.keep_frames),
            self.flatten_before,
            self.flatten_after,
        )


def channel_importance(w_conv: jax.Array) -> jax.Array:
    """L1-norm importance of each output channel of a conv kernel.

    ``w_conv`` has layout (kernel, in_ch, out_ch) — the lax.conv 1D layout
    used throughout the model code.
    """
    return jnp.sum(jnp.abs(w_conv), axis=(0, 1))


def plan_prune(
    w_conv: jax.Array,
    n_frames: int,
    *,
    keep: int,
    trim_frames: int = 0,
) -> PruneSpec:
    """Plan a structured prune of the final conv block feeding the flatten.

    keep=64, trim_frames=1 on the paper's (frames=137, ch=256) feature map
    reproduces Table I exactly: 137*256 = 35,072 → 136*64 = 8,704.
    The frame trim removes the final boundary frame (conv zero-padding
    artefact at the right edge) — cheap to remove, never informative.
    """
    imp = np.asarray(channel_importance(w_conv))
    order = np.argsort(imp)[::-1]
    keep_ch = np.sort(order[:keep])
    keep_fr = np.arange(n_frames - trim_frames)
    n_ch = w_conv.shape[-1]
    return PruneSpec(
        keep_channels=keep_ch,
        keep_frames=keep_fr,
        flatten_before=n_frames * n_ch,
        flatten_after=len(keep_fr) * keep,
    )


def apply_prune_conv(w_conv: jax.Array, b_conv: jax.Array, spec: PruneSpec):
    """Slice the producing conv's output channels."""
    return w_conv[:, :, spec.keep_channels], b_conv[spec.keep_channels]


def apply_prune_dense(w_dense: jax.Array, spec: PruneSpec, n_frames: int, n_ch: int):
    """Propagate the prune into the consumer dense layer.

    The flatten order is (frames, channels) row-major; rows of ``w_dense``
    (shape: flatten × out) corresponding to pruned channels/frames are
    dropped.
    """
    w = w_dense.reshape(n_frames, n_ch, -1)
    w = w[np.ix_(spec.keep_frames, spec.keep_channels)]
    return w.reshape(spec.flatten_after, -1)


def prune_ffn(
    w_in: jax.Array, w_out: jax.Array, *, keep: int
) -> tuple[jax.Array, jax.Array, np.ndarray]:
    """Structured hidden-channel prune of a dense FFN (LM generalisation).

    ``w_in``: (d_model, d_ff), ``w_out``: (d_ff, d_model).  Importance of a
    hidden channel is ||w_in[:, c]||_1 * ||w_out[c, :]||_1 (flow through the
    channel).  Returns sliced weights + kept indices.
    """
    imp = np.asarray(
        jnp.sum(jnp.abs(w_in), axis=0) * jnp.sum(jnp.abs(w_out), axis=1)
    )
    keep_idx = np.sort(np.argsort(imp)[::-1][:keep])
    return w_in[:, keep_idx], w_out[keep_idx, :], keep_idx
