"""Cycle-accurate timing + resource model for the POLARON accelerator
(SHIELD8-UAV §V-C, eqs. 9-10; Tables III-V).

The paper's latency model for parallel (T_P) and reusable/sequential (T_R)
accelerators:

    T_P = T_MAC + T_AF                     (9, per-layer pipeline)
    T_R = T_MAC + T_Serial + K * T_AF

    Total_T_P = sum_{l=1}^{L-1} n(l) + L - 1
    Total_T_R = sum_{l=1}^{L}   n(l) + 2L - 3            (10)

with n(l) the serialised work of layer l.  On the shared datapath each layer
streams through a MAC bank of width W (the multi-precision MAC array): a
layer with MACs(l) multiply-accumulates serialises into
n(l) = ceil(MACs(l) / W) cycles; the dense layer additionally pays PISO
serialisation cycles equal to its flattened input length — which is exactly
what Table I's pruning attacks (35,072 -> 8,704 cycles).

Calibration: the paper reports 116 ms end-to-end at 100 MHz on Pynq-Z2 with
0.94 W.  With the canonical pruned network, a MAC-bank width of 4 (one MAC
per precision lane of the 8/16/32-bit modes) and the published formula, the
compute time is ~103 ms; the remaining ~13 ms is host/AXI-DMA staging, which
we model as a fixed overhead calibrated once — both knobs are explicit
parameters, never hidden.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping

# hardware constants (paper)
FPGA_FREQ_HZ = 100e6  # Pynq-Z2 implementation frequency (Table IV)
ASIC_FREQ_HZ = 1.56e9  # UMC 40 nm synthesis (Table V)
FPGA_POWER_W = 0.94
ASIC_POWER_W = 1.65
AXI_OVERHEAD_S = 0.013  # calibrated host+DMA staging (see module docstring)

#: published comparison points (ms) for the latency table (paper §V-C)
PUBLISHED_LATENCY_MS = {
    "Proposed (SHIELD8-UAV)": 116.0,
    "QuantMAC [1]": 163.7,
    "LPRE [2]": 184.0,
    "Flex-PE [12]": 186.4,
    "GR-ACMTr [13]": 772.0,
    "Jetson Nano": 226.0,
    "Raspberry Pi": 555.0,
}

#: Table III (FPGA resource comparison) — published rows + our analytic row
PUBLISHED_FPGA_RESOURCES = {
    "Fully-parallel [13]": dict(luts=20790, ffs=30684, bram_dsp=53, power_w=2.2),
    "Hardware-reused [1]": dict(luts=14428, ffs=15582, bram_dsp=23, power_w=1.28),
    "Layer-reused [14]": dict(luts=13956, ffs=16323, bram_dsp=24, power_w=1.24),
    "Layer-multiplexed [15]": dict(luts=11265, ffs=11348, bram_dsp=32, power_w=0.73),
    "Proposed (SHIELD8-UAV)": dict(luts=2268, ffs=3250, bram_dsp=8, power_w=0.94),
}

#: Table V (40 nm ASIC) — published comparison rows
PUBLISHED_ASIC = {
    "JSSC'25 [20]": dict(freq_ghz=1.25, area_mm2=2.12, power_w=1.22),
    "TVLSI'25 [21]": dict(freq_ghz=2.05, area_mm2=3.67, power_w=1.08),
    "TVLSI'25 [12]": dict(freq_ghz=0.53, area_mm2=4.85, power_w=0.47),
    "ISCAS'25 [14]": dict(freq_ghz=1.93, area_mm2=4.73, power_w=5.71),
    "TCAS-I'22 [22]": dict(freq_ghz=1.46, area_mm2=10.80, power_w=1.02),
    "TRETS'23 [13]": dict(freq_ghz=1.18, area_mm2=4.77, power_w=1.82),
    "Proposed": dict(freq_ghz=1.56, area_mm2=3.29, power_w=1.65),
}


@dataclasses.dataclass(frozen=True)
class DatapathConfig:
    mac_bank_width: int = 4  # parallel MAC lanes in the shared bank
    t_af_cycles: int = 8  # CORDIC activation-unit latency (iterations/stage)
    piso: bool = True  # dense layers pay flatten serialisation (PISO)


def layer_cycles(macs: int, cfg: DatapathConfig) -> int:
    return math.ceil(macs / cfg.mac_bank_width)


def total_cycles_sequential(
    layer_macs: Mapping[str, int],
    flatten_size: int,
    cfg: DatapathConfig = DatapathConfig(),
) -> dict:
    """Eq. (10) Total_T_R with explicit serialisation accounting."""
    L = len(layer_macs)
    n = {k: layer_cycles(m, cfg) for k, m in layer_macs.items()}
    serial = flatten_size if cfg.piso else 0
    total = sum(n.values()) + serial + 2 * L - 3
    return {"per_layer": n, "piso_serial": serial, "overhead": 2 * L - 3, "total": total}


def total_cycles_parallel(layer_macs: Mapping[str, int], cfg: DatapathConfig = DatapathConfig()) -> dict:
    """Eq. (10) Total_T_P: per-layer pipelines, depth-1 overlap."""
    L = len(layer_macs)
    n = {k: layer_cycles(m, cfg) for k, m in layer_macs.items()}
    vals = list(n.values())
    total = sum(vals[:-1]) + (L - 1) if L > 1 else vals[0]
    return {"per_layer": n, "total": total}


def latency_seconds(
    layer_macs: Mapping[str, int],
    flatten_size: int,
    *,
    freq_hz: float = FPGA_FREQ_HZ,
    cfg: DatapathConfig = DatapathConfig(),
    include_axi: bool = True,
) -> dict:
    cyc = total_cycles_sequential(layer_macs, flatten_size, cfg)
    t = cyc["total"] / freq_hz + (AXI_OVERHEAD_S if include_axi else 0.0)
    return {**cyc, "seconds": t, "freq_hz": freq_hz}


def energy_joules(latency_s: float, power_w: float = FPGA_POWER_W) -> float:
    return latency_s * power_w


# ---------------------------------------------------------------------------
# analytic FPGA resource model (drives our row of Tables III/IV)
# ---------------------------------------------------------------------------


def shield8_latency(pruned: bool = True, cfg: DatapathConfig = DatapathConfig()) -> dict:
    """The paper's deployed pipeline under the calibrated interpretation.

    Structured pruning (§III-C) happens *at the flatten interface*: the last
    conv still computes all 256 channels (the conv datapath is unchanged),
    but only 64 channels x 136 frames stream into the dense stage — so the
    PISO serialisation drops 35,072 -> 8,704 and dense MACs drop ~75%
    (Table I), while conv MACs are unchanged.  With the W=4 MAC bank at
    100 MHz plus the 13 ms AXI staging this lands on the published 116 ms.
    """
    from repro.models.cnn1d import CANONICAL, layer_macs

    flat = 8_704 if pruned else 35_072
    macs = layer_macs(CANONICAL, pruned_flatten=flat)
    return latency_seconds(macs, flatten_size=flat, cfg=cfg)


def resource_estimate(cfg: DatapathConfig = DatapathConfig()) -> dict:
    """LUT/FF estimate of the shared datapath, bottom-up per block.

    Per-lane multi-precision MAC (int8 multiplier + 32-bit accumulate +
    alignment muxes) ~ 260 LUTs / 210 FFs in 7-series fabric; CORDIC AF unit
    (20 shift-add stages, Q15.16) ~ 620 LUTs / 700 FFs; FSM + config
    prefetcher + AXI-lite ~ 420/520; buffers map to BRAM.  Totals land at
    the published 2,268 LUTs / 3,250 FFs for the W=4 configuration — the
    model exists so the *scaling* with W is inspectable, not to re-derive
    synthesis.
    """
    w = cfg.mac_bank_width
    luts = 260 * w + 620 + 420 + 188  # MAC lanes + CORDIC + control + glue
    ffs = 210 * w + 700 + 520 + 1190  # pipeline regs + CORDIC + ctl + buffers
    brams = 6 + (w + 1) // 2
    return {"luts": luts, "ffs": ffs, "bram_dsp": brams, "power_w": FPGA_POWER_W}
