"""Precision-aware quantisation (SHIELD8-UAV §III-B).

Implements the paper's four numeric modes — FP32, BF16, INT8, FXP8 — plus the
PwQ weight quantiser (eqs. 4-6) and PACT activation quantiser (eqs. 7-8).

Two layers of machinery live here:

* *Emulation* quantisers (``pwq_quantize``, ``pact``, ``quantize_tensor``)
  that return fake-quantised fp32 tensors.  These reproduce the paper's
  "Python-based arithmetic emulation model ... prior to RTL realisation"
  and drive the accuracy tables.
* *Deployment* quantisers (``int8_symmetric``, ``fxp8_quantize``) that return
  actual int8 payloads + scales, consumed by the Pallas ``quant_matmul``
  kernel (the multi-precision MAC bank analogue).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class Precision(str, enum.Enum):
    """Numeric modes supported by the shared multi-precision datapath."""

    FP32 = "fp32"
    BF16 = "bf16"
    INT8 = "int8"
    FXP8 = "fxp8"

    @property
    def bits(self) -> int:
        return {"fp32": 32, "bf16": 16, "int8": 8, "fxp8": 8}[self.value]

    @property
    def is_integer(self) -> bool:
        return self in (Precision.INT8, Precision.FXP8)


# ---------------------------------------------------------------------------
# PwQ weight quantisation (paper eqs. 4-6)
# ---------------------------------------------------------------------------


def pwq_scale(w: jax.Array, n_bits: int) -> jax.Array:
    """Paper eq. (4):  scale(k) = mean(|W|) * (2^n - 1) / 2^(n-1)."""
    n = n_bits
    return jnp.mean(jnp.abs(w)) * (2.0**n - 1.0) / (2.0 ** (n - 1))


def default_clip_bounds(w: jax.Array, n_bits: int) -> tuple[jax.Array, jax.Array]:
    """Initial (W_l, W_h) clipping bounds for PwQ.

    The paper *learns* these; the learned values are initialised from the
    normalised weight range, which is what we use when no learned bounds are
    supplied.  Bounds live in the ``W / scale(k)`` domain (see eq. 5).
    """
    k = pwq_scale(w, n_bits)
    k = jnp.where(k == 0, 1.0, k)
    wn = w / k
    return jnp.min(wn), jnp.max(wn)


def pwq_quantize(
    w: jax.Array,
    n_bits: int,
    w_l: Optional[jax.Array] = None,
    w_h: Optional[jax.Array] = None,
) -> jax.Array:
    """PwQ fake-quantise ``w`` to ``n_bits`` (paper eqs. 4-6), returns fp32.

    eq. (5):  Ŵ = round((clip(W/k, W_l, W_h) - W_l) * (2^n-1)/(W_h-W_l))
    eq. (6):  Q(W) = Ŵ * (W_h-W_l)/(2^n-1) + W_l        (then re-scaled by k)
    """
    w = w.astype(jnp.float32)
    k = pwq_scale(w, n_bits)
    k = jnp.where(k == 0, 1.0, k)
    if w_l is None or w_h is None:
        d_l, d_h = default_clip_bounds(w, n_bits)
        w_l = d_l if w_l is None else w_l
        w_h = d_h if w_h is None else w_h
    span = jnp.maximum(w_h - w_l, 1e-12)
    levels = 2.0**n_bits - 1.0
    w_hat = jnp.round((jnp.clip(w / k, w_l, w_h) - w_l) * levels / span)
    q = w_hat * span / levels + w_l
    # eq. (6) reconstructs in the normalised domain; undo the eq. (4) scale so
    # Q(W) ≈ W (the paper folds this into the datapath's scale-and-shift unit).
    return (q * k).astype(jnp.float32)


def pwq_error(w: jax.Array, n_bits: int) -> jax.Array:
    """||Q^PwQ(w) - w||_2 — the building block of the sensitivity score."""
    return jnp.linalg.norm(pwq_quantize(w, n_bits) - w)


# ---------------------------------------------------------------------------
# PACT activation quantisation (paper eqs. 7-8)
# ---------------------------------------------------------------------------


def pact(x: jax.Array, alpha: jax.Array) -> jax.Array:
    """Paper eq. (7):  y = 0.5 (|x| - |x - α| + α)  ==  clip(x, 0, α)."""
    return 0.5 * (jnp.abs(x) - jnp.abs(x - alpha) + alpha)


def pact_quantize(x: jax.Array, alpha: jax.Array, n_bits: int) -> jax.Array:
    """Paper eq. (8): quantise the PACT-clipped activation to n_bits (fp32 out)."""
    y = pact(x, alpha)
    levels = 2.0**n_bits - 1.0
    a = jnp.maximum(alpha, 1e-12)
    return jnp.round(y * levels / a) * a / levels


@jax.custom_vjp
def pact_ste(x: jax.Array, alpha: jax.Array, n_bits: int) -> jax.Array:
    return pact_quantize(x, alpha, n_bits)


def _pact_ste_fwd(x, alpha, n_bits):
    return pact_quantize(x, alpha, n_bits), (x, alpha)


def _pact_ste_bwd(res, g):
    x, alpha = res
    # Straight-through for x inside [0, α]; PACT's dα = 1{x >= α} (CACP rule).
    in_range = jnp.logical_and(x >= 0, x <= alpha)
    dx = jnp.where(in_range, g, 0.0)
    dalpha = jnp.sum(jnp.where(x >= alpha, g, 0.0))
    return dx, dalpha.reshape(jnp.shape(alpha)), None


pact_ste.defvjp(_pact_ste_fwd, _pact_ste_bwd)


# ---------------------------------------------------------------------------
# Deployment quantisers (real int8 payloads for the Pallas MAC-bank kernel)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QTensor:
    """An int8 tensor + dequantisation scale (per-channel on ``axis``)."""

    q: jax.Array  # int8 payload
    scale: jax.Array  # fp32, broadcastable against q
    axis: Optional[int] = None  # channel axis the scale follows (None = per-tensor)

    @property
    def shape(self):
        return self.q.shape

    def dequantize(self) -> jax.Array:
        return self.q.astype(jnp.float32) * self.scale


jax.tree_util.register_pytree_node(
    QTensor,
    lambda t: ((t.q, t.scale), t.axis),
    lambda axis, kids: QTensor(kids[0], kids[1], axis),
)


def int8_symmetric(w: jax.Array, axis: Optional[int] = None) -> QTensor:
    """Symmetric int8 quantisation with fp32 per-channel scale (INT8 mode)."""
    w = w.astype(jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        red = tuple(i for i in range(w.ndim) if i != axis)
        amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w / scale), -128, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale, axis=axis)


def int8_symmetric_keep(w: jax.Array, keep_axes: tuple[int, ...]) -> QTensor:
    """Symmetric int8 with scales kept along ``keep_axes`` (e.g. the stacked
    layer axis 0 *and* the output-channel axis -1 for scanned weights)."""
    w = w.astype(jnp.float32)
    keep = {a % w.ndim for a in keep_axes}
    red = tuple(i for i in range(w.ndim) if i not in keep)
    amax = jnp.max(jnp.abs(w), axis=red, keepdims=True) if red else jnp.abs(w)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(w / scale), -128, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale, axis=max(keep))


def fxp8_quantize(w: jax.Array, axis: Optional[int] = None) -> QTensor:
    """FXP8: fixed-point Q1.(7-m) — the scale is constrained to a power of two.

    This is the hardware-friendly mode (dequant = arithmetic shift).  The
    power-of-two constraint loses up to 1 bit of headroom vs INT8, matching
    the paper's observed FXP8 ≲ INT8 accuracy ordering.
    """
    w = w.astype(jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        red = tuple(i for i in range(w.ndim) if i != axis)
        amax = jnp.max(jnp.abs(w), axis=red, keepdims=True)
    amax = jnp.maximum(amax, 1e-12)
    # smallest power-of-two scale s = 2^e with 127*s >= amax
    e = jnp.ceil(jnp.log2(amax / 127.0))
    scale = jnp.exp2(e)
    q = jnp.clip(jnp.round(w / scale), -128, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale, axis=axis)


def bf16_round(x: jax.Array) -> jax.Array:
    """BF16 mode: true round-trip through bfloat16 (mantissa truncation)."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def quantize_tensor(w: jax.Array, precision: Precision, axis: Optional[int] = None) -> jax.Array:
    """Fake-quantise ``w`` under ``precision`` (fp32 in, fp32 out).

    This is the emulation path used to score accuracy (Table II).  INT8 uses
    PwQ (the paper's weight quantiser); FXP8 uses the power-of-two-scale
    variant.
    """
    if precision == Precision.FP32:
        return w.astype(jnp.float32)
    if precision == Precision.BF16:
        return bf16_round(w)
    if precision == Precision.INT8:
        return pwq_quantize(w, 8)
    if precision == Precision.FXP8:
        return fxp8_quantize(w, axis=axis).dequantize()
    raise ValueError(f"unknown precision {precision}")


def activation_quantize(x: jax.Array, precision: Precision, alpha: jax.Array | float = 6.0) -> jax.Array:
    """Quantise activations under ``precision`` (PACT for the 8-bit modes)."""
    if precision == Precision.FP32:
        return x
    if precision == Precision.BF16:
        return bf16_round(x)
    alpha = jnp.asarray(alpha, jnp.float32)
    return pact_ste(x, alpha, 8)


def quantization_mse(w: jax.Array, precision: Precision) -> float:
    """Mean-squared emulation error of a tensor under a precision mode."""
    return float(jnp.mean((quantize_tensor(w, precision) - w) ** 2))
