"""Per-layer precision policy — the software face of the multi-precision datapath.

The POLARON accelerator's "configuration prefetcher interprets layer metadata
and updates execution parameters at runtime"; here that metadata is a
``PrecisionPolicy``: a mapping from parameter-tree paths (glob-style) to
``Precision`` modes.  Model code asks the policy which mode a given matmul
runs in and dispatches to the matching arithmetic:

* FP32  — plain fp32 einsum
* BF16  — bf16 cast (MXU-native)
* INT8  — W8A8 via the Pallas quant_matmul kernel (int32 accumulate)
* FXP8  — as INT8 but power-of-two scales (shift dequant)

Policies serialise to/from plain dicts so they ride along in configs and
checkpoints.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import os
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.quantization import (
    Precision,
    QTensor,
    activation_quantize,
    bf16_round,
    fxp8_quantize,
    int8_symmetric,
    quantize_tensor,
)


@dataclasses.dataclass
class PrecisionPolicy:
    """Glob-pattern → Precision mapping with a default mode."""

    rules: dict[str, Precision] = dataclasses.field(default_factory=dict)
    default: Precision = Precision.FP32

    def precision_for(self, path: str) -> Precision:
        # Most-specific matching pattern wins: longest first, then fewest
        # wildcards (an exact path beats an equal-length glob), then the
        # lexicographically smallest pattern (iteration is over sorted rules
        # with a strict comparison).  Resolution is therefore a function of
        # the rule *set*, never of dict insertion order — two policies built
        # from the same rules in different orders resolve identically
        # (pinned by tests/test_precision_policy.py).
        best = None
        best_key: tuple | None = None
        for pat in sorted(self.rules):
            if fnmatch.fnmatch(path, pat):
                key = (len(pat), -sum(pat.count(c) for c in "*?["))
                if best_key is None or key > best_key:
                    best, best_key = self.rules[pat], key
        return best if best is not None else self.default

    @staticmethod
    def uniform(precision: Precision) -> "PrecisionPolicy":
        return PrecisionPolicy(rules={}, default=precision)

    def to_dict(self) -> dict:
        return {
            "default": self.default.value,
            "rules": {k: v.value for k, v in self.rules.items()},
        }

    @staticmethod
    def from_dict(d: Mapping) -> "PrecisionPolicy":
        return PrecisionPolicy(
            rules={k: Precision(v) for k, v in d["rules"].items()},
            default=Precision(d["default"]),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "PrecisionPolicy":
        return PrecisionPolicy.from_dict(json.loads(s))

    @staticmethod
    def parse(spec: str, *, default: "Precision | str | None" = None) -> "PrecisionPolicy":
        """Build a policy from a CLI-ish spec.

        Accepts, in order of detection: a path to a ``to_json`` file, an
        inline JSON string, or comma-separated ``pattern=mode`` rules
        (``"conv0/w=bf16,dense1/w=fp32"``).  ``default`` overrides the
        default mode for the rule-list form (JSON forms carry their own).
        """
        default = Precision(default) if default is not None else Precision.FP32
        spec = spec.strip()
        if os.path.exists(spec):
            with open(spec) as f:
                return PrecisionPolicy.from_json(f.read())
        if spec.startswith("{"):
            return PrecisionPolicy.from_json(spec)
        rules = {}
        for item in spec.split(","):
            if not item.strip():
                continue
            pat, _, mode = item.partition("=")
            if not _:
                raise ValueError(f"policy rule {item!r} is not 'pattern=mode'")
            rules[pat.strip()] = Precision(mode.strip())
        return PrecisionPolicy(rules=rules, default=default)

    @staticmethod
    def from_sensitivity(scores: Mapping[str, float], **kw) -> "PrecisionPolicy":
        from repro.core.sensitivity import assign_precisions

        return PrecisionPolicy(rules=dict(assign_precisions(scores, **kw)))


def fake_quant_params(params, policy: PrecisionPolicy, prefix: str = ""):
    """Emulation path: fake-quantise every weight tensor per the policy.

    Biases / 1-D tensors ride at fp32 (they live in the extended-precision
    accumulator in hardware).
    """

    def walk(tree, path):
        if isinstance(tree, Mapping):
            return type(tree)({k: walk(v, f"{path}/{k}" if path else k) for k, v in tree.items()})
        if tree.ndim < 2:
            return tree
        return quantize_tensor(tree, policy.precision_for(path))

    return walk(params, prefix)


def policy_einsum(
    spec: str,
    x: jax.Array,
    w: jax.Array,
    precision: Precision,
    *,
    use_kernel: bool = False,
    act_alpha: float = 6.0,
) -> jax.Array:
    """A precision-dispatched einsum — the shared datapath's MAC bank.

    With ``use_kernel=True`` the 8-bit modes run the real Pallas W8A8 kernel
    (only for 2-D matmul specs); otherwise they run the fake-quant emulation
    (exact same numerics the kernel implements, validated in tests).
    """
    if precision == Precision.FP32:
        return jnp.einsum(spec, x, w, precision=jax.lax.Precision.HIGHEST)
    if precision == Precision.BF16:
        return jnp.einsum(
            spec, bf16_round(x).astype(jnp.bfloat16), w.astype(jnp.bfloat16)
        ).astype(jnp.float32)
    # 8-bit modes: quantise weights per output channel, activations per tensor.
    quant = int8_symmetric if precision == Precision.INT8 else fxp8_quantize
    wq: QTensor = quant(w, axis=w.ndim - 1)
    if use_kernel and spec in ("mk,kn->mn", "bk,kn->bn"):
        from repro.kernels import ops as kops

        xq = quant(x, axis=None)
        return kops.quant_matmul(xq.q, wq.q, xq.scale, wq.scale.reshape(1, -1))
    xf = activation_quantize(x, precision, act_alpha)
    return jnp.einsum(spec, xf, wq.dequantize())


__all__ = [
    "Precision",
    "PrecisionPolicy",
    "fake_quant_params",
    "policy_einsum",
]
