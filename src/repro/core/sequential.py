"""Sequential shared-datapath execution — the TPU analogue of POLARON layer reuse.

The paper's accelerator compiles *one* datapath and streams every layer
through it ("reusable sequential layer-execution ... eliminating datapath
replication").  The XLA-native equivalent is ``jax.lax.scan`` over
layer-stacked parameters: one compiled layer body, reused L times, with
weights streamed in per iteration.  Benefits mirror the hardware ones —
program size and compile time drop from O(L) to O(1), and the weights-
stationary discipline is explicit.

Heterogeneous stacks (gemma3's 5-local:1-global groups, zamba2's
mamba/mamba/shared-attn periods) scan over the repeating *pattern* instead:
the scanned body contains one instance of each member of the period.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def stack_layers(layer_params: list[Any]):
    """Stack a list of identical pytrees along a new leading 'layer' axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=0), *layer_params)


def unstack_layers(stacked: Any, n: int) -> list[Any]:
    return [jax.tree_util.tree_map(lambda x, i=i: x[i], stacked) for i in range(n)]


def scan_layers(
    body: Callable[[Any, Any], Any],
    stacked_params: Any,
    x: Any,
    *,
    unroll: int = 1,
    remat: bool = False,
    policy: Callable | None = None,
) -> Any:
    """Run ``x`` through L layers sequentially on the shared compiled body.

    ``body(layer_params, x) -> x`` is the one-layer program (the datapath).
    ``remat=True`` wraps the body in activation rematerialisation — the
    memory/compute knob used by the train-step's checkpoint policy.
    """
    fn = body
    if remat:
        fn = jax.checkpoint(body, policy=policy)

    def step(carry, layer):
        return fn(layer, carry), None

    out, _ = jax.lax.scan(step, x, stacked_params, unroll=unroll)
    return out


def scan_layers_with_aux(
    body: Callable[[Any, Any], tuple[Any, Any]],
    stacked_params: Any,
    x: Any,
    *,
    remat: bool = False,
) -> tuple[Any, Any]:
    """Like scan_layers but the body also emits a per-layer aux output
    (e.g. MoE load-balance stats, per-layer KV cache slices)."""
    fn = jax.checkpoint(body) if remat else body

    def step(carry, layer):
        new_carry, aux = fn(layer, carry)
        return new_carry, aux

    return jax.lax.scan(step, x, stacked_params)
