"""Layer-sensitivity-driven precision assignment (SHIELD8-UAV §III-B, eqs. 2-3).

For each layer ``l`` the paper scores quantisation sensitivity as

    s_{l,sc,k} = ( ||Q(w_l) - w_l|| - ||Q_{sc,k}(w_l) - w_l|| ) * ||∇L_{w_l}|| / n_l
    s_l        = max(s_{l,sc,16}, s_{l,sc,8})                                  (3)

where ``Q`` is the default (8-bit) PwQ quantiser and ``Q_{sc,k}`` the
scale-corrected k-bit variant: the score measures how much error a *better*
quantiser removes, weighted by the loss gradient — layers where extra
precision buys a lot of gradient-weighted error reduction are *sensitive*
and get FP32/BF16; the rest run INT8/FXP8.

The same machinery drives the LM-framework precision policies: embeddings,
routers, and decay/dt parameters naturally score high and stay
high-precision, matmul-heavy FFN/attention projections score low and drop
to int8.
"""
from __future__ import annotations

from typing import Callable, Mapping

import jax
import jax.numpy as jnp

from repro.core.quantization import Precision, pwq_error


def layer_sensitivity(w: jax.Array, grad: jax.Array) -> jax.Array:
    """Paper eqs. (2)-(3) for one layer's weight tensor + loss gradient.

    Eq. (3)'s ``s_{l,sc,8}`` term compares the default 8-bit quantiser with
    itself, so it is **identically zero by construction** — computing it
    would be a third ``pwq_quantize`` pass per layer for a guaranteed-zero
    operand.  The max against it survives as a clamp at 0 (the score can
    never be negative), with no dead quantiser call.
    """
    w = w.astype(jnp.float32)
    n_l = w.size
    gnorm = jnp.linalg.norm(grad.astype(jnp.float32))
    base = pwq_error(w, 8)  # Q^PwQ default = 8-bit
    s_16 = (base - pwq_error(w, 16)) * gnorm / n_l
    return jnp.maximum(s_16, 0.0)  # max(s_16, s_8) with s_8 == 0


def sensitivity_scores(
    params: Mapping[str, jax.Array], grads: Mapping[str, jax.Array]
) -> dict[str, float]:
    """Score every weight tensor in a flat {name: tensor} mapping."""
    out: dict[str, float] = {}
    for name, w in params.items():
        if w.ndim < 2:  # biases/scales: always high precision, not scored
            continue
        out[name] = float(layer_sensitivity(w, grads[name]))
    return out


def assign_precisions(
    scores: Mapping[str, float],
    *,
    high_fraction: float = 0.25,
    low_precision: Precision = Precision.INT8,
    high_precision: Precision = Precision.BF16,
    pinned: Mapping[str, Precision] | None = None,
) -> dict[str, Precision]:
    """Rank layers by sensitivity; the top ``high_fraction`` stay high precision.

    ``pinned`` overrides (e.g. first/last layer pinned FP32, MoE routers
    pinned BF16) are applied after ranking — mirroring the paper's practice
    of keeping boundary layers at full precision.
    """
    if not scores:
        return dict(pinned or {})
    names = sorted(scores, key=lambda n: scores[n], reverse=True)
    n_high = max(1, int(round(high_fraction * len(names)))) if high_fraction > 0 else 0
    policy = {}
    for i, name in enumerate(names):
        policy[name] = high_precision if i < n_high else low_precision
    if pinned:
        policy.update(pinned)
    return policy


def score_with_loss(
    loss_fn: Callable[[Mapping[str, jax.Array]], jax.Array],
    params: Mapping[str, jax.Array],
) -> dict[str, float]:
    """Convenience: compute grads of ``loss_fn`` and score in one shot."""
    grads = jax.grad(loss_fn)(params)
    flat_p = dict(_flatten(params))
    flat_g = dict(_flatten(grads))
    return sensitivity_scores(flat_p, flat_g)


def _flatten(tree, prefix=""):
    if isinstance(tree, Mapping):
        for k, v in tree.items():
            yield from _flatten(v, f"{prefix}{k}/" if prefix or True else k)
    else:
        yield prefix.rstrip("/"), tree
