"""phi4-mini-3.8b — dense 32L d3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
[arXiv:2412.08905; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=200064,
    pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,  # hf config: tie_word_embeddings (200k vocab x2 would be ~4.5B)
    source="arXiv:2412.08905",
    notes=(
        "24 Q heads do not divide the 16-way model axis: head sharding falls "
        "back per the divisibility rule (GSPMD reshards around the softmax). "
        "200k vocab makes the unembed/loss the memory hot spot.  Full "
        "attention -> long_500k skipped."
    ),
)
