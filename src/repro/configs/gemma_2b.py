"""gemma-2b — dense 18L d2048 8H (MQA kv=1) d_ff=16384 vocab=256000, GeGLU,
head_dim=256. [arXiv:2403.08295; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab=256000,
    pattern=("attn",),
    mlp_kind="geglu",
    rope_theta=10_000.0,
    scale_embed=True,
    tie_embeddings=True,
    source="arXiv:2403.08295",
    notes=(
        "MQA (kv_heads=1): KV tensors cannot shard on the model axis; the "
        "divisibility fallback replicates them (documented).  Full attention "
        "-> long_500k skipped."
    ),
)
