"""h2o-danube-3-4b — dense 24L d3840 32H (GQA kv=8) d_ff=10240 vocab=32000,
llama+mistral mix with sliding-window attention. [arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab=32000,
    pattern=("local",),
    window=4096,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2401.16818",
    notes=(
        "All layers sliding-window (mistral-style) -> long_500k RUNS with "
        "ring KV caches of 4k.  head_dim=120 (3840/32) is not MXU-aligned: "
        "padding cost shows up in the roofline compute:model ratio."
    ),
)
