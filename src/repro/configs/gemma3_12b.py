"""gemma3-12b — dense 48L d3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    mlp_kind="geglu",
    rope_theta=1_000_000.0,
    scale_embed=True,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt (family config, 12b dims per card)",
    notes=(
        "5:1 local:global -> long_500k RUNS: local layers use ring KV caches "
        "of window length (1k), only the 8 global layers hold full 512k KV "
        "(sharded over the data axis by the long-context rules)."
    ),
)
