"""internvl2-1b — VLM: InternViT frontend (STUB) + InternLM2-0.5b text
backbone, 24L d896 14H (GQA kv=2) d_ff=4864 vocab=151655.
[arXiv:2404.16821; hf]

Only the transformer backbone is modelled; the vision tower is a stub whose
``input_specs()`` provides 256 precomputed patch embeddings (1024-d, the
InternViT-300M output width) passed through the mlp1-style projector.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    pattern=("attn",),
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    frontend_dim=1024,
    n_patches=256,
    source="arXiv:2404.16821",
    notes=(
        "14 heads / kv=2 don't divide the 16-way model axis -> divisibility "
        "fallback (documented).  Full attention -> long_500k skipped."
    ),
)
