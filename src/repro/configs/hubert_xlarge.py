"""hubert-xlarge — encoder-only audio transformer, 48L d1280 16H d_ff=5120
vocab=504 (cluster targets). [arXiv:2106.07447; unverified]

The conv waveform frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (frontend_dim=512); only the
transformer backbone is modelled.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    pattern=("attn",),
    mlp_kind="gelu",
    causal=False,  # bidirectional encoder
    frontend="audio_frames",
    frontend_dim=512,
    source="arXiv:2106.07447",
    notes=(
        "Encoder-only: no decode step -> decode_32k and long_500k skipped "
        "per the assignment.  prefill_32k = full encoder forward."
    ),
)
