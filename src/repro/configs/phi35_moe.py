"""phi3.5-moe-42b-a6.6b — 32L d4096 32H (GQA kv=8) d_ff=6400, MoE 16e top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab=32064,
    pattern=("moe",),
    n_experts=16,
    top_k=2,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
    notes=(
        "Full attention in every layer -> long_500k skipped (needs "
        "sub-quadratic attention).  Router pinned high-precision by the "
        "sensitivity policy; experts are the prime int8/pruning targets."
    ),
)
