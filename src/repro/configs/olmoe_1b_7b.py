"""olmoe-1b-7b — 16L d2048 16H (kv=16) d_ff=1024, MoE 64e top-8.
[arXiv:2409.02060; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    pattern=("moe",),
    n_experts=64,
    top_k=8,
    mlp_kind="swiglu",
    rope_theta=10_000.0,
    source="arXiv:2409.02060",
    notes=(
        "64-expert fine-grained MoE: dispatch/all-to-all dominates -> the "
        "collective-bound hillclimb candidate.  Full attention -> long_500k "
        "skipped."
    ),
)
