"""rwkv6-7b "Finch" — 32L d4096 attention-free, d_ff=14336 vocab=65536,
data-dependent decay. [arXiv:2404.05892; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab=65536,
    pattern=("rwkv6",),
    rwkv_head_dim=64,
    rwkv_lora_rank=64,
    causal=True,
    source="arXiv:2404.05892",
    notes=(
        "Attention-free: O(1) decode state -> long_500k RUNS trivially (the "
        "500k context costs nothing at decode).  Decay params (double-exp) "
        "are pinned fp32 by the sensitivity policy.  The paper's attention-"
        "oriented pruning retargets to the channel-mix FFN."
    ),
)
