"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_config(name).smoke()`` returns the reduced same-family config used by
CPU smoke tests.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "shield8_cnn",
    "phi35_moe",
    "olmoe_1b_7b",
    "phi4_mini",
    "gemma3_12b",
    "h2o_danube3_4b",
    "gemma_2b",
    "rwkv6_7b",
    "zamba2_7b",
    "hubert_xlarge",
    "internvl2_1b",
]

#: assignment-pool ids -> module names
ALIASES = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "phi4-mini-3.8b": "phi4_mini",
    "gemma3-12b": "gemma3_12b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "gemma-2b": "gemma_2b",
    "rwkv6-7b": "rwkv6_7b",
    "zamba2-7b": "zamba2_7b",
    "hubert-xlarge": "hubert_xlarge",
    "internvl2-1b": "internvl2_1b",
    "shield8-cnn": "shield8_cnn",
}


def get_config(name: str):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def lm_arch_names() -> list[str]:
    return [a for a in ALIASES if a != "shield8-cnn"]
