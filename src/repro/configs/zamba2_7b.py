"""zamba2-7b — 81L hybrid: Mamba2 backbone + shared attention blocks,
d3584 32H (kv=32) d_ff=14336 ssm_state=64. [arXiv:2411.15242; unverified]

81 mamba2 blocks with the *single shared* attention+MLP block interleaved
after every third mamba block (27 invocations of one weight set — the
paper's "one datapath reused across layers" idea realised at the parameter
level).  The shared block rides inside the ``mamba2_shared`` pattern slot so
the layer count stays the published 81 mamba layers.  LoRA per-invocation
adapters of the released model are omitted (documented simplification).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab=32000,
    pattern=("mamba2", "mamba2", "mamba2_shared"),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_kernel=4,
    mlp_kind="gelu",
    rope_theta=10_000.0,
    source="arXiv:2411.15242",
    notes=(
        "Hybrid SSM+attention -> long_500k RUNS: mamba layers carry O(1) "
        "state; the 27 shared-attn invocations each keep a full-length KV "
        "cache (sharded over data axis for long context)."
    ),
)
