"""ArchConfig: the selectable architecture description consumed by
``repro.models.transformer`` and the launcher (``--arch <id>``)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # layer pattern, cycled over the depth; kinds:
    #   "attn"        full-attention block
    #   "local"       sliding-window attention block (cfg.window)
    #   "moe"         attention + MoE FFN block
    #   "mamba2"      Mamba2 SSM block
    #   "rwkv6"       RWKV6 (time-mix + channel-mix) block
    #   "shared_attn" attention block with weights shared across occurrences
    pattern: tuple[str, ...] = ("attn",)
    window: Optional[int] = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # MLP
    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu

    # SSM (mamba2 blocks)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_kernel: int = 4

    # rwkv6 blocks use d_model/64 heads internally
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64

    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    causal: bool = True
    tie_embeddings: bool = False
    scale_embed: bool = False  # gemma-style sqrt(d) embedding scale

    # modality frontend stub: None | "audio_frames" | "vision_patches"
    frontend: Optional[str] = None
    frontend_dim: int = 0
    n_patches: int = 0  # vlm: image patches prepended to the text sequence

    # numerics / execution
    param_dtype: str = "bfloat16"
    act_dtype: str = "bfloat16"
    remat: bool = True
    stack_mode: str = "scan"  # "scan" (sequential shared datapath) | "unroll"
    unroll_attn: bool = False  # unroll KV-chunk loop (dry-run cost accounting)
    sharded_embed_gather: bool = False  # vocab-parallel gather (hillclimb)
    moe_impl: str = "dense"  # "dense" (capacity scatter) | "a2a" (shard_map all-to-all)

    # notes recorded in DESIGN/EXPERIMENTS (applicability, skips)
    notes: str = ""
    source: str = ""

    def __post_init__(self):
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of "
            f"pattern={self.pattern}"
        )

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def attends(self) -> bool:
        return any(k in ("attn", "local", "moe", "shared_attn") for k in self.pattern)

    @property
    def subquadratic(self) -> bool:
        """True if decode over very long context is feasible (no full-attn
        layer with unbounded KV, or SSM/linear-attn)."""
        kinds = set(self.pattern)
        if kinds <= {"mamba2", "rwkv6"}:
            return True
        if "attn" in kinds or "moe" in kinds:
            return False
        # local-only or hybrid-with-attention: local windows are bounded;
        # shared_attn/global layers have unbounded KV but decode cost is
        # linear -> runnable; we treat archs with *any* full-attn layer as
        # runnable iff they also have sub-quadratic layers (gemma3, danube,
        # zamba2 per assignment).
        return True

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        period = len(self.pattern)
        shrink = {
            "n_layers": 2 * period,
            "d_model": 64,
            "n_heads": 4,
            "n_kv_heads": max(1, min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4),
            "head_dim": 16,
            "d_ff": 96,
            "vocab": 256,
            "window": min(self.window, 16) if self.window else None,
            "n_experts": min(self.n_experts, 4) if self.n_experts else 0,
            "top_k": min(self.top_k, 2) if self.top_k else 0,
            "ssm_state": min(self.ssm_state, 8) if self.ssm_state else 0,
            "ssm_head_dim": 8,
            "rwkv_head_dim": 16,
            "rwkv_lora_rank": 8,
            "frontend_dim": 32 if self.frontend else 0,
            "n_patches": 4 if self.n_patches else 0,
            "param_dtype": "float32",
            "act_dtype": "float32",
            "remat": False,
        }
        return self.replace(**shrink)


# model-parameter counting (feeds MODEL_FLOPS = 6*N*D roofline term)
def param_counts(cfg: ArchConfig) -> dict[str, int]:
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    qkv = d * cfg.n_heads * cfg.head_dim + 2 * d * cfg.n_kv_heads * cfg.head_dim
    att = qkv + cfg.n_heads * cfg.head_dim * d
    mlp = {"swiglu": 3, "geglu": 3, "gelu": 2}[cfg.mlp_kind] * d * ff
    per_kind = {}
    counts = {"embed": v * d, "head": 0 if cfg.tie_embeddings else d * v}
    n_shared_attn = 0
    for kind in cfg.pattern:
        if kind in ("attn", "local"):
            per_kind[kind] = att + mlp
        elif kind == "moe":
            per_kind[kind] = att + cfg.n_experts * mlp + d * cfg.n_experts
        elif kind == "shared_attn":
            n_shared_attn += 1
            per_kind[kind] = att + mlp  # counted once below
        elif kind == "mamba2":
            d_in = cfg.ssm_expand * d
            nh = d_in // cfg.ssm_head_dim
            per_kind[kind] = (
                d * (2 * d_in + 2 * cfg.ssm_state + nh) + d_in * d + d_in * cfg.conv_kernel
            )
        elif kind == "rwkv6":
            lora = cfg.rwkv_lora_rank
            # time-mix r/k/v/g/o (5 d^2) + decay/mix LoRAs + channel-mix
            per_kind[kind] = 6 * d * d + 12 * d * lora + 2 * d * ff
    total = counts["embed"] + counts["head"]
    for kind in cfg.pattern:
        if kind == "shared_attn":
            continue
        total += per_kind[kind] * cfg.n_groups
    if n_shared_attn:
        total += per_kind["shared_attn"]  # one shared instance
    active = total
    if cfg.n_experts:
        moe_n = sum(1 for k in cfg.pattern if k == "moe") * cfg.n_groups
        active = total - moe_n * (cfg.n_experts - cfg.top_k) * mlp
    return {"total": total, "active": active}
