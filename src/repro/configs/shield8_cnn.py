"""The paper's own model: the canonical 1D-F-CNN deployment config."""
from repro.models.cnn1d import CNNConfig

CONFIG = CNNConfig()  # M=1096, (64,128,256) channels, flatten 35,072
