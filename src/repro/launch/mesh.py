"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run process sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips/pod ("data", "model"); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)}; the dry-run must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax"
        )
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / examples)."""
    import numpy as np

    devs = np.asarray(jax.devices())
    data = len(devs) // model
    return jax.sharding.Mesh(devs[: data * model].reshape(data, model), ("data", "model"))
