"""Multi-stream continuous-monitoring driver —
``python -m repro.launch.monitor --streams 4 --duration 30``.

Simulates N always-on microphones: each stream is a synthetic acoustic scene
(background clutter with one UAV pass over a random interval), delivered to
the :class:`~repro.serving.engine.MonitorEngine` in uneven real-world-ish
chunks (never aligned to window boundaries).  The engine windows each
stream, scores ready windows in micro-batches on the W8A8 kernel datapath,
and the vectorised temporal tracker emits per-stream detection events that
are printed against the known ground-truth pass.

By default a small detector is trained in-process on the synthetic corpus
(psd features, ~1 min) so the demo produces *real* detections; ``--random``
skips training for a pure plumbing smoke, and ``--feature mfcc20 --trained``
uses the full cached canonical detector artifact (slow in interpret mode).
"""
from __future__ import annotations

import argparse
import time

from repro import hostdevices

# ``--shards k`` on CPU needs k simulated XLA devices, configured *before*
# the first jax import — peek at the raw argv at module-import time.
_shards = hostdevices.shards_from_argv()
if _shards is not None:
    hostdevices.force_host_device_count(_shards)

import jax
import numpy as np

from repro.data import acoustic, features
from repro.models import cnn1d
from repro.serving.engine import MonitorEngine

SMALL_CFG = dict(channels=(4, 8), hidden=8)


def synth_scene(seconds: float, rng: np.random.Generator):
    """One stream's audio: background everywhere except one UAV pass.

    Returns (samples, (t_on, t_off)) with the pass interval in seconds.
    """
    n_win = max(1, int(seconds / features.WINDOW_S))
    if n_win >= 6:
        on = int(rng.integers(1, n_win - 4))
        off = int(min(n_win - 1, on + rng.integers(3, max(4, n_win // 2))))
    else:
        on, off = 0, n_win  # short scene: all UAV
    wins = []
    for i in range(n_win):
        x = acoustic.synth_uav(rng) if on <= i < off else acoustic.synth_background(rng)
        wins.append(acoustic.add_noise_snr(x, float(rng.uniform(8, 20)), rng))
    return np.concatenate(wins), (on * features.WINDOW_S, off * features.WINDOW_S)


def quick_detector(kind: str, cfg: cnn1d.CNNConfig, *, n: int = 240, seed: int = 0):
    """Train a small in-process detector on the synthetic corpus."""
    from repro.training import loop

    ds = acoustic.make_dataset(n, seed=seed, snr_range=(0.0, 20.0))
    feats = features.batch_features(ds.audio, kind)
    n_tr = int(0.8 * n)
    res = loop.train_detector(
        feats[:n_tr], ds.labels[:n_tr], feats[n_tr:], ds.labels[n_tr:],
        cfg, epochs=12, batch=32, patience=12,
    )
    print(f"monitor: quick-trained {kind} detector, val_acc={res.best_val_acc:.2f}")
    return res.params


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--streams", type=int, default=4)
    ap.add_argument("--duration", "--seconds", type=float, default=16.0,
                    dest="duration", help="seconds per stream")
    ap.add_argument("--precision", choices=("int8", "fxp8"), default="int8")
    ap.add_argument("--prune", type=int, default=None, metavar="KEEP",
                    help="bake a structured channel prune into the served "
                         "artifact: keep this many output channels of the "
                         "last conv block (+1 boundary-frame trim, paper "
                         "SIII-C)")
    ap.add_argument("--policy", default=None, metavar="SPEC",
                    help="bake a per-layer precision policy into the served "
                         "artifact: a PrecisionPolicy JSON file/string, or "
                         "inline 'conv0/w=bf16,dense1/w=fp32' rules "
                         "(default mode = --precision)")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard each micro-batch over this many devices "
                         "(sharded-batch dispatch; bitwise-identical results)")
    ap.add_argument("--feature", default=None, choices=sorted(features.FEATURE_DIMS),
                    help="feature set (default: psd, or mfcc20 with --trained)")
    ap.add_argument("--device-features", action="store_true",
                    help="fuse the DSP front-end into the jitted device "
                         "program (engine submits raw windows; no host "
                         "feature extraction on the serving path)")
    ap.add_argument("--slots", type=int, default=8, help="micro-batch slot count")
    ap.add_argument("--adaptive-slots", action="store_true",
                    help="grow/shrink micro-batch blocks over a power-of-two "
                         "slot ladder to fit the ready backlog instead of "
                         "padding dead slots with silence (bitwise-identical "
                         "scores; shapes are pre-jitted)")
    ap.add_argument("--max-streams", type=int, default=None, metavar="N",
                    help="admit at most N distinct streams (first come, "
                         "first served); chunks for later streams are "
                         "refused and counted, never scored")
    ap.add_argument("--workers", type=int, default=None, metavar="N",
                    help="serve through the fault-tolerant fleet supervisor "
                         "with N health-checked workers instead of one "
                         "monolithic engine (bitwise-identical results)")
    ap.add_argument("--faults", default=None, metavar="PLAN.json",
                    help="inject a deterministic fault plan (written by "
                         "python -m repro.serving.faults) through the fleet "
                         "supervisor; implies --workers 2 unless given")
    ap.add_argument("--lanes", choices=("threads",), default=None,
                    help="give each fleet worker a named execution lane "
                         "(thread) so workers' rounds overlap — host "
                         "feature extraction for one worker overlaps device "
                         "scoring for another (bitwise-identical results); "
                         "implies --workers 2 unless given")
    ap.add_argument("--autoscale", action="store_true",
                    help="close the SLO loop: a FleetController watches "
                         "round latency and defer/drop rates and resizes "
                         "the fleet (spawn/retire workers, retune admission "
                         "budgets) against a default target; implies "
                         "--workers 2 unless given")
    ap.add_argument("--state-dir", default=None, metavar="DIR",
                    help="durable crash-safe fleet state: per-worker "
                         "checkpoints + write-ahead chunk journals under "
                         "DIR; rerun with the same DIR (and seed) after a "
                         "SIGKILL to resume bitwise where the fleet left "
                         "off; implies --workers 2 unless given")
    ap.add_argument("--fsync", choices=("always", "interval", "never"),
                    default="interval",
                    help="WAL fsync policy with --state-dir")
    ap.add_argument("--checkpoint-interval", type=int, default=1, metavar="R",
                    help="checkpoint every R rounds with --state-dir (R>1 "
                         "lowers overhead; 1 is the exact-restart setting)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--random", action="store_true",
                    help="random-init weights (plumbing smoke, no real detections)")
    ap.add_argument("--trained", action="store_true",
                    help="use the cached canonical detector artifact (mfcc20)")
    args = ap.parse_args(argv)
    if args.feature is None:
        # --trained serves the cached mfcc20 artifact; an explicit other
        # feature would silently train a full canonical model on cache miss.
        args.feature = "mfcc20" if args.trained else "psd"

    if args.trained:
        from repro.training.detector_artifact import get_detector

        det = get_detector(args.feature)
        params, cfg = det["params"], det["cfg"]
    else:
        cfg = cnn1d.CNNConfig(input_len=features.FEATURE_DIMS[args.feature], **SMALL_CFG)
        if args.random:
            params = cnn1d.init_params(jax.random.PRNGKey(args.seed), cfg)
            print("monitor: --random weights; probabilities are meaningless")
        else:
            params = quick_detector(args.feature, cfg, seed=args.seed)

    # Deploy-time decisions baked into the served artifact (quantise-once).
    prune_spec = None
    if args.prune is not None:
        from repro.core.pruning import plan_prune

        last = len(cfg.channels) - 1
        prune_spec = plan_prune(
            params[f"conv{last}"]["w"], cfg.n_frames,
            keep=args.prune, trim_frames=1,
        )
        print(
            f"monitor: pruned artifact — flatten {prune_spec.flatten_before} "
            f"-> {prune_spec.flatten_after} (-{prune_spec.reduction:.0%})"
        )
    policy = None
    if args.policy is not None:
        from repro.core.precision_policy import PrecisionPolicy

        policy = PrecisionPolicy.parse(args.policy, default=args.precision)
        modes = {
            pat: prec.value for pat, prec in sorted(policy.rules.items())
        }
        print(f"monitor: mixed-precision artifact — {modes}, "
              f"default {policy.default.value}")

    admission = None
    if args.max_streams is not None:
        from repro.serving.batching import AdmissionPolicy

        admission = AdmissionPolicy(max_streams=args.max_streams)
        print(f"monitor: admission cap {args.max_streams} stream(s)")

    fleet = (
        args.workers is not None
        or args.faults is not None
        or args.lanes is not None
        or args.autoscale
        or args.state_dir is not None
    )
    if fleet:
        from repro.serving.engine import SanitizePolicy
        from repro.serving.faults import FaultClock, FaultPlan
        from repro.serving.quantized_params import quantize_params
        from repro.serving.supervisor import FleetSupervisor

        plan = None
        if args.faults is not None:
            with open(args.faults) as fh:
                plan = FaultPlan.from_json(fh.read())
            print(f"monitor: fault plan {args.faults} "
                  f"({len(plan.faults)} fault(s), seed {plan.seed})")
        # The supervisor serves an immutable baked artifact (that is what
        # makes rebuilding a dead worker exact), so bake the deploy-time
        # decisions here instead of inside the engine.
        qp = quantize_params(
            params, cfg, mode=args.precision, prune=prune_spec, policy=policy,
            feature_kind=args.feature if args.device_features else None,
        )
        n_workers = args.workers if args.workers is not None else 2
        sup_kw = dict(
            lanes=args.lanes,
            faults=plan,
            clock=FaultClock() if plan is not None else None,
            fsync=args.fsync,
            checkpoint_interval=args.checkpoint_interval,
            sanitize=SanitizePolicy(),
            feature_kind=args.feature,
            on_device_features=args.device_features,
            batch_slots=args.slots,
            shards=args.shards,
            adaptive_slots=args.adaptive_slots,
            admission=admission,
        )
        engine = None
        if args.state_dir is not None:
            engine = FleetSupervisor.restore_from_dir(
                qp, cfg, state_dir=args.state_dir, **sup_kw
            )
        if engine is not None:
            if engine.n_streams != args.streams:
                raise SystemExit(
                    f"monitor: --streams {args.streams} does not match the "
                    f"state dir ({engine.n_streams} stream(s)); rerun with "
                    f"the original arguments or a fresh --state-dir"
                )
            print(f"monitor: resumed from state dir at round {engine.round}, "
                  f"replayed {engine.replayed_chunks} chunk(s)")
        else:
            engine = FleetSupervisor(
                qp, cfg,
                n_streams=args.streams,
                n_workers=n_workers,
                state_dir=args.state_dir,
                **sup_kw,
            )
        lane_note = (
            "" if args.lanes is None else f", {args.lanes} execution lanes"
        )
        print(f"monitor: fleet supervisor, {engine.n_live_workers} worker(s) "
              f"over {args.streams} stream(s){lane_note}")
    else:
        engine = MonitorEngine(
            params, cfg,
            n_streams=args.streams,
            feature_kind=args.feature,
            on_device_features=args.device_features,
            batch_slots=args.slots,
            precision=args.precision,
            prune=prune_spec,
            policy=policy,
            shards=args.shards,
            adaptive_slots=args.adaptive_slots,
            admission=admission,
        )
    controller = None
    if args.autoscale:
        from repro.serving.controller import FleetController, SLOTarget

        controller = FleetController(
            engine,
            SLOTarget(
                max_defer_rate=0.25,
                max_drop_rate=0.05,
                min_workers=1,
                max_workers=max(2, args.streams // 2),
            ),
            window=8,
            cooldown_rounds=4,
        )
        print("monitor: SLO autoscaler on (defer<=25%, drop<=5%, "
              f"workers 1..{controller.slo.max_workers})")
    if args.adaptive_slots:
        ladder = engine.precompile()
        print(f"monitor: adaptive slots, pre-jitted ladder {list(ladder)}")
    if args.shards:
        print(f"monitor: sharded dispatch over {args.shards} device(s)")
    if args.device_features:
        print(f"monitor: on-device {args.feature} front-end (raw-window dispatch)")

    rng = np.random.default_rng(args.seed + 1)
    scenes, truths = zip(*(synth_scene(args.duration, rng) for _ in range(args.streams)))

    # Real-time-ish delivery: uneven chunks, one engine round per outer
    # tick.  The whole schedule is precomputed (one chunk-size draw per
    # stream per round, finished streams included — the exact rng draw
    # order of the live loop) so that a --state-dir resume can regenerate
    # the identical delivery plan and skip what the restored fleet already
    # embeds: per-stream chunks below the ``pushed_chunks`` delivery
    # cursor, and rounds below the restored round counter.
    schedule = []
    cursors = [0] * args.streams
    while any(c < len(s) for c, s in zip(cursors, scenes)):
        round_pushes = []
        for s in range(args.streams):
            chunk = int(rng.uniform(0.3, 1.7) * features.N_SAMPLES)
            if cursors[s] < len(scenes[s]):
                round_pushes.append((s, cursors[s], cursors[s] + chunk))
                cursors[s] += chunk
        schedule.append(round_pushes)
    done = np.asarray(
        getattr(engine, "pushed_chunks", np.zeros(args.streams, np.int64))
    ).copy()
    skip_rounds = int(getattr(engine, "round", 0))
    ordinals = [0] * args.streams

    t0 = time.perf_counter()
    def show(scored):
        for ws in scored:
            flag = "TRACK" if ws.active else ""
            print(
                f"  stream {ws.stream} t={ws.window_idx * features.WINDOW_S:5.1f}s "
                f"p={ws.p_uav:.2f} ema={ws.smoothed:.2f} {flag}"
            )

    for r, round_pushes in enumerate(schedule):
        for s, lo, hi in round_pushes:
            if ordinals[s] >= done[s]:
                engine.push(s, scenes[s][lo:hi])
            ordinals[s] += 1
        if r < skip_rounds:
            continue  # this round's windows were scored before the restart
        t_round = time.perf_counter()
        show(engine.step())
        if controller is not None:
            controller.step((time.perf_counter() - t_round) * 1e3)
    show(engine.drain())  # backlogged windows: delivery outpaces 1/round
    dt = time.perf_counter() - t0
    events = engine.finalize()

    print(
        f"\nmonitor: {args.streams} stream(s) x {args.duration:.1f}s "
        f"({engine.windows_scored} windows) in {dt:.2f}s "
        f"-> {engine.windows_scored / dt:.1f} windows/s, "
        f"{engine.forward_calls} forward calls, "
        f"{engine.padded_slots} padded slots, "
        f"{engine.dropped_samples} dropped samples"
    )
    if args.adaptive_slots:
        hist = ", ".join(
            f"{k}x{v}" for k, v in sorted(engine.slot_histogram.items())
        )
        print(f"monitor: slot histogram {hist or '(no blocks)'}")
    if args.max_streams is not None:
        refused = engine.refused_chunks
        n_refused = int(np.count_nonzero(refused))
        print(f"monitor: {n_refused} stream(s) refused at admission, "
              f"{int(refused.sum())} chunk(s) dropped")
    if fleet:
        for h in engine.health():
            age = ("never" if h["heartbeat_age_s"] is None
                   else f"{h['heartbeat_age_s']:.3f}s ago")
            state = "alive" if h["alive"] else "RETIRED"
            print(f"  worker {h['worker']}: {state}, streams {h['streams']}, "
                  f"{h['rebuilds']} rebuild(s), last heartbeat {age}")
        if engine.incidents:
            print(f"monitor: survived {len(engine.incidents)} incident(s):")
            for i in engine.incidents:
                print(f"    round {i['round']:3d} worker {i['worker']} "
                      f"[{i['kind']}] {i['detail']}")
        if controller is not None:
            print(f"monitor: autoscaler took {len(controller.actions)} "
                  f"action(s), fleet ended at "
                  f"{engine.n_live_workers} live worker(s)")
            for a in controller.actions:
                m = a["metrics"]
                print(f"    round {a['round']:3d} [{a['kind']}] "
                      f"defer={m['defer_rate']:.2f} drop={m['drop_rate']:.2f} "
                      f"live={m['n_live']}")
        engine.close()
    for s, (evs, (t_on, t_off)) in enumerate(zip(events, truths)):
        print(f"stream {s}: ground truth UAV at {t_on:.1f}-{t_off:.1f}s, {len(evs)} event(s)")
        for e in evs:
            print(
                f"    onset={e.onset_idx * features.WINDOW_S:.1f}s "
                f"offset={e.offset_idx * features.WINDOW_S:.1f}s "
                f"peak={e.peak_score:.2f} mean={e.mean_score:.2f}"
            )
    return events


if __name__ == "__main__":
    main()
