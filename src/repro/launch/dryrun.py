import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) cell
lowers, compiles, and fits — and harvest the roofline terms.

The two lines above MUST stay the first statements in this module (before
any jax import): jax locks the device count at first init.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only-train]
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape train_4k --mesh both

Each cell writes artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, and per-op collective traffic.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ALIASES, get_config, lm_arch_names  # noqa: E402
from repro.configs.base import ArchConfig  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    LONG_CONTEXT_OVERRIDES,
    ShardingRules,
    tree_shardings,
    use_rules,
)
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import SHAPES, ShapeSpec, batch_specs, cache_specs, skip_reason  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.training.lm import TrainSettings, make_decode_step, make_train_step  # noqa: E402
from repro.training.lm import make_encoder_step, make_prefill_step  # noqa: E402
from repro.training.optimizer import Adam  # noqa: E402

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _with_sharding(tree, shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), tree, shardings
    )


def _sharded_tree(rules: ShardingRules, abstract, logical):
    return _with_sharding(abstract, tree_shardings(rules, abstract, logical))


def build_cell(
    cfg: ArchConfig,
    shape: ShapeSpec,
    rules: ShardingRules,
    n_micro: int,
    *,
    quantize: bool = False,
):
    """Returns (fn, example_args) ready for jit().lower(*args)."""
    aparams = T.abstract_params(cfg)
    logical = T.logical_axes(cfg)
    if quantize:
        from repro.models.quantized import abstract_quantized, default_lm_policy

        aparams, logical = abstract_quantized(aparams, logical, default_lm_policy(cfg))
    params = _sharded_tree(rules, aparams, logical)
    bspecs, blogical = batch_specs(cfg, shape)
    batch = {
        k: jax.ShapeDtypeStruct(
            v.shape, v.dtype, sharding=rules.sharding(blogical[k], dims=v.shape)
        )
        for k, v in bspecs.items()
    }
    if shape.kind == "train":
        opt = Adam(lr=1e-4)
        moment = lambda: jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding), params
        )
        from repro.training.optimizer import AdamState

        opt_state = AdamState(
            step=jax.ShapeDtypeStruct((), jnp.int32), mu=moment(), nu=moment()
        )
        step = make_train_step(cfg, opt, TrainSettings(n_micro=n_micro))
        return step, (params, opt_state, batch)
    if shape.kind == "prefill":
        if cfg.is_encoder:
            return make_encoder_step(cfg), (params, batch)
        fn = make_prefill_step(cfg, max_seq=shape.seq_len)
        return fn, (params, batch)
    # decode
    acache, clogical = cache_specs(cfg, shape, model_axis_size=rules.mesh.shape["model"])
    caches = _sharded_tree(rules, acache, clogical)
    fn = make_decode_step(cfg, max_seq=shape.seq_len)
    token = batch["token"]
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params, token, caches, pos)


def _loop_factors(cfg: ArchConfig, shape: ShapeSpec, stack_mode: str, n_micro: int):
    """Trip counts by while-loop nesting depth (see hlo_analysis).

    fit variant (scan):   train  [n_micro, n_groups]
                          prefill [n_groups]
                          decode  [n_groups]
    cost variant (unroll, n_micro=1, unroll_attn): no layer/micro loops left;
    remaining loops (SSM/RWKV time scans) carry no collectives — anything
    found there is reported unattributed with factor 1.
    """
    if stack_mode != "scan":
        return []
    if shape.kind == "train":
        return [float(n_micro), float(cfg.n_groups)]
    return [float(cfg.n_groups)]


def _run_variant(
    cfg: ArchConfig,
    shape: ShapeSpec,
    mesh,
    rules: ShardingRules,
    n_micro: int,
    *,
    quantize: bool = False,
) -> dict:
    t0 = time.time()
    fn, args = build_cell(cfg, shape, rules, n_micro, quantize=quantize)
    # donation: train updates (params, opt_state) in place; decode updates the
    # KV caches in place — exactly as the real launcher runs them.
    donate = {"train": (0, 1), "prefill": (), "decode": (2,)}[shape.kind]
    with mesh, use_rules(rules):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    ma = compiled.memory_analysis()
    print(ma)
    ca = compiled.cost_analysis()
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    factors = _loop_factors(cfg, shape, cfg.stack_mode, n_micro)
    coll = hlo_analysis.collective_bytes(hlo, loop_factors=factors)
    return {
        "stack_mode": cfg.stack_mode,
        "n_micro": n_micro if shape.kind == "train" else None,
        "loop_factors": factors,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops_per_device": ca.get("flops"),
        "bytes_per_device": ca.get("bytes accessed"),
        "collectives": coll,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_est": ma.argument_size_in_bytes
            + ma.temp_size_in_bytes
            + ma.output_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "hlo_chars": len(hlo),
    }


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    n_micro: int = 8,
    variants: tuple[str, ...] = ("fit", "cost"),
    rules_overrides: dict | None = None,
    cfg_overrides: dict | None = None,
    quantize: bool = False,
    tag: str = "",
    out_dir: Path = ARTIFACTS,
    verbose: bool = True,
) -> dict:
    """One (arch x shape x mesh) cell.  Two lowering variants:

    * fit  — stack_mode=scan (+grad-accum): honest *memory* feasibility.
      XLA:CPU's latency-oriented scheduler hoists unrolled/remat blocks, so
      only the scanned form reflects a memory-aware TPU schedule.
    * cost — stack_mode=unroll, n_micro=1, attention chunks unrolled: exact
      HLO FLOP/collective totals (nothing hidden inside while bodies).
    """
    shape = SHAPES[shape_name]
    base_cfg = get_config(arch)
    if cfg_overrides:
        base_cfg = base_cfg.replace(**cfg_overrides)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}
    reason = skip_reason(base_cfg, shape)
    if reason:
        rec.update(status="skip", reason=reason)
        _write(rec, out_dir)
        if verbose:
            print(f"[skip] {arch} x {shape_name} x {mesh_name}: {reason}")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    overrides = dict(rules_overrides or {})
    if shape.name == "long_500k":
        overrides = {**LONG_CONTEXT_OVERRIDES, **overrides}
    rec["n_params"] = T.param_count(base_cfg)
    rec["n_params_active"] = T.active_param_count(base_cfg)
    rec["variants"] = {}
    status = "ok"
    for variant in variants:
        rules = ShardingRules(mesh, overrides)
        if variant == "cost" and base_cfg.n_layers > 60:
            # Deep stacks (zamba2: 81 blocks) make the fully-unrolled compile
            # pathological on XLA:CPU.  Per-layer costs are exactly linear in
            # depth, so compile 1-group and 2-group models and extrapolate:
            # per_group = v2 - v1;  total = (v1 - per_group) + n_groups*per_group.
            try:
                rec["variants"]["cost"] = _extrapolated_cost(
                    base_cfg, shape, mesh, ShardingRules(mesh, overrides),
                    ShardingRules(mesh, overrides), quantize
                )
                if verbose:
                    v = rec["variants"]["cost"]
                    print(
                        f"[ok:cost*] {arch} x {shape_name} x {mesh_name} extrapolated "
                        f"flops/dev={v['flops_per_device']:.3e} "
                        f"coll={v['collectives']['total_bytes']:.3e}B"
                    )
            except Exception as e:  # noqa: BLE001
                status = "error"
                rec["variants"]["cost"] = {
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
            continue
        if variant == "fit":
            # all-f32 lowering: XLA:CPU upcasts bf16 operands to hoisted f32
            # copies (no native bf16 compute), which double-counts memory a
            # TPU would never allocate.  Lowering uniformly in f32 makes
            # every buffer exactly 2x its TPU-bf16 size; the recorded
            # tpu_peak_bytes_est is raw/2 (fp32-native buffers — norms,
            # router, SSM states — are conservatively halved too; they are
            # <1% of the total).
            cfg = base_cfg.replace(
                stack_mode="scan", param_dtype="float32", act_dtype="float32"
            )
            nm = n_micro
        else:
            cfg = base_cfg.replace(stack_mode="unroll", unroll_attn=True, remat=False)
            nm = 1
        try:
            v = _run_variant(cfg, shape, mesh, rules, nm, quantize=quantize)
            v["fallbacks"] = sorted(set(map(tuple, rules.fallbacks)))
            if variant == "fit":
                v["memory"]["tpu_peak_bytes_est"] = v["memory"]["peak_bytes_est"] / 2
            rec["variants"][variant] = v
            if verbose:
                peak = v["memory"].get("tpu_peak_bytes_est", v["memory"]["peak_bytes_est"])
                print(
                    f"[ok:{variant}] {arch} x {shape_name} x {mesh_name} "
                    f"compile={v['compile_s']}s flops/dev={v['flops_per_device']:.3e} "
                    f"coll={v['collectives']['total_bytes']:.3e}B "
                    f"peak={'tpu~' if variant=='fit' else ''}{peak/1e9:.2f}GB"
                )
        except Exception as e:  # noqa: BLE001 — a failing cell is a bug we record
            status = "error"
            rec["variants"][variant] = {
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            if verbose:
                print(f"[ERR:{variant}] {arch} x {shape_name} x {mesh_name}: {e}")
    rec["status"] = status
    _write(rec, out_dir)
    return rec


def _extrapolated_cost(base_cfg, shape, mesh, rules1, rules2, quantize):
    period = len(base_cfg.pattern)
    mk = lambda g: base_cfg.replace(
        n_layers=g * period, stack_mode="unroll", unroll_attn=True, remat=False
    )
    v1 = _run_variant(mk(1), shape, mesh, rules1, 1, quantize=quantize)
    v2 = _run_variant(mk(2), shape, mesh, rules2, 1, quantize=quantize)
    g = base_cfg.n_groups

    def ext(a, b):
        if a is None or b is None:
            return None
        per = b - a
        return (a - per) + g * per

    coll_ops = {
        op: ext(v1["collectives"]["per_op_bytes"].get(op, 0.0), v2["collectives"]["per_op_bytes"].get(op, 0.0))
        for op in set(v1["collectives"]["per_op_bytes"]) | set(v2["collectives"]["per_op_bytes"])
    }
    return {
        "stack_mode": "unroll(extrapolated 1->2 groups)",
        "n_micro": 1 if shape.kind == "train" else None,
        "extrapolated": True,
        "lower_s": v1["lower_s"] + v2["lower_s"],
        "compile_s": v1["compile_s"] + v2["compile_s"],
        "flops_per_device": ext(v1["flops_per_device"], v2["flops_per_device"]),
        "bytes_per_device": ext(v1["bytes_per_device"], v2["bytes_per_device"]),
        "collectives": {
            "per_op_bytes": coll_ops,
            "counts": v2["collectives"]["counts"],
            "total_bytes": float(sum(v for v in coll_ops.values() if v)),
            "tpu_adjusted_bytes": ext(
                v1["collectives"].get("tpu_adjusted_bytes", 0.0),
                v2["collectives"].get("tpu_adjusted_bytes", 0.0),
            ),
        },
        "memory": v2["memory"],  # not meaningful for cost; fit variant governs
        "hlo_chars": v2["hlo_chars"],
        "fallbacks": [],
    }


def _write(rec: dict, out_dir: Path):
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = out_dir / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variants", default="fit,cost")
    ap.add_argument("--n-micro", type=int, default=8)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args()

    archs = lm_arch_names() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(
                    run_cell(
                        arch,
                        shape,
                        mp,
                        n_micro=args.n_micro,
                        variants=tuple(args.variants.split(",")),
                        tag=args.tag,
                        out_dir=Path(args.out),
                    )
                )
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run summary: {n_ok} ok, {n_skip} skip, {n_err} error ===")
    if n_err:
        for r in results:
            if r["status"] == "error":
                errs = {k: v.get("error") for k, v in r.get("variants", {}).items() if "error" in v}
                print(f"  FAILED {r['arch']} x {r['shape']} x {r['mesh']}: {errs}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
