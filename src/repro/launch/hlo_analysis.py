"""Post-SPMD HLO analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` gives FLOPs and bytes but not collective traffic;
we parse the optimized (post-partitioning) HLO text and sum the byte sizes
of every collective op.  Shapes in the partitioned module are *per-device*,
so the summed figure is per-chip traffic; the roofline's collective term is
``per_chip_bytes / link_bw`` (documented convention: each chip moves its
share through one ICI link — conservative vs a 3D-torus's multiple links).

Per-op convention: max(operand bytes, result bytes) — covers all-gather
(result larger) and reduce-scatter (operand larger) symmetrically; a ring
all-reduce moves ~2x its operand, accounted with an op-specific factor.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# wire-traffic multiplier per op (ring algorithms)
_WIRE_FACTOR = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(?P<out>\([^=]*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\(",
)


def shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_bytes_by_dtype(text: str) -> dict[str, int]:
    out: dict[str, int] = defaultdict(int)
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[dt] += n * _DTYPE_BYTES[dt]
    return dict(out)


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_WHILE_BODY_RE = re.compile(r"while\(.*?body=%?([\w.\-]+)", re.DOTALL)
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")


def _split_computations(hlo_text: str) -> dict[str, str]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
        elif line.strip() == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def _collectives_in(text: str) -> tuple[dict, dict, dict]:
    out_bytes: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    by_dtype: dict[str, float] = defaultdict(float)
    for m in _LINE_RE.finditer(text):
        op = m.group("op")
        line_end = text.find("\n", m.end())
        args = text[m.end() : line_end if line_end > 0 else m.end() + 2000]
        paren = args.split("),")[0]
        in_b = shape_bytes(paren)
        out_b = shape_bytes(m.group("out"))
        eff = max(in_b, out_b) * _WIRE_FACTOR[op]
        out_bytes[op] += eff
        counts[op] += 1
        bigger = m.group("out") if out_b >= in_b else paren
        for dt, b in shape_bytes_by_dtype(bigger).items():
            by_dtype[dt] += b * _WIRE_FACTOR[op]
    return out_bytes, counts, by_dtype


def _loop_depths(hlo_text: str, comps: dict[str, str]) -> dict[str, int]:
    """Depth of every computation in the while-loop nesting (entry = 0)."""
    # edges: computation -> called computations; while bodies add +1 depth
    body_edges: dict[str, set[str]] = defaultdict(set)
    call_edges: dict[str, set[str]] = defaultdict(set)
    for name, text in comps.items():
        for line in text.splitlines():
            if " while(" in line or "= while(" in line or re.search(r"\bwhile\(", line):
                for b in re.findall(r"body=%?([\w.\-]+)", line):
                    body_edges[name].add(b)
                for c in re.findall(r"condition=%?([\w.\-]+)", line):
                    call_edges[name].add(c)
            else:
                for c in _CALL_RE.findall(line):
                    call_edges[name].add(c)
    depths: dict[str, int] = {}
    roots = set(comps) - {c for s in body_edges.values() for c in s} - {
        c for s in call_edges.values() for c in s
    }
    stack = [(r, 0) for r in roots] or [(max(comps, default=""), 0)]
    while stack:
        name, d = stack.pop()
        if name not in comps or depths.get(name, -1) >= d:
            continue
        depths[name] = d
        for b in body_edges.get(name, ()):
            stack.append((b, d + 1))
        for c in call_edges.get(name, ()):
            stack.append((c, d))
    return depths


def collective_bytes(hlo_text: str, loop_factors: list[float] | None = None) -> dict:
    """Per-chip collective traffic by op type (bytes), plus op counts.

    ``loop_factors``: trip counts by while-loop nesting depth.  Collectives
    inside a depth-k while body execute prod(loop_factors[:k]) times but
    appear once in the HLO text; they are scaled accordingly.  (The dry-run
    passes e.g. [n_micro, n_groups] for a scanned train step.)  Depths beyond
    the list get factor 1 with a 'truncated' note.
    """
    loop_factors = loop_factors or []
    comps = _split_computations(hlo_text)
    depths = _loop_depths(hlo_text, comps)
    out_bytes: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    dtype_bytes: dict[str, float] = defaultdict(float)
    per_comp = {}
    for name, text in comps.items():
        ob, ct, bd = _collectives_in(text)
        if not ob:
            continue
        d = depths.get(name, 0)
        mult = 1.0
        for f in loop_factors[:d]:
            mult *= f
        per_comp[name] = {"depth": d, "mult": mult, "bytes": float(sum(ob.values()))}
        for op, v in ob.items():
            out_bytes[op] += v * mult
        for op, v in ct.items():
            counts[op] += v
        for dt, v in bd.items():
            dtype_bytes[dt] += v * mult
    # XLA:CPU upcasts bf16 compute to f32; on TPU those payloads stay bf16.
    # tpu_adjusted halves f32 traffic (keeps s8/s32 as-is) as the bf16-wire
    # estimate — raw totals remain the primary (conservative) figure.
    adjusted = sum(v * (0.5 if dt in ("f32", "f64") else 1.0) for dt, v in dtype_bytes.items())
    return {
        "per_op_bytes": dict(out_bytes),
        "counts": dict(counts),
        "per_computation": per_comp,
        "by_dtype": dict(dtype_bytes),
        "total_bytes": float(sum(out_bytes.values())),
        "tpu_adjusted_bytes": float(adjusted),
    }
