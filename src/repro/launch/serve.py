"""LM serving driver: batched prefill + decode with a continuous-batching
queue — ``python -m repro.launch.serve --arch <id> --smoke``.

Production-shaped: requests enter a queue, are batched to the compiled batch
size (padding slots carry a dead request), prefilled in one shot, then
decoded step-locked with per-slot stop handling.  On the dry-run meshes the
same prefill/decode programs are exactly what launch/dryrun.py lowers for
the prefill_32k / decode_32k / long_500k cells.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import ShardingRules, use_rules
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: Optional[np.ndarray] = None


class BatchedServer:
    """Fixed-slot continuous batching server over prefill/decode programs."""

    def __init__(self, cfg, params, *, batch_slots: int = 4, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b: T.forward_with_cache(p, b, cfg, max_seq)
        )
        self._decode = jax.jit(
            lambda p, tok, c, pos: T.decode_step(p, tok, c, pos, cfg, max_seq),
            donate_argnums=(2,),
        )

    def serve(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        done: list[Request] = []
        queue = list(requests)
        while queue:
            batch = queue[: self.slots]
            queue = queue[self.slots :]
            batch = batch + [  # pad dead slots
                Request(rid=-1, prompt=batch[0].prompt, max_new=0)
                for _ in range(self.slots - len(batch))
            ]
            done.extend(r for r in self._serve_batch(batch, greedy) if r.rid >= 0)
        return done

    def _serve_batch(self, batch: list[Request], greedy: bool) -> list[Request]:
        s = max(len(r.prompt) for r in batch)
        toks = np.zeros((len(batch), s), np.int32)
        for i, r in enumerate(batch):
            toks[i, s - len(r.prompt) :] = r.prompt  # left-pad
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        outs = [[] for _ in batch]
        cur = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new for r in batch)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new:
                    outs[i].append(int(cur[i, 0]))
            pos = jnp.asarray(s + step, jnp.int32)
            logits, caches = self._decode(self.params, cur, caches, pos)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for r, o in zip(batch, outs):
            r.out = np.asarray(o[: r.max_new], np.int32)
        return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    rules = ShardingRules(mesh)
    with mesh, use_rules(rules):
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        server = BatchedServer(cfg, params, batch_slots=args.slots)
        rng = np.random.default_rng(0)
        reqs = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24)).astype(np.int32), max_new=args.max_new)
            for i in range(args.requests)
        ]
        t0 = time.time()
        done = server.serve(reqs)
        dt = time.time() - t0
        n_tok = sum(len(r.out) for r in done)
        print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
        for r in done:
            print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {list(r.out)}")
    return done


if __name__ == "__main__":
    main()
