"""LM serving driver: batched prefill + decode with a continuous-batching
queue — ``python -m repro.launch.serve --arch <id> --smoke``.

Production-shaped: requests enter the shared
:class:`~repro.serving.batching.DispatchCore` queue (the same core the
detector fleet's ``MonitorEngine`` runs on), are batched to a compiled slot
count (padding slots carry a dead request, or — with ``adaptive_slots=True``
— the block shrinks over the power-of-two ladder to fit the tail of the
queue), prefilled in one shot, then decoded step-locked with per-slot stop
handling.  On the dry-run meshes the same prefill/decode programs are
exactly what launch/dryrun.py lowers for the prefill_32k / decode_32k /
long_500k cells.

Unlike the detector datapath, LM decode is *not* batch-composition
independent (prompts are left-padded to the batch's longest prompt with no
pad masking), so the core is run with synchronous submit and no cross-batch
bitwise claim — what it shares is the queue/slot/commit machinery, not the
parity guarantee.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import ShardingRules, use_rules
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serving.batching import DispatchCore, SlotPolicy


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int = 16
    out: Optional[np.ndarray] = None


class BatchedServer:
    """Continuous-batching server over prefill/decode programs, running on
    the shared :class:`~repro.serving.batching.DispatchCore`.

    ``batch_slots`` fixes the compiled batch size; dead slots in a partial
    final batch carry a dead request (``rid=-1``) exactly as before.
    ``adaptive_slots=True`` instead lets the slot policy shrink the final
    blocks over a power-of-two ladder, trading a few extra compiled batch
    shapes for not decoding dead slots.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        batch_slots: int = 4,
        max_seq: int = 256,
        adaptive_slots: bool = False,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        self._prefill = jax.jit(
            lambda p, b: T.forward_with_cache(p, b, cfg, max_seq)
        )
        self._decode = jax.jit(
            lambda p, tok, c, pos: T.decode_step(p, tok, c, pos, cfg, max_seq),
            donate_argnums=(2,),
        )
        self._greedy = True  # per-serve() decode mode, read by _submit
        # Synchronous program (prefill+decode completes before the next
        # block is packed), so no harvest stage and a single in-flight slot.
        self._core = DispatchCore(
            submit=self._submit,
            harvest=None,
            slot_policy=SlotPolicy(batch_slots, adaptive=adaptive_slots),
            inflight=1,
        )

    @property
    def slot_histogram(self) -> dict[int, int]:
        """Blocks dispatched per slot shape (adaptive observability)."""
        return dict(self._core.slot_histogram)

    def _submit(self, live: list[Request], slots: int) -> list[Request]:
        batch = list(live) + [  # pad dead slots
            Request(rid=-1, prompt=live[0].prompt, max_new=0)
            for _ in range(slots - len(live))
        ]
        return self._serve_batch(batch, self._greedy)[: len(live)]

    def serve(self, requests: list[Request], greedy: bool = True) -> list[Request]:
        """Serve the requests in arrival order; returns them completed."""
        self._greedy = greedy
        self._core.enqueue(requests)
        return self._core.drain()

    def _serve_batch(self, batch: list[Request], greedy: bool) -> list[Request]:
        s = max(len(r.prompt) for r in batch)
        toks = np.zeros((len(batch), s), np.int32)
        for i, r in enumerate(batch):
            toks[i, s - len(r.prompt) :] = r.prompt  # left-pad
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        outs = [[] for _ in batch]
        cur = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new for r in batch)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if step < r.max_new:
                    outs[i].append(int(cur[i, 0]))
            pos = jnp.asarray(s + step, jnp.int32)
            logits, caches = self._decode(self.params, cur, caches, pos)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for r, o in zip(batch, outs):
            r.out = np.asarray(o[: r.max_new], np.int32)
        return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument(
        "--adaptive-slots", action="store_true",
        help="shrink final blocks over the slot ladder instead of padding "
             "dead requests",
    )
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    rules = ShardingRules(mesh)
    with mesh, use_rules(rules):
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        server = BatchedServer(
            cfg, params, batch_slots=args.slots,
            adaptive_slots=args.adaptive_slots,
        )
        rng = np.random.default_rng(0)
        reqs = [
            Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(4, 24)).astype(np.int32), max_new=args.max_new)
            for i in range(args.requests)
        ]
        t0 = time.time()
        done = server.serve(reqs)
        dt = time.time() - t0
        n_tok = sum(len(r.out) for r in done)
        print(f"served {len(done)} requests, {n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
        print(f"slot histogram: {server.slot_histogram}")
        for r in done:
            print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {list(r.out)}")
    return done


if __name__ == "__main__":
    main()
