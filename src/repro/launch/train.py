"""End-to-end LM training driver: ``python -m repro.launch.train --arch <id>``.

Production-shaped loop: mesh + logical-rules sharding, grad-accumulation
train step, async prefetching loader, checkpoint/restart (elastic across
mesh changes), preemption hook, straggler mitigation, and optional int8
gradient compression on the pod axis.

Straggler policy: on a real fleet the per-step all-reduce synchronises
everyone, so a straggling host shows up as step-time skew.  The loop tracks
a robust step-time EMA; steps slower than ``straggler_factor`` x EMA are
logged and counted, and after ``max_straggler_steps`` consecutive hits the
driver checkpoints and exits with code 75 (EX_TEMPFAIL) so the scheduler can
reschedule/reshape the job — the standard recover-by-restart path (elastic
restore then continues on whatever mesh the new allocation provides).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import PrefetchingLoader, synthetic_lm_batches
from repro.distributed.sharding import ShardingRules, tree_shardings, use_rules
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T
from repro.training.checkpoint import CheckpointManager
from repro.training.lm import TrainSettings, make_train_step
from repro.training.optimizer import Adam, cosine_warmup_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--scale", type=float, default=1.0, help="width multiplier on the smoke config")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compress-pod-grads", action="store_true")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--max-straggler-steps", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
        if args.scale != 1.0:
            s = args.scale
            cfg = cfg.replace(
                d_model=int(cfg.d_model * s),
                d_ff=int(cfg.d_ff * s),
                head_dim=int(cfg.head_dim * s),
                vocab=max(cfg.vocab, 1024),
            )
    mesh = (
        make_production_mesh(multi_pod=args.multi_pod)
        if args.production_mesh
        else make_host_mesh()
    )
    rules = ShardingRules(mesh)
    print(f"arch={cfg.name} params~{T.param_count(cfg)/1e6:.1f}M mesh={dict(mesh.shape)}")

    opt = Adam(lr=cosine_warmup_schedule(args.lr, warmup=args.warmup, total=args.steps))
    step_fn = make_train_step(
        cfg, opt, TrainSettings(n_micro=args.n_micro, compress_pod_grads=args.compress_pod_grads)
    )

    with mesh, use_rules(rules):
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        pshard = tree_shardings(rules, T.abstract_params(cfg), T.logical_axes(cfg))
        params = jax.tree_util.tree_map(lambda x, s: jax.device_put(x, s), params, pshard)
        opt_state = opt.init(params)

        ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.name.replace("/", "_"), save_every=args.ckpt_every)
        start_step, (params, opt_state) = ckpt.maybe_restore((params, opt_state))
        state_ref = {"step": start_step, "params": params, "opt": opt_state}
        ckpt.install_preemption_hook(lambda: (state_ref["step"], (state_ref["params"], state_ref["opt"])))

        bshard = rules.sharding(("batch", "seq"), dims=(args.batch, args.seq))
        loader = PrefetchingLoader(
            synthetic_lm_batches(cfg.vocab, args.batch, args.seq, n_steps=args.steps - start_step),
            sharding=bshard,
        )
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))

        ema = None
        stragglers = 0
        losses = []
        t_start = time.time()
        for i, batch in enumerate(loader):
            step = start_step + i
            t0 = time.time()
            params, opt_state, metrics = jstep(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            state_ref.update(step=step + 1, params=params, opt=opt_state)
            losses.append(loss)
            ema = dt if ema is None else (0.9 * ema + 0.1 * dt) if i > 2 else dt
            if i > 5 and dt > args.straggler_factor * ema:
                stragglers += 1
                print(f"[straggler] step {step}: {dt:.2f}s vs ema {ema:.2f}s ({stragglers})")
                if stragglers >= args.max_straggler_steps:
                    ckpt.save(step + 1, (params, opt_state), extra={"straggler_exit": True})
                    print("[straggler] persistent skew -> checkpoint + EX_TEMPFAIL")
                    raise SystemExit(75)
            else:
                stragglers = 0
            if ckpt.should_save(step + 1):
                ckpt.save(step + 1, (params, opt_state))
            if step % args.log_every == 0:
                print(f"step {step}: loss={loss:.4f} ({dt:.2f}s/step)")
        n = len(losses)
        print(
            f"done: {n} steps in {time.time()-t_start:.1f}s; "
            f"loss {losses[0]:.4f} -> {np.mean(losses[-10:]):.4f}"
        )
        ckpt.save(start_step + n, (params, opt_state))
        loader.close()
    return losses


if __name__ == "__main__":
    main()
