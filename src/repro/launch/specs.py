"""Input ShapeDtypeStruct stand-ins per (architecture x input shape) cell.

Shapes from the assignment:
    train_4k      seq_len=4,096  global_batch=256   (train_step)
    prefill_32k   seq_len=32,768 global_batch=32    (prefill serve step)
    decode_32k    seq_len=32,768 global_batch=128   (decode serve step)
    long_500k     seq_len=524,288 global_batch=1    (long-context decode)

Skips (recorded in EXPERIMENTS.md):
    * encoder-only archs (hubert) have no decode step -> decode_32k /
      long_500k skipped;
    * pure full-attention archs skip long_500k (needs sub-quadratic
      attention); SSM / hybrid / SWA archs run it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def skip_reason(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    if cfg.is_encoder and shape.kind == "decode":
        return "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "pure full-attention arch: long_500k needs sub-quadratic attention"
    return None


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> tuple[dict, dict]:
    """Returns (tree of ShapeDtypeStruct, tree of logical axis tuples) for
    the *data* inputs of the step (params/caches handled separately)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_frames":
            specs = {"frames": _sds((b, s, cfg.frontend_dim), jnp.float32)}
            logical = {"frames": ("batch", "seq", "frontend")}
        elif cfg.frontend == "vision_patches":
            st = s - cfg.n_patches
            specs = {
                "tokens": _sds((b, st), i32),
                "patches": _sds((b, cfg.n_patches, cfg.frontend_dim), jnp.float32),
            }
            logical = {
                "tokens": ("batch", "seq"),
                "patches": ("batch", "seq", "frontend"),
            }
        else:
            specs = {"tokens": _sds((b, s), i32)}
            logical = {"tokens": ("batch", "seq")}
        if shape.kind == "train":
            lt = specs.get("tokens")
            lbl_len = lt.shape[1] if lt is not None else s
            specs["labels"] = _sds((b, lbl_len), i32)
            logical["labels"] = ("batch", "seq")
        return specs, logical
    # decode
    specs = {
        "token": _sds((b, 1), i32),
        "pos": _sds((), i32),
    }
    logical = {"token": ("decode_batch", None), "pos": ()}
    return specs, logical


def cache_specs(cfg: ArchConfig, shape: ShapeSpec, model_axis_size: int = 16):
    caches = T.cache_shapes(cfg, shape.global_batch, shape.seq_len)
    seq_axis = "kv_seq" if cfg.n_kv_heads % model_axis_size == 0 else "kv_seq_model"
    logical = T.cache_logical_axes(cfg, seq_axis=seq_axis)
    return caches, logical
