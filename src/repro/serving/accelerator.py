"""Deployed-datapath inference: the whole 1D-F-CNN through the Pallas kernels.

This is the software twin of the POLARON accelerator's execution: every
convolution and dense layer runs on the W8A8 kernels — conv on the fused
in-kernel-im2col conv kernel, dense on quant_matmul — with bias+ReLU fused
into each layer's dequant epilogue, and the classifier head finishes with
the CORDIC softmax.  Against fp32 JAX inference this bounds the
*accelerator's* end-to-end numerical deviation — the sign-off artifact an
RTL team would diff against.

Weights come from a :class:`~repro.serving.quantized_params.QuantizedParams`
artifact (baked once at deploy time); only the per-request activations are
quantised per call.  The whole forward is one ``jax.jit`` program,
interpret-mode on CPU and compiled on TPU via the ``interpret=None``
autodetect.

The artifact's static metadata drives per-layer dispatch (the POLARON
"configuration prefetcher interprets layer metadata" idea): each layer's
``conv_modes``/``dense_modes`` tag routes it to the matching datapath —
fused W8A8 kernels for int8/fxp8, a bf16-operand/fp32-accumulate einsum for
BF16, plain fp32 otherwise — and a pruned artifact's ``keep_frames`` applies
the boundary-frame trim between the last pool and the flatten.  Every
datapath keeps each batch row's result independent of its co-batch (the
8-bit modes via per-sample activation scales, the float modes trivially), so
the streaming == batched == sharded bitwise guarantee holds for pruned and
mixed-precision artifacts unchanged (pinned by
``tests/test_pruned_serving_conformance.py``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.data import features_jax
from repro.distributed.sharding import STREAM_AXIS
from repro.kernels import ops
from repro.kernels.backend import resolve_interpret
from repro.models.cnn1d import CNNConfig, _maxpool2
from repro.serving.quantized_params import QuantizedParams, quantize_params


def _quantizer(layer_mode: str):
    from repro.core.quantization import fxp8_quantize, int8_symmetric

    return fxp8_quantize if layer_mode == "fxp8" else int8_symmetric


def _conv1d_float(x: jax.Array, w: jax.Array) -> jax.Array:
    """'same' 1D conv for the float layer modes; accumulates in fp32 even for
    bf16 operands (the MXU's bf16-in/fp32-accumulate discipline)."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("interpret", "per_sample_acts", "raw_windows")
)
def _forward_quantized(
    qp: QuantizedParams,
    x: jax.Array,
    interpret: bool,
    per_sample_acts: bool,
    raw_windows: bool = False,
) -> jax.Array:
    # Fused DSP front-end: with raw_windows the program starts at the
    # microphone samples — feature extraction runs in-graph (per-row, see
    # features_jax) ahead of the quantised datapath, so host feature work
    # never serializes with device dispatch.
    if raw_windows:
        x = features_jax.feature_rows(x, qp.feature_kind)
    # Per-sample (row-wise) activation scales are the default: with one
    # per-tensor scale, a single loud sample crushes the quantisation
    # resolution of every co-batched quiet one — exactly the failure mode
    # micro-batching windows from N independent streams triggers.  Row-wise
    # scales also make every row's result independent of its co-batch, which
    # is what the streaming engine's bitwise-parity guarantee rests on.  The
    # float layer modes preserve the same row independence for free (conv and
    # matmul rows never mix).
    act_axis = 0 if per_sample_acts else None
    bsz = x.shape[0]
    conv_modes, dense_modes = qp.layer_modes
    h = x[:, :, None].astype(jnp.float32)
    for layer, lmode in zip(qp.convs, conv_modes):
        if lmode in ("int8", "fxp8"):
            hq = _quantizer(lmode)(h, axis=act_axis)  # per-request act quant
            h = ops.conv1d_fused_q(
                hq.q,
                layer["w"].q,
                hq.scale.reshape(-1, 1) if per_sample_acts else hq.scale,
                layer["w"].scale,
                layer["b"],
                act="relu",  # CORDIC ReLU == max(v, 0): fused into the epilogue
                interpret=interpret,
            )
        else:
            hin = h.astype(jnp.bfloat16) if lmode == "bf16" else h
            h = jnp.maximum(_conv1d_float(hin, layer["w"]) + layer["b"], 0.0)
        h = _maxpool2(h)
    if qp.keep_frames is not None:
        h = h[:, : qp.keep_frames, :]  # pruned artifact: boundary-frame trim
    h = h.reshape(bsz, -1)
    for i, (layer, lmode) in enumerate(zip(qp.denses, dense_modes)):
        act = "relu" if i < len(qp.denses) - 1 else None
        if lmode in ("int8", "fxp8"):
            hq = _quantizer(lmode)(h, axis=act_axis)
            h = ops.quant_matmul(
                hq.q,
                layer["w"].q,
                hq.scale.reshape(bsz if per_sample_acts else 1, 1),
                layer["w"].scale.reshape(1, -1),
                layer["b"],
                act=act,
                interpret=interpret,
            )
        else:
            if lmode == "bf16":
                h = jnp.einsum(
                    "bk,kn->bn",
                    h.astype(jnp.bfloat16),
                    layer["w"],
                    preferred_element_type=jnp.float32,
                )
            else:
                h = jnp.einsum(
                    "bk,kn->bn", h, layer["w"],
                    precision=jax.lax.Precision.HIGHEST,
                )
            h = h + layer["b"]
            if act == "relu":
                h = jnp.maximum(h, 0.0)
    return ops.cordic_softmax(h, interpret=interpret)


def _check_raw_windows(qp: QuantizedParams, x: jax.Array, feature_kind: str | None):
    """Validate the raw-window contract before tracing (clear errors beat
    shape mismatches inside jit)."""
    if qp.feature_kind is None:
        raise ValueError(
            "raw_windows=True needs an artifact with a baked feature kind; "
            "re-bake with quantize_params(..., feature_kind=...) or pass "
            "feature_kind= alongside the fp32 checkpoint"
        )
    if feature_kind is not None and feature_kind != qp.feature_kind:
        raise ValueError(
            f"artifact was baked for feature kind {qp.feature_kind!r}, "
            f"got feature_kind={feature_kind!r}"
        )
    if x.ndim != 2 or x.shape[1] != features_jax.N_SAMPLES:
        raise ValueError(
            f"raw_windows=True expects (B, {features_jax.N_SAMPLES}) raw "
            f"0.8 s windows, got {tuple(x.shape)}"
        )


def accelerator_forward(
    params: dict | QuantizedParams,
    x: jax.Array,
    cfg: CNNConfig,
    *,
    fxp: bool = False,
    interpret: bool | None = None,
    per_sample_acts: bool = True,
    raw_windows: bool = False,
    feature_kind: str | None = None,
) -> jax.Array:
    """x: (B, M) features -> (B, n_classes) class probabilities, computed
    entirely on the kernel datapath.

    Pass a :class:`QuantizedParams` artifact to serve from the weight cache
    (zero weight-quantisation work per call) — pruned and mixed-precision
    artifacts dispatch per layer off the artifact's tags.  A raw fp32
    ``params`` dict is quantised on the fly (``fxp`` selects the mode) for
    one-off sign-offs.

    ``raw_windows=True`` accepts raw (B, 12800) 0.8 s audio windows instead
    of features: the artifact's baked ``feature_kind`` front-end runs
    in-graph as the first stage of the same jitted program (windows -> probs
    end to end).  Feature bits are per-row by construction, so every parity
    guarantee (streaming == batched == sharded) carries over; note the JAX
    front-end is the float32 twin of the numpy oracle — tolerance-bounded,
    not bitwise, against host-extracted features.

    ``per_sample_acts`` (default) quantises activations with one scale per
    batch row; ``False`` restores the legacy per-tensor scale (kept as the
    A/B surface for the mixed-loudness regression tests).
    """
    if isinstance(params, QuantizedParams):
        qp = params
    else:
        qp = quantize_params(
            params, cfg, mode="fxp8" if fxp else "int8", feature_kind=feature_kind
        )
    if raw_windows:
        _check_raw_windows(qp, x, feature_kind)
    return _forward_quantized(
        qp, x, resolve_interpret(interpret), per_sample_acts, raw_windows
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "mesh", "axis_name", "interpret", "per_sample_acts", "raw_windows"
    ),
)
def _forward_sharded(
    qp: QuantizedParams,
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    interpret: bool,
    per_sample_acts: bool,
    raw_windows: bool = False,
) -> jax.Array:
    # raw_windows shards the *windows*: each device runs the DSP front-end
    # shard-local on its own rows (per-row feature bits make this exactly the
    # unsharded computation), then its slice of the quantised datapath.
    fwd = functools.partial(
        _forward_quantized,
        interpret=interpret,
        per_sample_acts=per_sample_acts,
        raw_windows=raw_windows,
    )
    return shard_map(
        fwd,
        mesh=mesh,
        in_specs=(P(), P(axis_name)),  # weights replicated, rows sharded
        out_specs=P(axis_name),
        check_rep=False,
    )(qp, x)


def accelerator_forward_sharded(
    params: dict | QuantizedParams,
    x: jax.Array,
    cfg: CNNConfig,
    *,
    mesh: Mesh,
    axis_name: str = STREAM_AXIS,
    fxp: bool = False,
    interpret: bool | None = None,
    raw_windows: bool = False,
    feature_kind: str | None = None,
) -> jax.Array:
    """Sharded-batch twin of :func:`accelerator_forward`: the batch dimension
    is split along ``mesh``'s ``axis_name`` axis, weights stay replicated,
    and each device runs the whole W8A8 datapath on its rows.

    Because activations are quantised with **per-sample** scales, each row's
    quantisation (and therefore its result) depends on nothing outside the
    row — the scales travel with their rows across the shard boundary, and
    the output is **bitwise identical** to the unsharded forward on the same
    batch.  That is the serving analogue of the paper's sequential scaling
    claim: partitioning the fixed batch over more hardware changes the
    schedule, never the numbers (the conformance suite pins this).

    Per-tensor activation scales are deliberately unsupported here: a shard-
    local per-tensor amax would differ from the global one, silently breaking
    the parity guarantee.  Pruned and mixed-precision artifacts shard
    unchanged — the float layer modes compute each row independently, so the
    bitwise guarantee extends to every artifact cell (conformance-pinned).

    ``raw_windows=True`` shards raw (B, 12800) windows instead of features:
    each device runs the fused DSP front-end on its own rows (shard-local,
    per-row bits) before its slice of the datapath — bitwise identical to
    the unsharded raw-window forward.

    ``x.shape[0]`` must divide evenly by the shard count.
    """
    n_shards = mesh.shape[axis_name]
    if x.shape[0] % n_shards != 0:
        raise ValueError(
            f"batch {x.shape[0]} not divisible by {n_shards} shards on "
            f"mesh axis {axis_name!r}"
        )
    if isinstance(params, QuantizedParams):
        qp = params
    else:
        qp = quantize_params(
            params, cfg, mode="fxp8" if fxp else "int8", feature_kind=feature_kind
        )
    if raw_windows:
        _check_raw_windows(qp, x, feature_kind)
    return _forward_sharded(
        qp, x, mesh, axis_name, resolve_interpret(interpret), True, raw_windows
    )


def precompile_slot_shapes(
    qp: QuantizedParams,
    cfg: CNNConfig,
    slot_counts,
    *,
    row_width: int | None = None,
    mesh: Mesh | None = None,
    axis_name: str | None = None,
    interpret: bool | None = None,
    raw_windows: bool = False,
) -> None:
    """Trace and compile the forward once per batch (slot) shape.

    Adaptive batch-slot sizing dispatches a small ladder of block shapes
    instead of one fixed ``batch_slots``; each distinct shape costs one jit
    trace.  Serving pays that cost at whatever round first uses the shape —
    a visible latency spike — unless the shapes are compiled up front.  This
    warms the jit cache with a zeros block per ladder value (zeros = the
    engine's silence padding, so no NaN hazards) and blocks until every
    program is built.  Per-sample activation scales make the traced numbers
    irrelevant — only the shapes enter the cache key.
    """
    if not isinstance(qp, QuantizedParams):
        raise TypeError(
            f"precompile_slot_shapes needs a baked QuantizedParams artifact, "
            f"got {type(qp).__name__}"
        )
    if row_width is None:
        row_width = features_jax.N_SAMPLES if raw_windows else cfg.input_len
    for slots in sorted(set(int(s) for s in slot_counts)):
        x = jnp.zeros((slots, row_width), jnp.float32)
        if mesh is not None:
            out = accelerator_forward_sharded(
                qp, x, cfg, mesh=mesh,
                axis_name=STREAM_AXIS if axis_name is None else axis_name,
                interpret=interpret, raw_windows=raw_windows,
            )
        else:
            out = accelerator_forward(
                qp, x, cfg, interpret=interpret, raw_windows=raw_windows
            )
        out.block_until_ready()


def deviation_report(
    params: dict, x: jax.Array, cfg: CNNConfig, *, per_sample_acts: bool = True
) -> dict:
    """Max probability deviation + decision agreement vs fp32 inference."""
    from repro.models import cnn1d

    ref = jax.nn.softmax(cnn1d.forward(params, x, cfg), axis=-1)
    acc = accelerator_forward(params, x, cfg, per_sample_acts=per_sample_acts)
    return {
        "max_prob_dev": float(jnp.max(jnp.abs(ref - acc))),
        "decision_agreement": float(jnp.mean(jnp.argmax(ref, -1) == jnp.argmax(acc, -1))),
    }
