"""Deployed-datapath inference: the whole 1D-F-CNN through the Pallas kernels.

This is the software twin of the POLARON accelerator's execution: every
convolution and dense layer runs on the W8A8 kernels — conv on the fused
in-kernel-im2col conv kernel, dense on quant_matmul — with bias+ReLU fused
into each layer's dequant epilogue, and the classifier head finishes with
the CORDIC softmax.  Against fp32 JAX inference this bounds the
*accelerator's* end-to-end numerical deviation — the sign-off artifact an
RTL team would diff against.

Weights come from a :class:`~repro.serving.quantized_params.QuantizedParams`
artifact (quantised once per precision mode at deploy time); only the
per-request activations are quantised per call.  The whole forward is one
``jax.jit`` program, interpret-mode on CPU and compiled on TPU via the
``interpret=None`` autodetect.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import STREAM_AXIS
from repro.kernels import ops
from repro.kernels.backend import resolve_interpret
from repro.models.cnn1d import CNNConfig, _maxpool2
from repro.serving.quantized_params import QuantizedParams, quantize_params


@functools.partial(jax.jit, static_argnames=("interpret", "per_sample_acts"))
def _forward_quantized(
    qp: QuantizedParams, x: jax.Array, interpret: bool, per_sample_acts: bool
) -> jax.Array:
    from repro.core.quantization import fxp8_quantize, int8_symmetric

    quant = fxp8_quantize if qp.fxp else int8_symmetric
    # Per-sample (row-wise) activation scales are the default: with one
    # per-tensor scale, a single loud sample crushes the quantisation
    # resolution of every co-batched quiet one — exactly the failure mode
    # micro-batching windows from N independent streams triggers.  Row-wise
    # scales also make every row's result independent of its co-batch, which
    # is what the streaming engine's bitwise-parity guarantee rests on.
    act_axis = 0 if per_sample_acts else None
    bsz = x.shape[0]
    h = x[:, :, None].astype(jnp.float32)
    for layer in qp.convs:
        hq = quant(h, axis=act_axis)  # per-request activation quantisation
        h = ops.conv1d_fused_q(
            hq.q,
            layer["w"].q,
            hq.scale.reshape(-1, 1) if per_sample_acts else hq.scale,
            layer["w"].scale,
            layer["b"],
            act="relu",  # CORDIC ReLU == max(v, 0): fused into the epilogue
            interpret=interpret,
        )
        h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    d0, d1 = qp.denses
    hq = quant(h, axis=act_axis)
    h = ops.quant_matmul(
        hq.q,
        d0["w"].q,
        hq.scale.reshape(bsz if per_sample_acts else 1, 1),
        d0["w"].scale.reshape(1, -1),
        d0["b"],
        act="relu",
        interpret=interpret,
    )
    hq = quant(h, axis=act_axis)
    logits = ops.quant_matmul(
        hq.q,
        d1["w"].q,
        hq.scale.reshape(bsz if per_sample_acts else 1, 1),
        d1["w"].scale.reshape(1, -1),
        d1["b"],
        interpret=interpret,
    )
    return ops.cordic_softmax(logits, interpret=interpret)


def accelerator_forward(
    params: dict | QuantizedParams,
    x: jax.Array,
    cfg: CNNConfig,
    *,
    fxp: bool = False,
    interpret: bool | None = None,
    per_sample_acts: bool = True,
) -> jax.Array:
    """x: (B, M) features -> (B, n_classes) class probabilities, computed
    entirely on the kernel datapath.

    Pass a :class:`QuantizedParams` artifact to serve from the weight cache
    (zero weight-quantisation work per call); a raw fp32 ``params`` dict is
    quantised on the fly (``fxp`` selects the mode) for one-off sign-offs.

    ``per_sample_acts`` (default) quantises activations with one scale per
    batch row; ``False`` restores the legacy per-tensor scale (kept as the
    A/B surface for the mixed-loudness regression tests).
    """
    if isinstance(params, QuantizedParams):
        qp = params
    else:
        qp = quantize_params(params, cfg, mode="fxp8" if fxp else "int8")
    return _forward_quantized(qp, x, resolve_interpret(interpret), per_sample_acts)


@functools.partial(
    jax.jit, static_argnames=("mesh", "axis_name", "interpret", "per_sample_acts")
)
def _forward_sharded(
    qp: QuantizedParams,
    x: jax.Array,
    mesh: Mesh,
    axis_name: str,
    interpret: bool,
    per_sample_acts: bool,
) -> jax.Array:
    fwd = functools.partial(
        _forward_quantized, interpret=interpret, per_sample_acts=per_sample_acts
    )
    return shard_map(
        fwd,
        mesh=mesh,
        in_specs=(P(), P(axis_name)),  # weights replicated, rows sharded
        out_specs=P(axis_name),
        check_rep=False,
    )(qp, x)


def accelerator_forward_sharded(
    params: dict | QuantizedParams,
    x: jax.Array,
    cfg: CNNConfig,
    *,
    mesh: Mesh,
    axis_name: str = STREAM_AXIS,
    fxp: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """Sharded-batch twin of :func:`accelerator_forward`: the batch dimension
    is split along ``mesh``'s ``axis_name`` axis, weights stay replicated,
    and each device runs the whole W8A8 datapath on its rows.

    Because activations are quantised with **per-sample** scales, each row's
    quantisation (and therefore its result) depends on nothing outside the
    row — the scales travel with their rows across the shard boundary, and
    the output is **bitwise identical** to the unsharded forward on the same
    batch.  That is the serving analogue of the paper's sequential scaling
    claim: partitioning the fixed batch over more hardware changes the
    schedule, never the numbers (the conformance suite pins this).

    Per-tensor activation scales are deliberately unsupported here: a shard-
    local per-tensor amax would differ from the global one, silently breaking
    the parity guarantee.

    ``x.shape[0]`` must divide evenly by the shard count.
    """
    n_shards = mesh.shape[axis_name]
    if x.shape[0] % n_shards != 0:
        raise ValueError(
            f"batch {x.shape[0]} not divisible by {n_shards} shards on "
            f"mesh axis {axis_name!r}"
        )
    if isinstance(params, QuantizedParams):
        qp = params
    else:
        qp = quantize_params(params, cfg, mode="fxp8" if fxp else "int8")
    return _forward_sharded(
        qp, x, mesh, axis_name, resolve_interpret(interpret), True
    )


def deviation_report(
    params: dict, x: jax.Array, cfg: CNNConfig, *, per_sample_acts: bool = True
) -> dict:
    """Max probability deviation + decision agreement vs fp32 inference."""
    from repro.models import cnn1d

    ref = jax.nn.softmax(cnn1d.forward(params, x, cfg), axis=-1)
    acc = accelerator_forward(params, x, cfg, per_sample_acts=per_sample_acts)
    return {
        "max_prob_dev": float(jnp.max(jnp.abs(ref - acc))),
        "decision_agreement": float(jnp.mean(jnp.argmax(ref, -1) == jnp.argmax(acc, -1))),
    }
