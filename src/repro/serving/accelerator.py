"""Deployed-datapath inference: the whole 1D-F-CNN through the Pallas kernels.

This is the software twin of the POLARON accelerator's execution: every
convolution and dense layer runs on the W8A8 quant_matmul kernel (conv via
im2col on the shared MAC datapath), activations run through the fixed-point
CORDIC unit, and the classifier head finishes with the CORDIC softmax.
Against fp32 JAX inference this bounds the *accelerator's* end-to-end
numerical deviation — the sign-off artifact an RTL team would diff against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.cnn1d import CNNConfig, _maxpool2


def accelerator_forward(params: dict, x: jax.Array, cfg: CNNConfig, *, fxp: bool = False) -> jax.Array:
    """x: (B, M) features -> (B, n_classes) class probabilities, computed
    entirely on the kernel datapath (interpret mode on CPU)."""
    h = x[:, :, None].astype(jnp.float32)
    for i in range(len(cfg.channels)):
        p = params[f"conv{i}"]
        h = ops.conv1d_q(h, p["w"].astype(jnp.float32), p["b"].astype(jnp.float32), fxp=fxp)
        h = ops.cordic_activation(h, "relu")
        h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    p = params["dense0"]
    h = ops.quant_matmul_f32(h, p["w"].astype(jnp.float32), fxp=fxp) + p["b"]
    h = ops.cordic_activation(h, "relu")
    p = params["dense1"]
    logits = ops.quant_matmul_f32(h, p["w"].astype(jnp.float32), fxp=fxp) + p["b"]
    return ops.cordic_softmax(logits)


def deviation_report(params: dict, x: jax.Array, cfg: CNNConfig) -> dict:
    """Max probability deviation + decision agreement vs fp32 inference."""
    from repro.models import cnn1d

    ref = jax.nn.softmax(cnn1d.forward(params, x, cfg), axis=-1)
    acc = accelerator_forward(params, x, cfg)
    return {
        "max_prob_dev": float(jnp.max(jnp.abs(ref - acc))),
        "decision_agreement": float(jnp.mean(jnp.argmax(ref, -1) == jnp.argmax(acc, -1))),
    }
