"""Shared continuous-batching/dispatch core for every serving surface.

The paper's datapath wins by keeping *one* sequential engine saturated
instead of replicating hardware; the serving layer follows the same shape:
work items (ready acoustic windows, queued LM requests) are packed into
slot-blocks of a compiled program and rotated through a bounded in-flight
pipeline.  Before this module, the detector fleet
(:class:`repro.serving.engine.MonitorEngine`) and the LM side
(:class:`repro.launch.serve.BatchedServer`) each carried a private half-copy
of that machinery; both now run on :class:`DispatchCore`.

The pieces, bottom up:

* :class:`SlotPolicy` — which slot counts (block batch sizes) a server may
  dispatch.  Fixed mode always uses ``max_slots`` (the pre-PR-7 behaviour:
  dead slots padded with silence/dead requests).  Adaptive mode grows and
  shrinks the block over a small power-of-two *ladder* between
  ``min_slots`` and ``max_slots`` to fit the ready backlog — at 1 live
  stream the engine dispatches 1-slot blocks instead of padding 7/8 slots.
  The ladder is deliberately tiny (``O(log2 max_slots)`` shapes) so a
  jitted forward compiles a bounded set of batch shapes instead of
  retracing per backlog size; every ladder value is a multiple of
  ``multiple`` so sharded dispatch keeps dividing evenly.
* :class:`BlockPool` — preallocated ``(slots, width)`` dispatch buffers,
  one rotation of ``inflight + 1`` buffers per slot shape.  ``device_put``
  on CPU may alias host memory zero-copy, so a buffer must never be
  rewritten while its dispatch is still in flight; rotating ``inflight +
  1`` deep guarantees the buffer being packed is older than every
  unharvested submission (the invariant PR 5 pinned, now held in one
  place for all slot shapes).
* :class:`DispatchCore` — the ready-work queue and the dispatch loop:
  split items into slot-blocks via the policy, ``submit`` each block
  (async handles welcome), harvest with at most ``inflight`` blocks
  outstanding, and reassemble per-item results *in submission order*.
  ``dispatch`` is all-or-nothing: either every item's result is returned
  (commit) or the exception propagates and the optional rollback hook
  fires with no partial results observable — the transactional-round
  protocol the monitor engine and the fleet supervisor's crash recovery
  are built on.  ``pre_dispatch`` is the fault-injection seam
  (:mod:`repro.serving.faults`): called with the items before anything is
  submitted, it may raise (simulated crash) or stall, and the rollback
  guarantee makes the failed round re-runnable.
* :class:`AdmissionPolicy` / :func:`fair_allocation` — fleet-scale stream
  admission and per-tenant fairness on top of the core: cap how many
  ready windows one stream may drain per round, bound the total round
  budget with depth-fair allocation (no stream gets its second window
  before every ready stream got its first, so a firehose cannot starve a
  trickle), cap how many distinct streams are admitted at all, and evict
  streams that persistently overflow their ingest rings.

Every row's result is bitwise independent of its co-batch (per-sample
activation scales, PRs 2-5), which is exactly what makes elastic
re-batching safe: any grow/shrink schedule over any backlog produces the
same per-item numbers as the fixed-slot engine, and the conformance suites
hold that to ``==``, not a tolerance.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Callable, Sequence

import numpy as np


class SlotPolicy:
    """Slot-count selection for one dispatch block.

    ``adaptive=False`` (the legacy behaviour) always dispatches
    ``max_slots`` and pads dead slots.  ``adaptive=True`` picks from a
    power-of-two ladder of multiples of ``multiple`` in
    ``[min_slots, max_slots]``: for a backlog of ``n`` items it chooses the
    largest ladder value that fits (``<= n``), falling back to the smallest
    ladder value that covers a sub-``min_slots`` remainder — so padding is
    bounded by ``min_slots``-granularity instead of ``max_slots``.
    """

    def __init__(
        self,
        max_slots: int,
        *,
        adaptive: bool = False,
        min_slots: int = 1,
        multiple: int = 1,
    ):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if min_slots < 1:
            raise ValueError(f"min_slots must be >= 1, got {min_slots}")
        if min_slots > max_slots:
            raise ValueError(
                f"min_slots {min_slots} must be <= max_slots {max_slots}"
            )
        if multiple < 1:
            raise ValueError(f"multiple must be >= 1, got {multiple}")
        if max_slots % multiple != 0:
            raise ValueError(
                f"max_slots {max_slots} must be a multiple of {multiple} "
                f"(sharded dispatch splits every block evenly)"
            )
        self.max_slots = int(max_slots)
        self.min_slots = int(min_slots)
        self.multiple = int(multiple)
        self.adaptive = bool(adaptive)
        if not adaptive:
            ladder = [self.max_slots]
        else:
            ladder, v = [self.max_slots], self.multiple
            while v < self.max_slots:
                if v >= self.min_slots:
                    ladder.append(v)
                v *= 2
        #: the complete set of block shapes this policy will ever dispatch —
        #: pre-jit each once (see ``MonitorEngine.precompile``) and adaptive
        #: serving never hits a compile stall mid-round.
        self.ladder: tuple[int, ...] = tuple(sorted(set(ladder)))

    @classmethod
    def fixed(cls, slots: int, *, multiple: int = 1) -> "SlotPolicy":
        return cls(slots, adaptive=False, multiple=multiple)

    def pick(self, backlog: int) -> int:
        """Slot count for the next block given ``backlog`` remaining items."""
        if backlog < 1:
            raise ValueError(f"backlog must be >= 1, got {backlog}")
        if not self.adaptive or backlog >= self.max_slots:
            return self.max_slots
        fitting = [s for s in self.ladder if s <= backlog]
        if fitting:
            return fitting[-1]  # largest block that fits: zero padding
        return self.ladder[0]  # sub-min remainder: smallest block, some pad

    def __repr__(self):
        mode = "adaptive" if self.adaptive else "fixed"
        return f"SlotPolicy({mode}, ladder={self.ladder})"


class BlockPool:
    """Preallocated dispatch buffers: ``inflight + 1`` rotating ``(slots,
    width)`` float32 blocks per slot shape, allocated lazily per shape.

    The rotation depth is the aliasing-safety invariant: with at most
    ``inflight`` submissions unharvested, the buffer being packed is always
    older than every in-flight one, so zero-copy ``device_put`` can never
    observe a rewrite.  Shapes rotate independently — an in-flight block of
    one shape is untouched by packing another shape.
    """

    def __init__(self, width: int, inflight: int, dtype=np.float32):
        if width < 1:
            raise ValueError(f"width must be >= 1, got {width}")
        if inflight < 1:
            raise ValueError(f"inflight must be >= 1, got {inflight}")
        self.width = int(width)
        self.depth = int(inflight) + 1
        self.dtype = dtype
        self._pools: dict[int, list[np.ndarray]] = {}
        self._next: dict[int, int] = {}

    def pack(self, rows: Sequence[np.ndarray] | np.ndarray, slots: int) -> np.ndarray:
        """Copy ``rows`` into the next rotation buffer of shape ``(slots,
        width)``; dead-slot tails carry zeros (silence)."""
        n = len(rows)
        if n > slots:
            raise ValueError(f"{n} rows do not fit {slots} slots")
        pool = self._pools.get(slots)
        if pool is None:
            pool = [
                np.zeros((slots, self.width), self.dtype)
                for _ in range(self.depth)
            ]
            self._pools[slots] = pool
            self._next[slots] = 0
        i = self._next[slots]
        self._next[slots] = (i + 1) % self.depth
        block = pool[i]
        block[:n] = rows
        if n < slots:
            block[n:] = 0.0  # dead slots carry silence
        return block


class IngestQueue:
    """Thread-safe front-of-fleet ingest queue for lane-parallel serving.

    With execution lanes enabled, the fleet supervisor's ``push`` must never
    touch a worker engine directly — a lane may be mid-round on that engine.
    Producers ``append`` (never blocks, only a lock-protected deque append);
    the supervisor ``drain``s the whole backlog at the top of each round, on
    its own thread, and routes the items through the exact same admission /
    fault-injection / journal path the sequential fleet uses — so queued
    ingest changes *when* a chunk is delivered, never *what* is delivered,
    and the lane-parallel fleet stays bitwise equal to the sequential one.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._items: collections.deque = collections.deque()

    def append(self, item) -> None:
        with self._lock:
            self._items.append(item)

    def drain(self) -> list:
        """Swap out and return the queued items, oldest first."""
        with self._lock:
            items, self._items = self._items, collections.deque()
        return list(items)

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


class DispatchCore:
    """Queue → slot-blocks → bounded in-flight rotation → ordered results.

    Generic over the work item and the block program:

    ``submit(live_items, slots)``
        Dispatch one block of ``slots`` slots holding ``live_items`` (at
        most ``slots`` of them; the callee pads dead slots).  May return an
        async handle (e.g. an in-flight jax array) — submission must not
        block on the result, that is what gives the double-buffered
        overlap.
    ``harvest(handle)``
        Block until the handle's results are ready; return an indexable of
        per-slot results (only the first ``len(live_items)`` are read).
        ``None`` means ``submit`` is synchronous and already returns the
        per-item results.

    ``dispatch(items)`` is all-or-nothing: the optional ``pre_dispatch``
    hook (the fault-injection seam) runs first and may raise; any exception
    from it, ``submit`` or ``harvest`` triggers ``on_rollback`` and
    propagates with no partial results observable, so a transactional
    caller can simply retry the identical round.  On success ``on_commit``
    fires and every item's result is returned in input order.
    """

    def __init__(
        self,
        *,
        submit: Callable[[Any, int], Any],
        harvest: Callable[[Any], Any] | None = None,
        slot_policy: SlotPolicy,
        inflight: int = 1,
        pre_dispatch: Callable[[Any], None] | None = None,
        on_commit: Callable[[Any, list], None] | None = None,
        on_rollback: Callable[[Any], None] | None = None,
    ):
        if inflight < 1:
            raise ValueError(f"inflight must be >= 1, got {inflight}")
        self._submit = submit
        self._harvest = harvest
        self.slot_policy = slot_policy
        self.inflight = int(inflight)
        self.pre_dispatch = pre_dispatch
        self.on_commit = on_commit
        self.on_rollback = on_rollback
        self.queue: collections.deque = collections.deque()
        # observability: what the dispatch loop actually did
        self.blocks_dispatched = 0
        self.padded_slots = 0
        self.slot_histogram: dict[int, int] = {}

    # -- ready-work queue ----------------------------------------------------

    def enqueue(self, items) -> None:
        """Append work items to the ready queue (see :meth:`drain`)."""
        self.queue.extend(items)

    def drain(self) -> list:
        """Dispatch everything currently queued, in arrival order."""
        items = list(self.queue)
        self.queue.clear()
        if not items:
            return []
        try:
            return self.dispatch(items)
        except Exception:
            # rollback: the work is not lost — it goes back to the front of
            # the queue so a recovered caller can drain() again
            self.queue.extendleft(reversed(items))
            raise

    # -- the dispatch loop ---------------------------------------------------

    def dispatch(self, items) -> list:
        """Run ``items`` through slot-blocks; all-or-nothing (see class
        docstring).  Returns one result per item, in input order."""
        try:
            if self.pre_dispatch is not None:
                # fault-injection seam: may raise (crash) or stall; nothing
                # has been submitted yet either way
                self.pre_dispatch(items)
            results = self._run(items)
        except Exception:
            if self.on_rollback is not None:
                self.on_rollback(items)
            raise
        if self.on_commit is not None:
            self.on_commit(items, results)
        return results

    def _run(self, items) -> list:
        n = len(items)
        results: list = [None] * n
        pending: collections.deque[tuple[int, int, Any]] = collections.deque()

        def harvest_one():
            # blocking on the oldest in-flight block also means the device
            # has consumed its input buffer, so the BlockPool rotation may
            # safely rewrite it on a later turn
            start, n_live, handle = pending.popleft()
            out = self._harvest(handle)
            for j in range(n_live):
                results[start + j] = out[j]

        i = 0
        while i < n:
            slots = self.slot_policy.pick(n - i)
            live = items[i : i + slots]
            n_live = len(live)
            out = self._submit(live, slots)
            self.blocks_dispatched += 1
            self.padded_slots += slots - n_live
            self.slot_histogram[slots] = self.slot_histogram.get(slots, 0) + 1
            if self._harvest is None:  # synchronous program
                for j in range(n_live):
                    results[i + j] = out[j]
            else:
                pending.append((i, n_live, out))
                if len(pending) >= self.inflight:
                    harvest_one()
            i += n_live
        while pending:
            harvest_one()
        return results


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """Stream admission, per-tenant fairness and eviction knobs for a
    fleet-scale monitor (consumed by :class:`~repro.serving.engine.
    MonitorEngine`; the defaults reproduce the pre-PR-7 behaviour exactly).

    ``max_streams``
        At most this many *distinct* streams are admitted, first come first
        served; pushes to a stream refused at admission are dropped and
        counted (``refused_chunks``), never scored.  ``None`` admits every
        stream the engine was built for.
    ``max_per_stream_per_round``
        A stream with backlog may drain up to this many ready windows in
        one ``step()`` round (1 = the classic one-window beat).  Raising it
        lets a stream catch up after a stall without unbounded rounds.
    ``round_budget``
        Cap on the total windows scored per round.  When the fleet backlog
        exceeds it, :func:`fair_allocation` serves streams depth-fair: no
        stream gets its second window before every ready stream got its
        first, so one firehose stream cannot starve a trickle stream's
        latency.  ``None`` = unbounded.
    ``evict_overflow_rounds``
        A stream whose ring overflowed (dropped samples) in this many
        *consecutive* committed rounds is evicted: de-admitted, its pushes
        refused from then on.  The fleet supervisor additionally rebuilds
        the worker without the stream (the reassignment machinery), so the
        abusive tenant stops costing slots entirely.  ``None`` disables
        eviction.
    """

    max_streams: int | None = None
    max_per_stream_per_round: int = 1
    round_budget: int | None = None
    evict_overflow_rounds: int | None = None

    def __post_init__(self):
        if self.max_streams is not None and self.max_streams < 1:
            raise ValueError(
                f"max_streams must be >= 1 or None, got {self.max_streams}"
            )
        if self.max_per_stream_per_round < 1:
            raise ValueError(
                f"max_per_stream_per_round must be >= 1, got "
                f"{self.max_per_stream_per_round}"
            )
        if self.round_budget is not None and self.round_budget < 1:
            raise ValueError(
                f"round_budget must be >= 1 or None, got {self.round_budget}"
            )
        if (
            self.evict_overflow_rounds is not None
            and self.evict_overflow_rounds < 1
        ):
            raise ValueError(
                f"evict_overflow_rounds must be >= 1 or None, got "
                f"{self.evict_overflow_rounds}"
            )


def fair_allocation(want: np.ndarray, budget: int | None) -> np.ndarray:
    """Depth-fair allocation of ``budget`` units over per-stream demands.

    ``want[i]`` is how many windows stream ``i`` wants this round (already
    capped by ``max_per_stream_per_round``).  With no budget, or a budget
    that covers the total demand, everyone gets what they want.  Otherwise
    units are granted depth by depth — every stream with unmet demand gets
    its d-th unit before any stream gets its (d+1)-th — and ties at the
    budget boundary break by stream index (deterministic).  This is the
    fairness guarantee: a firehose stream's backlog can never displace
    another stream's *first* window of the round.
    """
    want = np.asarray(want, np.int64)
    if (want < 0).any():
        raise ValueError("want must be non-negative")
    if budget is None or int(want.sum()) <= budget:
        return want.copy()
    alloc = np.zeros_like(want)
    remaining = int(budget)
    depth = 0
    while remaining > 0:
        eligible = np.flatnonzero(want > depth)
        if eligible.size == 0:
            break
        grant = eligible[:remaining]
        alloc[grant] += 1
        remaining -= grant.size
        depth += 1
    return alloc
