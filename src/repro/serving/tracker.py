"""Temporal tracking of UAV detections (the title's "Temporal Tracking").

Continuous monitoring emits a per-window UAV probability every 0.8 s; raw
thresholding chatters under noise.  The tracker smooths scores with an EMA
and applies hysteresis (enter/exit thresholds) plus a minimum-duration
filter, producing stable *events* (onset, offset, peak confidence) — the
false-alarm behaviour that Fig. 5 measures is what the hysteresis
suppresses.

Two implementations share the exact same semantics:

* :class:`TemporalTracker` — scalar, one stream, one ``update`` per window.
* :class:`VectorTemporalTracker` — EMA/hysteresis/min-duration state held in
  ``(n_streams,)`` float64/bool arrays so tracking N concurrent streams is
  one numpy pass per window round, not N Python loops.  This is what the
  multi-stream monitor engine uses.

Both accumulate event statistics incrementally (running sum / count / max in
float64, the same left-to-right order), so their :class:`TrackEvent` outputs
are *identical*, not merely close — the streaming-parity tests compare them
with ``==``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np


@dataclasses.dataclass
class TrackEvent:
    onset_idx: int
    offset_idx: int
    peak_score: float
    mean_score: float

    @property
    def duration(self) -> int:
        return self.offset_idx - self.onset_idx + 1


@dataclasses.dataclass
class TemporalTracker:
    ema_alpha: float = 0.4
    enter_threshold: float = 0.65
    exit_threshold: float = 0.35
    min_duration: int = 2  # windows (>= 1.6 s of sustained detection)

    def __post_init__(self):
        self.reset()

    def reset(self):
        self._ema: Optional[float] = None
        self._active = False
        self._onset = 0
        # Incremental event statistics (not a score list): count/sum/peak over
        # the windows that are *part of the event* — the exit window (EMA at
        # or below exit_threshold) never contributes.
        self._count = 0
        self._sum = 0.0
        self._peak = -np.inf
        self._idx = -1
        self.events: list[TrackEvent] = []

    @property
    def smoothed(self) -> float:
        return self._ema if self._ema is not None else 0.0

    def update(self, p_uav: float) -> dict:
        """Feed one window's UAV probability; returns the tracker state."""
        # Coerce to a Python float: a np.float32 input would otherwise run
        # the whole EMA/stats chain in float32 (NEP 50) and break the
        # bitwise scalar-vs-vector parity contract.
        p_uav = float(p_uav)
        self._idx += 1
        self._ema = (
            p_uav
            if self._ema is None
            else self.ema_alpha * p_uav + (1 - self.ema_alpha) * self._ema
        )
        if not self._active and self._ema >= self.enter_threshold:
            self._active = True
            self._onset = self._idx
            self._count, self._sum, self._peak = 0, 0.0, -np.inf
        if self._active:
            if self._ema <= self.exit_threshold:
                # The current window broke the track: it is NOT part of the
                # event, so the event ends at the previous window.
                self._close(self._idx - 1)
            else:
                self._count += 1
                self._sum += self._ema
                self._peak = max(self._peak, self._ema)
        return {"idx": self._idx, "smoothed": self._ema, "active": self._active}

    def _close(self, offset_idx: int):
        self._active = False
        # Duration gate agrees with TrackEvent.duration: an event spanning
        # exactly min_duration windows is kept.  self._count always equals
        # offset_idx - self._onset + 1 here.
        if self._count >= max(self.min_duration, 1):
            self.events.append(
                TrackEvent(
                    onset_idx=self._onset,
                    offset_idx=offset_idx,
                    peak_score=float(self._peak),
                    mean_score=float(self._sum / self._count),
                )
            )

    def finalize(self) -> list[TrackEvent]:
        if self._active:
            # The final window is genuinely active (the EMA never fell below
            # exit_threshold), so it closes the event *inclusively*.
            self._close(self._idx)
        return self.events


class VectorTemporalTracker:
    """Track N streams at once; state lives in ``(n_streams,)`` arrays.

    ``update(p, mask)`` advances only the streams selected by ``mask`` (a
    stream that produced no window this round keeps its state frozen,
    including its per-stream window index), which is exactly what the
    monitor engine's uneven-arrival rounds need.

    Semantics are window-for-window identical to :class:`TemporalTracker`;
    see the module docstring for why the event statistics match bitwise.
    """

    def __init__(
        self,
        n_streams: int,
        *,
        ema_alpha: float = 0.4,
        enter_threshold: float = 0.65,
        exit_threshold: float = 0.35,
        min_duration: int = 2,
    ):
        self.n_streams = n_streams
        self.ema_alpha = ema_alpha
        self.enter_threshold = enter_threshold
        self.exit_threshold = exit_threshold
        self.min_duration = min_duration
        self.reset()

    def reset(self):
        n = self.n_streams
        self._ema = np.zeros(n, np.float64)
        self._seen = np.zeros(n, bool)  # has stream ever produced a window?
        self._active = np.zeros(n, bool)
        self._onset = np.zeros(n, np.int64)
        self._count = np.zeros(n, np.int64)
        self._sum = np.zeros(n, np.float64)
        self._peak = np.full(n, -np.inf, np.float64)
        self._idx = np.full(n, -1, np.int64)  # per-stream window index
        self.events: list[list[TrackEvent]] = [[] for _ in range(n)]

    @property
    def smoothed(self) -> np.ndarray:
        return np.where(self._seen, self._ema, 0.0)

    @property
    def active(self) -> np.ndarray:
        return self._active.copy()

    def update(self, p_uav: np.ndarray, mask: np.ndarray | None = None) -> dict:
        """Feed one window round: ``p_uav[i]`` is stream i's probability.

        ``mask[i]`` False freezes stream i this round (``p_uav[i]`` ignored).
        Returns arrays ``{"idx", "smoothed", "active"}`` mirroring the scalar
        tracker's state dict.
        """
        p = np.asarray(p_uav, np.float64)
        assert p.shape == (self.n_streams,), p.shape
        m = (
            np.ones(self.n_streams, bool)
            if mask is None
            else np.asarray(mask, bool)
        )
        a = self.ema_alpha

        self._idx[m] += 1
        # First-ever window seeds the EMA directly (scalar: self._ema is None).
        new_ema = np.where(self._seen, a * p + (1 - a) * self._ema, p)
        self._ema = np.where(m, new_ema, self._ema)
        self._seen |= m

        entering = m & ~self._active & (self._ema >= self.enter_threshold)
        self._active |= entering
        self._onset[entering] = self._idx[entering]
        self._count[entering] = 0
        self._sum[entering] = 0.0
        self._peak[entering] = -np.inf

        exiting = m & self._active & (self._ema <= self.exit_threshold)
        staying = m & self._active & ~exiting
        self._count[staying] += 1
        self._sum[staying] += self._ema[staying]
        self._peak[staying] = np.maximum(self._peak[staying], self._ema[staying])

        if exiting.any():
            # The exiting window is not part of the event: offset = idx - 1.
            self._close(np.flatnonzero(exiting), self._idx[exiting] - 1)
        return {
            "idx": self._idx.copy(),
            "smoothed": self.smoothed,
            "active": self._active.copy(),
        }

    def _close(self, streams: np.ndarray, offsets: np.ndarray):
        self._active[streams] = False
        for s, off in zip(streams, offsets):
            if self._count[s] >= max(self.min_duration, 1):
                self.events[s].append(
                    TrackEvent(
                        onset_idx=int(self._onset[s]),
                        offset_idx=int(off),
                        peak_score=float(self._peak[s]),
                        mean_score=float(self._sum[s] / self._count[s]),
                    )
                )

    def finalize(self) -> list[list[TrackEvent]]:
        open_ = np.flatnonzero(self._active)
        if open_.size:
            # Still-active streams close inclusively at their last window.
            self._close(open_, self._idx[open_])
        return self.events

    # -- crash-recoverable state ---------------------------------------------

    #: array fields captured by state_dict (events are handled separately)
    _STATE_ARRAYS = (
        "_ema", "_seen", "_active", "_onset", "_count", "_sum", "_peak", "_idx"
    )

    def state_dict(self) -> dict:
        """Deep-copied snapshot of every per-stream array plus the emitted
        events.  Feeding it back through :meth:`load_state_dict` — on this
        instance or a freshly built one — reproduces the tracker *exactly*:
        replaying the same probability sequence afterwards yields bitwise
        identical EMA trajectories and ``TrackEvent`` lists (the
        crash-recovery conformance tests pin this)."""
        sd = {name: getattr(self, name).copy() for name in self._STATE_ARRAYS}
        # TrackEvent instances are never mutated after emission, so copying
        # the per-stream lists (not the events) is a full deep copy.
        sd["events"] = [list(evs) for evs in self.events]
        return sd

    def load_state_dict(self, sd: dict):
        """Restore a :meth:`state_dict` snapshot; the tracker must have been
        built with the same ``n_streams``."""
        n = len(sd["_ema"])
        if n != self.n_streams:
            raise ValueError(
                f"state_dict holds {n} stream(s) but this tracker was built "
                f"for {self.n_streams}"
            )
        for name in self._STATE_ARRAYS:
            cur = getattr(self, name)
            arr = np.asarray(sd[name], cur.dtype)
            if arr.shape != cur.shape:
                raise ValueError(
                    f"state_dict field {name} has shape {arr.shape}, "
                    f"expected {cur.shape}"
                )
            setattr(self, name, arr.copy())
        self.events = [list(evs) for evs in sd["events"]]


def track_stream(probs: Iterable[float], **kw) -> list[TrackEvent]:
    tr = TemporalTracker(**kw)
    for p in probs:
        tr.update(float(p))
    return tr.finalize()
