"""Temporal tracking of UAV detections (the title's "Temporal Tracking").

Continuous monitoring emits a per-window UAV probability every 0.8 s; raw
thresholding chatters under noise.  The tracker smooths scores with an EMA
and applies hysteresis (enter/exit thresholds) plus a minimum-duration
filter, producing stable *events* (onset, offset, peak confidence) — the
false-alarm behaviour that Fig. 5 measures is what the hysteresis
suppresses.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np


@dataclasses.dataclass
class TrackEvent:
    onset_idx: int
    offset_idx: int
    peak_score: float
    mean_score: float

    @property
    def duration(self) -> int:
        return self.offset_idx - self.onset_idx + 1


@dataclasses.dataclass
class TemporalTracker:
    ema_alpha: float = 0.4
    enter_threshold: float = 0.65
    exit_threshold: float = 0.35
    min_duration: int = 2  # windows (>= 1.6 s of sustained detection)

    def __post_init__(self):
        self.reset()

    def reset(self):
        self._ema: Optional[float] = None
        self._active = False
        self._onset = 0
        self._scores: list[float] = []
        self._idx = -1
        self.events: list[TrackEvent] = []

    @property
    def smoothed(self) -> float:
        return self._ema if self._ema is not None else 0.0

    def update(self, p_uav: float) -> dict:
        """Feed one window's UAV probability; returns the tracker state."""
        self._idx += 1
        self._ema = (
            p_uav
            if self._ema is None
            else self.ema_alpha * p_uav + (1 - self.ema_alpha) * self._ema
        )
        if not self._active and self._ema >= self.enter_threshold:
            self._active = True
            self._onset = self._idx
            self._scores = []
        if self._active:
            self._scores.append(self._ema)
            if self._ema <= self.exit_threshold:
                self._close(self._idx - 1)
        return {"idx": self._idx, "smoothed": self._ema, "active": self._active}

    def _close(self, offset_idx: int):
        self._active = False
        if len(self._scores) - 1 >= self.min_duration:
            scores = self._scores[:-1] or self._scores
            self.events.append(
                TrackEvent(
                    onset_idx=self._onset,
                    offset_idx=offset_idx,
                    peak_score=float(np.max(scores)),
                    mean_score=float(np.mean(scores)),
                )
            )

    def finalize(self) -> list[TrackEvent]:
        if self._active:
            self._close(self._idx)
        return self.events


def track_stream(probs: Iterable[float], **kw) -> list[TrackEvent]:
    tr = TemporalTracker(**kw)
    for p in probs:
        tr.update(float(p))
    return tr.finalize()
