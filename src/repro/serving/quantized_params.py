"""Pre-quantised parameter cache for the deployed datapath.

The serving lifecycle is: train in fp32 → quantise the weights **once** per
precision mode → serve every request against the cached int8 payloads.  The
seed ``accelerator_forward`` re-ran ``int8_symmetric``/``fxp8_quantize`` on
every weight tensor on every call; with millions of requests that is pure
waste — weights only change on redeploy.  ``QuantizedParams`` is the frozen
artifact (conv weights per-output-channel on axis 2, dense weights on axis
1, biases kept fp32 for the epilogue adder), and ``QuantizedParamsCache``
memoises one artifact per precision mode for a given fp32 checkpoint.

``quantize_calls`` counts weight-tensor quantisations performed by this
module — the test surface proving serving does zero per-call quantisation
work.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.quantization import QTensor, fxp8_quantize, int8_symmetric
from repro.models.cnn1d import CNNConfig

MODES = ("int8", "fxp8")

# Incremented once per weight tensor quantised; tests assert this stays flat
# across serving calls.
quantize_calls: int = 0


@dataclasses.dataclass(frozen=True)
class QuantizedParams:
    """One precision mode's frozen weights for ``accelerator_forward``."""

    mode: str  # "int8" | "fxp8" (static pytree aux data)
    convs: tuple[dict, ...]  # each {"w": QTensor(K,Cin,Cout), "b": fp32}
    denses: tuple[dict, ...]  # each {"w": QTensor(In,Out), "b": fp32}

    @property
    def fxp(self) -> bool:
        return self.mode == "fxp8"


jax.tree_util.register_pytree_node(
    QuantizedParams,
    lambda p: ((p.convs, p.denses), p.mode),
    lambda mode, kids: QuantizedParams(mode, kids[0], kids[1]),
)


def _quantize_weight(w: jax.Array, mode: str, axis: int) -> QTensor:
    global quantize_calls
    quantize_calls += 1
    quant = fxp8_quantize if mode == "fxp8" else int8_symmetric
    return quant(w.astype(jax.numpy.float32), axis=axis)


def quantize_params(params: dict, cfg: CNNConfig, *, mode: str = "int8") -> QuantizedParams:
    """Quantise a trained fp32 checkpoint into one mode's serving artifact."""
    assert mode in MODES, mode
    convs = tuple(
        {
            "w": _quantize_weight(params[f"conv{i}"]["w"], mode, axis=2),
            "b": params[f"conv{i}"]["b"].astype(jax.numpy.float32),
        }
        for i in range(len(cfg.channels))
    )
    denses = tuple(
        {
            "w": _quantize_weight(params[name]["w"], mode, axis=1),
            "b": params[name]["b"].astype(jax.numpy.float32),
        }
        for name in ("dense0", "dense1")
    )
    return QuantizedParams(mode=mode, convs=convs, denses=denses)


def replicate_params(qp: QuantizedParams, mesh: jax.sharding.Mesh) -> QuantizedParams:
    """Pin every weight leaf onto ``mesh`` fully replicated.

    Sharded-batch dispatch keeps weights on all devices and splits only the
    activation rows; placing the artifact once at engine construction means
    no per-call host->device weight transfers and no accidental re-layout
    inside the jitted sharded forward.
    """
    sharding = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return jax.tree_util.tree_map(lambda leaf: jax.device_put(leaf, sharding), qp)


class QuantizedParamsCache:
    """Per-precision-mode memo over one fp32 checkpoint.

    ``cache.get("int8")`` quantises on first use and returns the same
    ``QuantizedParams`` object forever after — the train → quantise once →
    serve lifecycle in one place.
    """

    def __init__(self, params: dict, cfg: CNNConfig):
        self._params = params
        self._cfg = cfg
        self._by_mode: dict[str, QuantizedParams] = {}

    def get(self, mode: str = "int8") -> QuantizedParams:
        if mode not in self._by_mode:
            self._by_mode[mode] = quantize_params(self._params, self._cfg, mode=mode)
        return self._by_mode[mode]
