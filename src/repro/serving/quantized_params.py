"""Pre-quantised parameter artifact for the deployed datapath.

The serving lifecycle is: train in fp32 → bake the deployment decisions
**once** → serve every request against the frozen artifact.  Three decisions
are baked in at quantise-once time:

* **precision** — each layer's weight is stored in its serving numeric form:
  int8/fxp8 payload + scale (``QTensor``) for the 8-bit modes, a bf16 cast
  for BF16, plain fp32 otherwise.  A :class:`~repro.core.precision_policy.
  PrecisionPolicy` resolves per-layer modes (the paper's §III-B layer-
  sensitivity assignment); without one, every layer rides the artifact's
  default ``mode``.
* **pruning** — a :class:`~repro.core.pruning.PruneSpec` (§III-C) physically
  removes pruned conv-out channels and the matching dense rows *before*
  quantisation, so per-channel scales are computed on the surviving weights
  and the serving graph never touches dead FLOPs.  The boundary-frame trim
  survives as ``keep_frames`` (applied between the last pool and the
  flatten).
* **layout** — conv weights per-output-channel on axis 2, dense weights on
  axis 1, biases kept fp32 for the epilogue adder.

A fourth, optional decision is the **DSP front-end**: ``feature_kind`` bakes
the feature set the model was trained on into the artifact, so the jitted
serving program can start at raw 0.8 s audio windows
(``accelerator_forward(..., raw_windows=True)``) instead of host-extracted
features.

``QuantizedParamsCache`` memoises one artifact per (mode, prune, policy)
cell over a fp32 checkpoint; ``save_artifact``/``load_artifact`` round-trip
an artifact through one ``.npz`` file (the golden-artifact conformance
surface).  ``quantize_calls`` counts weight-tensor quantisations performed
by this module — the test surface proving serving does zero per-call
quantisation work.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision_policy import PrecisionPolicy
from repro.core.pruning import PruneSpec, apply_prune_conv, apply_prune_dense
from repro.core.quantization import QTensor, fxp8_quantize, int8_symmetric
from repro.models.cnn1d import CNNConfig

MODES = ("int8", "fxp8")
#: every numeric form a single layer may be stored in
LAYER_MODES = ("fp32", "bf16", "int8", "fxp8")

# Incremented once per weight tensor quantised; tests assert this stays flat
# across serving calls.
quantize_calls: int = 0


@dataclasses.dataclass(frozen=True)
class QuantizedParams:
    """The frozen serving artifact for ``accelerator_forward``.

    ``mode`` is the default precision; ``conv_modes``/``dense_modes`` carry
    the per-layer tags the accelerator dispatches on (``None`` means uniform
    ``mode`` — the pre-mixed-precision artifact shape).  ``keep_frames`` is
    the pruned artifact's frame count before the flatten (``None`` =
    unpruned).  All of these are static pytree aux data, so a jitted forward
    specialises on the artifact's layer layout, never on its weights.
    """

    mode: str  # default mode: "int8" | "fxp8" (static pytree aux data)
    convs: tuple[dict, ...]  # each {"w": QTensor | jax.Array, "b": fp32}
    denses: tuple[dict, ...]  # each {"w": QTensor | jax.Array, "b": fp32}
    conv_modes: tuple[str, ...] | None = None  # per-layer tags (None = uniform)
    dense_modes: tuple[str, ...] | None = None
    keep_frames: int | None = None  # frames kept before flatten (None = all)
    #: DSP front-end baked into the serving program: when set, the artifact
    #: may be served on raw 0.8 s windows (``raw_windows=True``) and the
    #: jitted forward prepends repro.data.features_jax for this kind.
    feature_kind: str | None = None

    @property
    def fxp(self) -> bool:
        return self.mode == "fxp8"

    @property
    def layer_modes(self) -> tuple[tuple[str, ...], tuple[str, ...]]:
        """Resolved (conv_modes, dense_modes) with the uniform default applied."""
        return (
            self.conv_modes or (self.mode,) * len(self.convs),
            self.dense_modes or (self.mode,) * len(self.denses),
        )

    @property
    def mixed(self) -> bool:
        conv_m, dense_m = self.layer_modes
        return any(m != self.mode for m in conv_m + dense_m)

    @property
    def pruned(self) -> bool:
        return self.keep_frames is not None


jax.tree_util.register_pytree_node(
    QuantizedParams,
    lambda p: (
        (p.convs, p.denses),
        (p.mode, p.conv_modes, p.dense_modes, p.keep_frames, p.feature_kind),
    ),
    lambda aux, kids: QuantizedParams(
        aux[0], kids[0], kids[1], aux[1], aux[2], aux[3], aux[4]
    ),
)


def _quantize_weight(w: jax.Array, mode: str, axis: int) -> QTensor:
    global quantize_calls
    quantize_calls += 1
    quant = fxp8_quantize if mode == "fxp8" else int8_symmetric
    return quant(w.astype(jnp.float32), axis=axis)


def _prep_weight(w: jax.Array, layer_mode: str, axis: int):
    """One layer's weight in its serving numeric form."""
    if layer_mode in ("int8", "fxp8"):
        return _quantize_weight(w, layer_mode, axis)
    if layer_mode == "bf16":
        return w.astype(jnp.bfloat16)
    return w.astype(jnp.float32)


def quantize_params(
    params: dict,
    cfg: CNNConfig,
    *,
    mode: str = "int8",
    prune: PruneSpec | None = None,
    policy: PrecisionPolicy | None = None,
    feature_kind: str | None = None,
) -> QuantizedParams:
    """Bake a trained fp32 checkpoint into one serving artifact.

    ``mode`` is the default precision for every layer; ``policy`` overrides
    it per layer (resolved against ``conv{i}/w`` / ``dense{i}/w`` paths, the
    same paths the emulation forward uses).  ``prune`` physically removes the
    planned conv-out channels and dense rows *before* quantisation — scales
    are computed on the surviving weights, and the artifact remembers the
    boundary-frame trim in ``keep_frames``.  ``feature_kind`` bakes the DSP
    front-end the model was trained on into the artifact, enabling
    raw-window serving (the jitted forward then starts at the microphone
    samples, not the host-extracted features).
    """
    assert mode in MODES, mode
    if feature_kind is not None:
        from repro.data.features import FEATURE_DIMS

        if feature_kind not in FEATURE_DIMS:
            raise ValueError(f"unknown feature kind {feature_kind!r}")
        if FEATURE_DIMS[feature_kind] != cfg.input_len:
            raise ValueError(
                f"feature kind {feature_kind!r} yields "
                f"{FEATURE_DIMS[feature_kind]}-dim vectors but the model "
                f"takes input_len {cfg.input_len}"
            )
    n_convs = len(cfg.channels)
    names = [f"conv{i}" for i in range(n_convs)] + ["dense0", "dense1"]
    if policy is None:
        modes = {name: mode for name in names}
    else:
        modes = {name: policy.precision_for(f"{name}/w").value for name in names}
    bad = {n: m for n, m in modes.items() if m not in LAYER_MODES}
    assert not bad, f"unsupported layer modes {bad}"

    weights = {name: params[name]["w"] for name in names}
    biases = {name: params[name]["b"] for name in names}
    keep_frames = None
    if prune is not None:
        if prune.flatten_before != cfg.flatten_size:
            raise ValueError(
                f"PruneSpec planned for flatten {prune.flatten_before}, "
                f"model flattens {cfg.flatten_size}"
            )
        # The artifact records the frame trim as a count and the accelerator
        # applies it as a prefix slice, so only boundary trims (a contiguous
        # prefix of frames, what plan_prune produces) can be served — an
        # arbitrary frame subset would silently disagree with the dense rows
        # apply_prune_dense actually kept.
        if not np.array_equal(
            np.asarray(prune.keep_frames), np.arange(len(prune.keep_frames))
        ):
            raise ValueError(
                "PruneSpec.keep_frames must be a contiguous prefix "
                "(boundary-frame trim); arbitrary frame subsets are not "
                "servable"
            )
        last = f"conv{n_convs - 1}"
        weights[last], biases[last] = apply_prune_conv(
            weights[last], biases[last], prune
        )
        weights["dense0"] = apply_prune_dense(
            params["dense0"]["w"], prune, cfg.n_frames, cfg.channels[-1]
        )
        keep_frames = len(prune.keep_frames)

    convs = tuple(
        {
            "w": _prep_weight(weights[f"conv{i}"], modes[f"conv{i}"], axis=2),
            "b": biases[f"conv{i}"].astype(jnp.float32),
        }
        for i in range(n_convs)
    )
    denses = tuple(
        {
            "w": _prep_weight(weights[name], modes[name], axis=1),
            "b": biases[name].astype(jnp.float32),
        }
        for name in ("dense0", "dense1")
    )
    return QuantizedParams(
        mode=mode,
        convs=convs,
        denses=denses,
        conv_modes=tuple(modes[f"conv{i}"] for i in range(n_convs)),
        dense_modes=(modes["dense0"], modes["dense1"]),
        keep_frames=keep_frames,
        feature_kind=feature_kind,
    )


def replicate_params(qp: QuantizedParams, mesh: jax.sharding.Mesh) -> QuantizedParams:
    """Pin every weight leaf onto ``mesh`` fully replicated.

    Sharded-batch dispatch keeps weights on all devices and splits only the
    activation rows; placing the artifact once at engine construction means
    no per-call host->device weight transfers and no accidental re-layout
    inside the jitted sharded forward.
    """
    sharding = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return jax.tree_util.tree_map(lambda leaf: jax.device_put(leaf, sharding), qp)


# ---------------------------------------------------------------------------
# Artifact (de)serialisation — the golden-artifact conformance surface
# ---------------------------------------------------------------------------

_ARTIFACT_VERSION = 1


def save_artifact(path, qp: QuantizedParams) -> None:
    """Write one artifact to ``path`` as an ``.npz`` (arrays + JSON meta).

    bf16 weights are stored as fp32 (a lossless widening — npz has no native
    bfloat16) and re-narrowed on load; int8 payloads/scales are stored raw.
    """
    conv_modes, dense_modes = qp.layer_modes
    arrays: dict[str, np.ndarray] = {}
    meta: dict = {
        "version": _ARTIFACT_VERSION,
        "mode": qp.mode,
        "conv_modes": list(conv_modes),
        "dense_modes": list(dense_modes),
        "keep_frames": qp.keep_frames,
        "feature_kind": qp.feature_kind,
        "scale_axes": {},
    }
    for kind, layers, modes in (
        ("conv", qp.convs, conv_modes),
        ("dense", qp.denses, dense_modes),
    ):
        for i, (layer, lmode) in enumerate(zip(layers, modes)):
            pre = f"{kind}{i}"
            w = layer["w"]
            if lmode in ("int8", "fxp8"):
                assert isinstance(w, QTensor), (pre, type(w))
                arrays[f"{pre}.w_q"] = np.asarray(w.q)
                arrays[f"{pre}.w_scale"] = np.asarray(w.scale, np.float32)
                meta["scale_axes"][pre] = w.axis
            else:
                arrays[f"{pre}.w"] = np.asarray(w, np.float32)
            arrays[f"{pre}.b"] = np.asarray(layer["b"], np.float32)
    arrays["meta"] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), np.uint8
    )
    with open(path, "wb") as f:
        np.savez(f, **arrays)


def load_artifact(path) -> QuantizedParams:
    """Reconstruct a :func:`save_artifact` file as a live artifact."""
    z = np.load(path)
    meta = json.loads(bytes(z["meta"]).decode())
    if meta["version"] != _ARTIFACT_VERSION:
        raise ValueError(f"artifact version {meta['version']} != {_ARTIFACT_VERSION}")

    def layer(pre: str, lmode: str) -> dict:
        if lmode in ("int8", "fxp8"):
            w = QTensor(
                q=jnp.asarray(z[f"{pre}.w_q"]),
                scale=jnp.asarray(z[f"{pre}.w_scale"]),
                axis=meta["scale_axes"][pre],
            )
        elif lmode == "bf16":
            w = jnp.asarray(z[f"{pre}.w"]).astype(jnp.bfloat16)
        else:
            w = jnp.asarray(z[f"{pre}.w"])
        return {"w": w, "b": jnp.asarray(z[f"{pre}.b"])}

    return QuantizedParams(
        mode=meta["mode"],
        convs=tuple(
            layer(f"conv{i}", m) for i, m in enumerate(meta["conv_modes"])
        ),
        denses=tuple(
            layer(f"dense{i}", m) for i, m in enumerate(meta["dense_modes"])
        ),
        conv_modes=tuple(meta["conv_modes"]),
        dense_modes=tuple(meta["dense_modes"]),
        keep_frames=meta["keep_frames"],
        # .get(): pre-front-end artifacts (same version) lack the key
        feature_kind=meta.get("feature_kind"),
    )


class QuantizedParamsCache:
    """Per-deployment-cell memo over one fp32 checkpoint.

    ``cache.get("int8")`` quantises on first use and returns the same
    ``QuantizedParams`` object forever after — the train → quantise once →
    serve lifecycle in one place.  A cell is the full deployment decision
    (mode, prune, policy): asking for the same cell twice never re-quantises,
    asking for a new cell bakes a new artifact.
    """

    def __init__(self, params: dict, cfg: CNNConfig):
        self._params = params
        self._cfg = cfg
        self._by_cell: dict[tuple, QuantizedParams] = {}

    def get(
        self,
        mode: str = "int8",
        *,
        prune: PruneSpec | None = None,
        policy: PrecisionPolicy | None = None,
        feature_kind: str | None = None,
    ) -> QuantizedParams:
        cell = (
            mode,
            prune.cache_key if prune is not None else None,
            policy.to_json() if policy is not None else None,
            feature_kind,
        )
        if cell not in self._by_cell:
            self._by_cell[cell] = quantize_params(
                self._params, self._cfg, mode=mode, prune=prune,
                policy=policy, feature_kind=feature_kind,
            )
        return self._by_cell[cell]
