"""Deterministic fault injection for the fleet supervisor's chaos suite.

A :class:`FaultPlan` is a seeded, JSON-serialisable list of :class:`Fault`
records, each pinned to an ingest round and a target (a global stream for
chunk faults, a worker index for worker faults).  The supervisor consults
the plan at exactly two seams — ``push()`` for chunk faults, and the shared
dispatch core's ``pre_dispatch`` hook (exposed as the engine's
``fault_hook`` property, fired at the top of every
:class:`~repro.serving.batching.DispatchCore` dispatch before anything is
submitted) for worker faults — so a plan replays *identically* on every
run: same seed, same faults, same rounds, same blast radius.  That
determinism is what lets the chaos tests assert bitwise equality of the
unaffected streams instead of "mostly worked".  Routing worker faults
through the core seam means the same harness exercises every server built
on the core, and the core's all-or-nothing dispatch contract is what makes
a faulted round cleanly re-runnable.

Fault kinds and their contracts:

``drop_chunk``
    The chunk never reaches the worker (lossy transport).  Only the target
    stream's windows shift; every other stream is bitwise unaffected.
``corrupt_chunk``
    The chunk's payload is deterministically poisoned with NaN before
    delivery (truncated packet decoded as garbage).  With a reject
    sanitize policy the worker refuses it — same blast radius as a drop.
``jitter_chunk``
    The chunk is split and delivered as two back-to-back pushes
    (re-segmented transport).  Content-preserving: *no* stream's output
    may change, not even the target's.
``raise_forward``
    The worker's forward raises mid-round (driver bug, device loss).
    Lossless: the transactional round plus snapshot/restore recovery must
    leave every stream bitwise identical to the fault-free run.
    ``magnitude`` is the number of *consecutive* dispatch attempts that
    raise (``0``/``1`` = the classic single crash): the supervisor's revive
    path re-runs the round after rebuilding the worker, and a magnitude of
    ``k`` makes the first ``k`` attempts — the original round plus ``k - 1``
    recovery re-runs — fail, modelling a genuinely transient error that
    outlives one rebuild.  Bounded recovery (``max_rebuilds``) must absorb
    every value without the fault ever escaping ``step()``.
``stall_forward``
    The forward hangs past the dispatch deadline; the watchdog abandons it
    (:class:`StalledForward`).  Detected via the supervisor's deadline
    check on the injected clock.  Lossless, like ``raise_forward``.
``kill_worker``
    The worker process dies between rounds; its engine object is gone.
    The supervisor rebuilds from the baked artifact + last-good snapshot +
    journal.  Lossless.

``python -m repro.serving.faults --seed 7 --streams 8 --workers 2
--rounds 20 --out plan.json`` writes a plan for the ``launch/monitor
--faults`` demo.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import threading

import numpy as np

#: chunk faults target one global stream's ingest
CHUNK_KINDS = ("drop_chunk", "jitter_chunk", "corrupt_chunk")
#: worker faults target one worker's scoring round
WORKER_KINDS = ("raise_forward", "stall_forward", "kill_worker")
KINDS = CHUNK_KINDS + WORKER_KINDS

#: kinds that destroy data on their target stream — everything else must be
#: bitwise invisible in the output
LOSSY_KINDS = ("drop_chunk", "corrupt_chunk")


class InjectedFault(RuntimeError):
    """Raised inside a worker round to simulate a crash."""


class StalledForward(InjectedFault):
    """A forward that hung past the dispatch deadline (watchdog fired)."""


class FaultClock:
    """Deterministic stand-in for ``time.monotonic`` so stall detection is
    testable: each ``now()`` ticks a fixed amount, and a stalling fault
    ``advance()``s it past the supervisor's dispatch deadline.

    Lock-protected: with execution lanes every worker thread reads the one
    shared clock concurrently, and a torn ``+=`` would lose a stall's
    ``advance`` and misclassify it as a crash."""

    def __init__(self, start: float = 0.0, tick: float = 1e-4):
        self._t = float(start)
        self._tick = float(tick)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            self._t += self._tick  # time only moves forward
            return self._t

    def advance(self, dt: float):
        with self._lock:
            self._t += float(dt)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault, pinned to an ingest round and a target."""

    kind: str
    round: int
    stream: int | None = None  # chunk faults: global stream id
    worker: int | None = None  # worker faults: worker index
    # jitter: split fraction; stall: hang seconds; raise: consecutive
    # failing dispatch attempts (0/1 = the classic single crash)
    magnitude: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (one of {KINDS})")
        if self.round < 0:
            raise ValueError(f"round must be >= 0, got {self.round}")
        if self.kind in CHUNK_KINDS and self.stream is None:
            raise ValueError(f"{self.kind} needs a target stream")
        if self.kind in WORKER_KINDS and self.worker is None:
            raise ValueError(f"{self.kind} needs a target worker")


@dataclasses.dataclass
class FaultPlan:
    """An ordered set of faults plus the seed that generated them."""

    faults: list[Fault]
    seed: int | None = None

    def __post_init__(self):
        self.faults = [
            f if isinstance(f, Fault) else Fault(**f) for f in self.faults
        ]
        self._chunk: dict[tuple[int, int], Fault] = {}
        self._worker: dict[tuple[int, int], list[Fault]] = {}
        for f in self.faults:
            if f.kind in CHUNK_KINDS:
                # first fault wins on a (round, stream) collision
                self._chunk.setdefault((f.round, f.stream), f)
            else:
                self._worker.setdefault((f.round, f.worker), []).append(f)

    # -- lookups the supervisor uses ----------------------------------------

    def chunk_fault(self, round_: int, stream: int) -> Fault | None:
        return self._chunk.get((round_, stream))

    def worker_faults(self, round_: int, worker: int) -> list[Fault]:
        return self._worker.get((round_, worker), [])

    @property
    def affected_streams(self) -> set[int]:
        """Streams hit by data-destroying faults; every stream NOT in this
        set must be bitwise identical to the fault-free run."""
        return {f.stream for f in self.faults if f.kind in LOSSY_KINDS}

    # -- construction / serialisation ---------------------------------------

    @classmethod
    def generate(
        cls,
        seed: int,
        *,
        n_streams: int,
        n_workers: int,
        n_rounds: int,
        n_faults: int = 6,
        kinds: tuple[str, ...] = KINDS,
    ) -> "FaultPlan":
        """Seeded random plan: same arguments, same plan, every time."""
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            rnd = int(rng.integers(n_rounds))
            if kind in CHUNK_KINDS:
                mag = float(rng.uniform(0.2, 0.8)) if kind == "jitter_chunk" else 0.0
                faults.append(
                    Fault(kind, rnd, stream=int(rng.integers(n_streams)),
                          magnitude=mag)
                )
            else:
                mag = float(rng.uniform(2.0, 10.0)) if kind == "stall_forward" else 0.0
                faults.append(
                    Fault(kind, rnd, worker=int(rng.integers(n_workers)),
                          magnitude=mag)
                )
        faults.sort(key=lambda f: (f.round, KINDS.index(f.kind),
                                   -1 if f.stream is None else f.stream,
                                   -1 if f.worker is None else f.worker))
        return cls(faults, seed=seed)

    def to_json(self) -> str:
        return json.dumps(
            {"seed": self.seed,
             "faults": [dataclasses.asdict(f) for f in self.faults]},
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        return cls([Fault(**f) for f in d["faults"]], seed=d.get("seed"))


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Write a seeded fault plan (JSON) for the chaos demo."
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--faults", type=int, default=6)
    ap.add_argument("--out", default="fault_plan.json")
    args = ap.parse_args(argv)
    plan = FaultPlan.generate(
        args.seed, n_streams=args.streams, n_workers=args.workers,
        n_rounds=args.rounds, n_faults=args.faults,
    )
    with open(args.out, "w") as fh:
        fh.write(plan.to_json())
    print(f"wrote {len(plan.faults)} fault(s) to {args.out}")
    for f in plan.faults:
        target = f"stream {f.stream}" if f.stream is not None else f"worker {f.worker}"
        print(f"  round {f.round:3d}  {f.kind:14s}  {target}")


if __name__ == "__main__":
    main()
