"""Deterministic fault injection for the fleet supervisor's chaos suite.

A :class:`FaultPlan` is a seeded, JSON-serialisable list of :class:`Fault`
records, each pinned to an ingest round and a target (a global stream for
chunk faults, a worker index for worker faults).  The supervisor consults
the plan at exactly two seams — ``push()`` for chunk faults, and the shared
dispatch core's ``pre_dispatch`` hook (exposed as the engine's
``fault_hook`` property, fired at the top of every
:class:`~repro.serving.batching.DispatchCore` dispatch before anything is
submitted) for worker faults — so a plan replays *identically* on every
run: same seed, same faults, same rounds, same blast radius.  That
determinism is what lets the chaos tests assert bitwise equality of the
unaffected streams instead of "mostly worked".  Routing worker faults
through the core seam means the same harness exercises every server built
on the core, and the core's all-or-nothing dispatch contract is what makes
a faulted round cleanly re-runnable.

Fault kinds and their contracts:

``drop_chunk``
    The chunk never reaches the worker (lossy transport).  Only the target
    stream's windows shift; every other stream is bitwise unaffected.
``corrupt_chunk``
    The chunk's payload is deterministically poisoned with NaN before
    delivery (truncated packet decoded as garbage).  With a reject
    sanitize policy the worker refuses it — same blast radius as a drop.
``jitter_chunk``
    The chunk is split and delivered as two back-to-back pushes
    (re-segmented transport).  Content-preserving: *no* stream's output
    may change, not even the target's.
``raise_forward``
    The worker's forward raises mid-round (driver bug, device loss).
    Lossless: the transactional round plus snapshot/restore recovery must
    leave every stream bitwise identical to the fault-free run.
    ``magnitude`` is the number of *consecutive* dispatch attempts that
    raise (``0``/``1`` = the classic single crash): the supervisor's revive
    path re-runs the round after rebuilding the worker, and a magnitude of
    ``k`` makes the first ``k`` attempts — the original round plus ``k - 1``
    recovery re-runs — fail, modelling a genuinely transient error that
    outlives one rebuild.  Bounded recovery (``max_rebuilds``) must absorb
    every value without the fault ever escaping ``step()``.
``stall_forward``
    The forward hangs past the dispatch deadline; the watchdog abandons it
    (:class:`StalledForward`).  Detected via the supervisor's deadline
    check on the injected clock.  Lossless, like ``raise_forward``.
``kill_worker``
    The worker process dies between rounds; its engine object is gone.
    The supervisor rebuilds from the baked artifact + last-good snapshot +
    journal.  Lossless.

Disk faults (``--state-dir`` durability, :mod:`repro.serving.durability`)
enter through the injectable filesystem seam — :class:`FaultyFilesystem`
wraps the production ``LocalFilesystem`` and consults the plan on every
``write``/``fsync`` op.  For these kinds the :class:`Fault` ``round`` field
is the *0-based filesystem operation index* (write ops for the write
kinds, fsync ops for ``slow_fsync``), not an ingest round: disk activity
is not round-synchronous, and an op counter is the deterministic clock the
seam actually has.

``torn_write``
    Only a prefix of the buffer reaches the file, then the write errors —
    a crash mid-write.  ``magnitude`` = surviving fraction (default 0.5).
    WAL replay must truncate the torn tail, never raise.
``bit_flip``
    One bit of the buffer is flipped *silently* (``magnitude`` = bit
    index).  The CRC-32 frame check must catch it on read-back.
``enospc``
    The write fails upfront with ``OSError(ENOSPC)`` (disk full).  The
    supervisor counts the durability degradation and keeps serving.
``slow_fsync``
    The fsync blocks ``magnitude`` seconds (advanced on the injectable
    clock when one is provided) — a saturated device.  Visible only as
    latency.

``python -m repro.serving.faults --seed 7 --streams 8 --workers 2
--rounds 20 --out plan.json`` writes a plan for the ``launch/monitor
--faults`` demo; ``--kinds`` restricts (or extends, e.g. to the disk
kinds) the generated mix and rejects unknown kind names with the full
known list in the error.
"""
from __future__ import annotations

import argparse
import dataclasses
import errno
import json
import threading
import time

import numpy as np

#: chunk faults target one global stream's ingest
CHUNK_KINDS = ("drop_chunk", "jitter_chunk", "corrupt_chunk")
#: worker faults target one worker's scoring round
WORKER_KINDS = ("raise_forward", "stall_forward", "kill_worker")
#: disk faults target the Nth filesystem op on the durability seam
#: (``round`` = op index; no stream/worker target)
DISK_KINDS = ("torn_write", "bit_flip", "enospc", "slow_fsync")
KINDS = CHUNK_KINDS + WORKER_KINDS + DISK_KINDS

#: kinds that destroy data on their target stream — everything else must be
#: bitwise invisible in the output
LOSSY_KINDS = ("drop_chunk", "corrupt_chunk")


class InjectedFault(RuntimeError):
    """Raised inside a worker round to simulate a crash."""


class StalledForward(InjectedFault):
    """A forward that hung past the dispatch deadline (watchdog fired)."""


class FaultClock:
    """Deterministic stand-in for ``time.monotonic`` so stall detection is
    testable: each ``now()`` ticks a fixed amount, and a stalling fault
    ``advance()``s it past the supervisor's dispatch deadline.

    Lock-protected: with execution lanes every worker thread reads the one
    shared clock concurrently, and a torn ``+=`` would lose a stall's
    ``advance`` and misclassify it as a crash."""

    def __init__(self, start: float = 0.0, tick: float = 1e-4):
        self._t = float(start)
        self._tick = float(tick)
        self._lock = threading.Lock()

    def now(self) -> float:
        with self._lock:
            self._t += self._tick  # time only moves forward
            return self._t

    def advance(self, dt: float):
        with self._lock:
            self._t += float(dt)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected fault, pinned to an ingest round and a target."""

    kind: str
    round: int  # ingest round; for DISK_KINDS: filesystem op index
    stream: int | None = None  # chunk faults: global stream id
    worker: int | None = None  # worker faults: worker index
    # jitter: split fraction; stall: hang seconds; raise: consecutive
    # failing dispatch attempts (0/1 = the classic single crash);
    # torn_write: surviving fraction; bit_flip: bit index; slow_fsync:
    # hang seconds
    magnitude: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} (one of {KINDS})")
        if self.round < 0:
            raise ValueError(f"round must be >= 0, got {self.round}")
        if self.kind in CHUNK_KINDS and self.stream is None:
            raise ValueError(f"{self.kind} needs a target stream")
        if self.kind in WORKER_KINDS and self.worker is None:
            raise ValueError(f"{self.kind} needs a target worker")


@dataclasses.dataclass
class FaultPlan:
    """An ordered set of faults plus the seed that generated them."""

    faults: list[Fault]
    seed: int | None = None

    def __post_init__(self):
        self.faults = [
            f if isinstance(f, Fault) else Fault(**f) for f in self.faults
        ]
        self._chunk: dict[tuple[int, int], Fault] = {}
        self._worker: dict[tuple[int, int], list[Fault]] = {}
        self._disk: dict[int, list[Fault]] = {}
        for f in self.faults:
            if f.kind in CHUNK_KINDS:
                # first fault wins on a (round, stream) collision
                self._chunk.setdefault((f.round, f.stream), f)
            elif f.kind in DISK_KINDS:
                self._disk.setdefault(f.round, []).append(f)
            else:
                self._worker.setdefault((f.round, f.worker), []).append(f)

    # -- lookups the supervisor uses ----------------------------------------

    def chunk_fault(self, round_: int, stream: int) -> Fault | None:
        return self._chunk.get((round_, stream))

    def worker_faults(self, round_: int, worker: int) -> list[Fault]:
        return self._worker.get((round_, worker), [])

    def disk_faults(self, op: int) -> list[Fault]:
        """Disk faults pinned to the ``op``-th filesystem operation (see
        :class:`FaultyFilesystem` for which counter each kind consults)."""
        return self._disk.get(op, [])

    @property
    def has_disk_faults(self) -> bool:
        return bool(self._disk)

    @property
    def affected_streams(self) -> set[int]:
        """Streams hit by data-destroying faults; every stream NOT in this
        set must be bitwise identical to the fault-free run."""
        return {f.stream for f in self.faults if f.kind in LOSSY_KINDS}

    # -- construction / serialisation ---------------------------------------

    @classmethod
    def generate(
        cls,
        seed: int,
        *,
        n_streams: int,
        n_workers: int,
        n_rounds: int,
        n_faults: int = 6,
        kinds: tuple[str, ...] = CHUNK_KINDS + WORKER_KINDS,
    ) -> "FaultPlan":
        """Seeded random plan: same arguments, same plan, every time.

        The default mix covers the transport and worker kinds (the fleet
        chaos sweep); pass ``kinds`` explicitly — e.g. ``KINDS`` or just
        ``DISK_KINDS`` — to include disk faults.  Unknown kind names are
        rejected upfront with the full known list, instead of surfacing
        later as a bare lookup error."""
        unknown = [k for k in kinds if k not in KINDS]
        if unknown:
            raise ValueError(
                f"unknown fault kind(s) {unknown} (known kinds: {list(KINDS)})"
            )
        rng = np.random.default_rng(seed)
        faults = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            rnd = int(rng.integers(n_rounds))
            if kind in CHUNK_KINDS:
                mag = float(rng.uniform(0.2, 0.8)) if kind == "jitter_chunk" else 0.0
                faults.append(
                    Fault(kind, rnd, stream=int(rng.integers(n_streams)),
                          magnitude=mag)
                )
            elif kind in DISK_KINDS:
                # round = filesystem op index: disk activity runs several
                # ops per ingest round, so spread over a wider range
                op = int(rng.integers(n_rounds * 8))
                mag = {
                    "torn_write": float(rng.uniform(0.1, 0.9)),
                    "bit_flip": float(rng.integers(0, 256)),
                    "slow_fsync": float(rng.uniform(0.5, 5.0)),
                }.get(kind, 0.0)
                faults.append(Fault(kind, op, magnitude=mag))
            else:
                mag = float(rng.uniform(2.0, 10.0)) if kind == "stall_forward" else 0.0
                faults.append(
                    Fault(kind, rnd, worker=int(rng.integers(n_workers)),
                          magnitude=mag)
                )
        faults.sort(key=lambda f: (f.round, KINDS.index(f.kind),
                                   -1 if f.stream is None else f.stream,
                                   -1 if f.worker is None else f.worker))
        return cls(faults, seed=seed)

    def to_json(self) -> str:
        return json.dumps(
            {"seed": self.seed,
             "faults": [dataclasses.asdict(f) for f in self.faults]},
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        return cls([Fault(**f) for f in d["faults"]], seed=d.get("seed"))


class FaultyFilesystem:
    """Deterministic disk-fault injection on the durability seam.

    Wraps a :class:`~repro.serving.durability.LocalFilesystem` (any object
    with the same duck type) and consults the plan's :meth:`disk faults
    <FaultPlan.disk_faults>` on every ``write`` (op counter ``writes``) and
    every ``fsync`` (op counter ``fsyncs``).  All other operations pass
    straight through.  The same plan replays the same faults at the same
    ops on every run, which is what lets the durability tests assert exact
    truncation/fallback behaviour instead of "eventually recovered".

    Injected faults are recorded in :attr:`injected` as
    ``(kind, op_index)`` pairs."""

    def __init__(self, inner, plan: FaultPlan, clock=None):
        self._inner = inner
        self.plan = plan
        self._clock = clock
        self._lock = threading.Lock()
        self.writes = 0
        self.fsyncs = 0
        self.injected: list[tuple[str, int]] = []

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def write(self, fh, data: bytes) -> int:
        with self._lock:
            op = self.writes
            self.writes += 1
        for f in self.plan.disk_faults(op):
            if f.kind == "enospc":
                self.injected.append((f.kind, op))
                raise OSError(errno.ENOSPC, "injected: no space left on device")
            if f.kind == "torn_write":
                self.injected.append((f.kind, op))
                frac = f.magnitude if 0.0 < f.magnitude < 1.0 else 0.5
                keep = max(1, int(len(data) * frac)) if data else 0
                self._inner.write(fh, data[:keep])
                raise InjectedFault(
                    f"torn write: {keep}/{len(data)} byte(s) reached disk"
                )
            if f.kind == "bit_flip" and data:
                # silent corruption: the write "succeeds"; only the CRC
                # framing can catch it on read-back
                self.injected.append((f.kind, op))
                flipped = bytearray(data)
                bit = int(f.magnitude) % (len(flipped) * 8)
                flipped[bit // 8] ^= 1 << (bit % 8)
                data = bytes(flipped)
        return self._inner.write(fh, data)

    def fsync(self, fh) -> None:
        with self._lock:
            op = self.fsyncs
            self.fsyncs += 1
        for f in self.plan.disk_faults(op):
            if f.kind == "slow_fsync":
                self.injected.append((f.kind, op))
                advance = getattr(self._clock, "advance", None)
                if advance is not None:
                    advance(float(f.magnitude))  # deterministic test clock
                else:
                    # real clock: a token stall, capped so no test hangs
                    time.sleep(min(float(f.magnitude), 0.05))
        self._inner.fsync(fh)


def _parse_kinds(spec: str) -> tuple[str, ...]:
    kinds = tuple(k.strip() for k in spec.split(",") if k.strip())
    unknown = [k for k in kinds if k not in KINDS]
    if unknown:
        raise argparse.ArgumentTypeError(
            f"unknown fault kind(s) {unknown} (known kinds: {list(KINDS)})"
        )
    if not kinds:
        raise argparse.ArgumentTypeError("--kinds needs at least one kind")
    return kinds


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="Write a seeded fault plan (JSON) for the chaos demo."
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--faults", type=int, default=6)
    ap.add_argument("--kinds", type=_parse_kinds,
                    default=CHUNK_KINDS + WORKER_KINDS,
                    help="comma-separated fault kinds to draw from "
                         f"(known: {','.join(KINDS)}; default excludes the "
                         "disk kinds — add them for --state-dir runs)")
    ap.add_argument("--out", default="fault_plan.json")
    args = ap.parse_args(argv)
    plan = FaultPlan.generate(
        args.seed, n_streams=args.streams, n_workers=args.workers,
        n_rounds=args.rounds, n_faults=args.faults, kinds=args.kinds,
    )
    with open(args.out, "w") as fh:
        fh.write(plan.to_json())
    print(f"wrote {len(plan.faults)} fault(s) to {args.out}")
    for f in plan.faults:
        if f.stream is not None:
            target = f"stream {f.stream}"
        elif f.worker is not None:
            target = f"worker {f.worker}"
        else:
            target = "fs op"  # disk fault: round IS the op index
        print(f"  round {f.round:3d}  {f.kind:14s}  {target}")


if __name__ == "__main__":
    main()
