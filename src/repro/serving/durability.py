"""Durable crash-safe serving state: checkpoints + a write-ahead chunk log.

Every recovery mechanism above this module (``last_good`` snapshots, push
journals, revive/splice) lives in process memory: it survives a worker
*fault*, not a process *death*.  A field deployment is duty-cycled and
brown-out-prone — SIGKILL, watchdog restart, power loss — so the mutable
serving state must also live on disk:

* :class:`CheckpointStore` — versioned snapshot files.  Each file is one
  CRC-32-framed record holding a :func:`dumps_state` payload, published
  with the temp-file + ``os.replace`` idiom (``training/checkpoint.py``):
  a reader sees the old bytes or the new bytes, never a torn file.
  Superseded versions are compacted away (``retain`` newest kept), and a
  corrupt newest version falls back to the previous one instead of
  crashing the restart.
* :class:`ChunkWAL` — a per-worker append-only journal of admitted chunks,
  the on-disk twin of the supervisor's in-memory push journal.  Appends are
  CRC-32-framed; the fsync policy (``always`` | ``interval`` | ``never``)
  trades durability of the last few chunks against append latency.
  :meth:`ChunkWAL.replay` verifies every frame CRC and *truncates* the log
  at the first torn or corrupt tail record — the expected end state of a
  crash mid-append — instead of raising.
* :func:`dumps_state` / :func:`loads_state` — an exact byte codec for the
  engine's ``snapshot()`` payloads: numpy arrays keep dtype and shape
  bit-for-bit (``.npy`` framing), scalar counters and
  :class:`~repro.serving.tracker.TrackEvent` records round-trip through a
  JSON skeleton (Python's shortest-repr floats make that exact too).  The
  bitwise cold-restart contract in ``tests/test_durability.py`` rests on
  this codec being lossless.
* :class:`LocalFilesystem` — the injectable seam every byte passes through.
  Production uses this thin ``os`` wrapper; the chaos harness wraps it in
  :class:`~repro.serving.faults.FaultyFilesystem` to inject deterministic
  torn writes, bit flips, ENOSPC and slow fsyncs.

Nothing here imports the engine or the supervisor: this module is the
bottom of the durability stack and is reused by both.
"""
from __future__ import annotations

import io
import json
import os
import struct
import typing
import zlib

import numpy as np

from repro.serving.tracker import TrackEvent

#: WAL/checkpoint fsync policies (see :class:`ChunkWAL`)
FSYNC_POLICIES = ("always", "interval", "never")

#: one frame = <payload length u32, CRC-32 of payload u32> + payload
FRAME_HEADER = struct.Struct("<II")

#: WAL record header inside a frame: global stream id, per-stream push
#: sequence number, ingest round at push time, flags
_WAL_HEADER = struct.Struct("<IIII")

#: WAL record flags: FAULTED = this record accounts for one transport-level
#: chunk fault (replay re-increments the supervisor's ``faulted_chunks``);
#: DROPPED = marker only — the fault ate the chunk, nothing to push (the
#: marker keeps the per-stream delivery cursor and fault counter exact
#: across a crash).
WAL_FAULTED = 0x1
WAL_DROPPED = 0x2


class CorruptRecord(ValueError):
    """A framed record failed its CRC / structure check."""


# -- CRC-32 record framing ----------------------------------------------------

def frame(payload: bytes) -> bytes:
    """Wrap a payload in the length+CRC frame both stores use."""
    return FRAME_HEADER.pack(len(payload), zlib.crc32(payload)) + payload


def read_frames(blob: bytes) -> tuple[list[bytes], int]:
    """Parse consecutive frames; returns ``(payloads, clean_length)``.

    Stops — without raising — at the first torn header, short payload, or
    CRC mismatch: ``clean_length`` is the byte offset of the first bad
    frame, i.e. everything before it verified.  ``clean_length <
    len(blob)`` is how a caller detects a torn/corrupt tail.
    """
    out: list[bytes] = []
    off = 0
    while off + FRAME_HEADER.size <= len(blob):
        n, crc = FRAME_HEADER.unpack_from(blob, off)
        start = off + FRAME_HEADER.size
        end = start + n
        if end > len(blob):
            break  # torn tail: frame promises more bytes than exist
        payload = blob[start:end]
        if zlib.crc32(payload) != crc:
            break  # corrupt frame (bit rot / flipped bits)
        out.append(payload)
        off = end
    return out, off


# -- exact state codec --------------------------------------------------------

def _encode(obj, arrays: list[np.ndarray]):
    if isinstance(obj, np.ndarray):
        arrays.append(obj)
        return {"t": "nd", "i": len(arrays) - 1}
    if isinstance(obj, TrackEvent):
        return {
            "t": "ev",
            "v": [obj.onset_idx, obj.offset_idx, obj.peak_score,
                  obj.mean_score],
        }
    if isinstance(obj, np.bool_):
        return {"t": "s", "v": bool(obj)}
    if isinstance(obj, np.integer):
        return {"t": "np", "d": str(obj.dtype), "v": int(obj)}
    if isinstance(obj, np.floating):
        return {"t": "np", "d": str(obj.dtype), "v": float(obj)}
    if isinstance(obj, dict):
        # tagged pairs, not a JSON object: integer keys (eviction stashes
        # are keyed by global stream id) must survive the round-trip
        return {
            "t": "d",
            "v": [[_encode(k, arrays), _encode(v, arrays)]
                  for k, v in obj.items()],
        }
    if isinstance(obj, tuple):
        return {"t": "tu", "v": [_encode(x, arrays) for x in obj]}
    if isinstance(obj, list):
        return {"t": "l", "v": [_encode(x, arrays) for x in obj]}
    if isinstance(obj, set):
        return {"t": "set", "v": [_encode(x, arrays) for x in sorted(obj)]}
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return {"t": "s", "v": obj}
    raise TypeError(f"dumps_state cannot serialise {type(obj).__name__}")


def _decode(node, arrays: list[np.ndarray]):
    t = node["t"]
    if t == "nd":
        return arrays[node["i"]]
    if t == "ev":
        on, off, peak, mean = node["v"]
        return TrackEvent(onset_idx=int(on), offset_idx=int(off),
                          peak_score=float(peak), mean_score=float(mean))
    if t == "np":
        return np.dtype(node["d"]).type(node["v"])
    if t == "d":
        return {
            _decode(k, arrays): _decode(v, arrays) for k, v in node["v"]
        }
    if t == "tu":
        return tuple(_decode(x, arrays) for x in node["v"])
    if t == "l":
        return [_decode(x, arrays) for x in node["v"]]
    if t == "set":
        return {_decode(x, arrays) for x in node["v"]}
    if t == "s":
        return node["v"]
    raise CorruptRecord(f"unknown state-codec tag {t!r}")


def dumps_state(obj) -> bytes:
    """Serialise a (possibly nested) state payload to bytes, exactly.

    Arrays are written in ``.npy`` framing (dtype, shape and byte order
    preserved bit-for-bit, including bool and float64); everything else —
    ints, floats, strings, ``TrackEvent``s, dicts with non-string keys,
    tuples, sets — rides a tagged JSON skeleton.  ``loads_state`` is the
    exact inverse: the serialisation round-trip tests pin ``==`` on every
    field, not closeness."""
    arrays: list[np.ndarray] = []
    skeleton = json.dumps(
        _encode(obj, arrays), separators=(",", ":")
    ).encode()
    buf = io.BytesIO()
    buf.write(struct.pack("<II", len(skeleton), len(arrays)))
    buf.write(skeleton)
    for a in arrays:
        np.lib.format.write_array(
            buf, np.ascontiguousarray(a), version=(1, 0), allow_pickle=False
        )
    return buf.getvalue()


def loads_state(data: bytes):
    """Inverse of :func:`dumps_state`; raises :class:`CorruptRecord` on any
    structural damage (a CRC frame normally catches that first)."""
    try:
        buf = io.BytesIO(data)
        n_skel, n_arrays = struct.unpack("<II", buf.read(8))
        skeleton = json.loads(buf.read(n_skel).decode())
        arrays = [
            np.lib.format.read_array(buf, allow_pickle=False)
            for _ in range(n_arrays)
        ]
        return _decode(skeleton, arrays)
    except CorruptRecord:
        raise
    except Exception as exc:  # struct/json/npy damage -> one error type
        raise CorruptRecord(f"undecodable state payload: {exc}") from exc


# -- filesystem seam ----------------------------------------------------------

class LocalFilesystem:
    """The injectable filesystem seam all durable I/O goes through.

    Production code uses this thin wrapper over ``os``; the chaos harness
    substitutes :class:`~repro.serving.faults.FaultyFilesystem` (same duck
    type) to inject deterministic disk faults at the ``write``/``fsync``
    ops.  Keeping the surface small — open/write/fsync/replace and a few
    directory ops — is what makes the fault injection exhaustive."""

    def makedirs(self, path: str) -> None:
        os.makedirs(path, exist_ok=True)

    def exists(self, path: str) -> bool:
        return os.path.exists(path)

    def listdir(self, path: str) -> list[str]:
        try:
            return sorted(os.listdir(path))
        except FileNotFoundError:
            return []

    def remove(self, path: str) -> None:
        try:
            os.remove(path)
        except FileNotFoundError:
            pass

    def read_bytes(self, path: str) -> bytes:
        with open(path, "rb") as fh:
            return fh.read()

    def open_write(self, path: str):
        return open(path, "wb")

    def open_append(self, path: str):
        return open(path, "ab")

    def write(self, fh, data: bytes) -> int:
        return fh.write(data)

    def fsync(self, fh) -> None:
        fh.flush()
        os.fsync(fh.fileno())

    def close(self, fh) -> None:
        fh.close()

    def replace(self, src: str, dst: str) -> None:
        os.replace(src, dst)

    def truncate(self, path: str, size: int) -> None:
        with open(path, "r+b") as fh:
            fh.truncate(size)


def write_atomic(fs, path: str, data: bytes) -> None:
    """Temp-file + fsync + rename publish: a reader (or a restart) sees the
    old bytes or the new bytes, never a torn file.  On a failed write the
    temp file is removed and the published file is untouched."""
    tmp = path + ".tmp"
    try:
        fh = fs.open_write(tmp)
        try:
            fs.write(fh, data)
            fs.fsync(fh)
        finally:
            fs.close(fh)
    except BaseException:
        fs.remove(tmp)
        raise
    fs.replace(tmp, path)


# -- versioned checkpoint store -----------------------------------------------

class CheckpointStore:
    """Versioned, CRC-framed, atomically-published snapshot files.

    One file per version (``ckpt-<version>.bin``), each a single framed
    :func:`dumps_state` record.  ``save`` publishes atomically then
    compacts superseded versions down to ``retain``; ``load_latest`` walks
    versions newest-first and *skips* corrupt files (counted in
    ``corrupt_skipped``) so one damaged checkpoint degrades to the previous
    version instead of a crash."""

    PREFIX = "ckpt-"
    SUFFIX = ".bin"

    def __init__(self, root: str, *, fs=None, retain: int = 2):
        if retain < 1:
            raise ValueError(f"retain must be >= 1, got {retain}")
        self.root = root
        self.fs = fs if fs is not None else LocalFilesystem()
        self.retain = int(retain)
        self.corrupt_skipped = 0
        self.fs.makedirs(root)

    def _path(self, version: int) -> str:
        return os.path.join(
            self.root, f"{self.PREFIX}{int(version):010d}{self.SUFFIX}"
        )

    def versions(self) -> list[int]:
        out = []
        for name in self.fs.listdir(self.root):
            if name.startswith(self.PREFIX) and name.endswith(self.SUFFIX):
                try:
                    out.append(int(name[len(self.PREFIX):-len(self.SUFFIX)]))
                except ValueError:
                    continue
        return sorted(out)

    def save(self, version: int, payload) -> str:
        """Atomically publish ``payload`` as ``version``; compacts after."""
        path = self._path(version)
        write_atomic(self.fs, path, frame(dumps_state(payload)))
        self.compact()
        return path

    def load(self, version: int):
        """Load one version; raises :class:`CorruptRecord` if the file is
        torn, bit-rotted, or structurally damaged."""
        blob = self.fs.read_bytes(self._path(version))
        payloads, clean = read_frames(blob)
        if len(payloads) != 1 or clean != len(blob):
            raise CorruptRecord(
                f"checkpoint version {version} failed CRC framing "
                f"({clean}/{len(blob)} clean byte(s))"
            )
        return loads_state(payloads[0])

    def load_latest(self, *, at_or_before: int | None = None):
        """Newest valid ``(version, payload)``, or ``None`` when nothing
        loads.  ``at_or_before`` pins the search below a known version (the
        fleet meta's pinned version on restore: a newer orphan checkpoint —
        written just before the crash, never referenced by any meta — must
        not be resurrected)."""
        for v in reversed(self.versions()):
            if at_or_before is not None and v > at_or_before:
                continue
            try:
                return v, self.load(v)
            except (OSError, CorruptRecord):
                self.corrupt_skipped += 1
        return None

    def compact(self) -> None:
        """Drop superseded versions beyond the newest ``retain``."""
        for v in self.versions()[: -self.retain]:
            self.fs.remove(self._path(v))


# -- write-ahead chunk journal ------------------------------------------------

class WALRecord(typing.NamedTuple):
    stream: int  # global stream id
    seq: int  # per-stream push sequence number at push time
    round: int  # ingest round at push time
    flags: int  # WAL_FAULTED / WAL_DROPPED
    chunk: np.ndarray  # float32 payload (empty for DROPPED markers)


class ChunkWAL:
    """Append-only, CRC-framed journal of one worker's admitted chunks.

    The on-disk twin of the supervisor's in-memory push journal: every
    delivered chunk is appended *before* it reaches the engine, so the
    state at any crash instant is reconstructible as
    ``checkpoint + replay(wal)``.

    fsync policy:

    * ``always`` — fsync after every append: nothing acknowledged is ever
      lost, at ~one disk flush per chunk.
    * ``interval`` — fsync every ``fsync_interval`` appends: bounds the
      loss window to the last few chunks (the OS page cache still makes
      them visible to a same-host restart that didn't lose power).
    * ``never`` — leave flushing to the OS entirely.

    :meth:`replay` verifies every frame CRC and truncates the file at the
    first torn/corrupt tail record — counted in :attr:`truncations`, never
    raised — because a crash mid-append *routinely* leaves a half-written
    final frame."""

    def __init__(self, path: str, *, fs=None, fsync: str = "interval",
                 fsync_interval: int = 8):
        if fsync not in FSYNC_POLICIES:
            raise ValueError(
                f"fsync policy must be one of {FSYNC_POLICIES}, got {fsync!r}"
            )
        if fsync_interval < 1:
            raise ValueError(
                f"fsync_interval must be >= 1, got {fsync_interval}"
            )
        self.path = path
        self.fs = fs if fs is not None else LocalFilesystem()
        self.fsync = fsync
        self.fsync_interval = int(fsync_interval)
        self.fs.makedirs(os.path.dirname(path) or ".")
        self._fh = None
        self._since_sync = 0
        self.appended = 0  # records appended over this object's lifetime
        self.truncations = 0  # torn/corrupt tails truncated by replay()

    def append(self, *, stream: int, seq: int, round_: int,
               chunk: np.ndarray | None = None, flags: int = 0) -> None:
        """Append one framed record (chunk may be None for marker records)
        and fsync per policy.  Raises OSError/InjectedFault upward on a
        disk fault — the caller decides whether durability degradation is
        fatal (the supervisor counts it and keeps serving)."""
        payload = _WAL_HEADER.pack(int(stream), int(seq), int(round_),
                                   int(flags))
        if chunk is not None:
            payload += np.ascontiguousarray(chunk, np.float32).tobytes()
        if self._fh is None:
            self._fh = self.fs.open_append(self.path)
        self.fs.write(self._fh, frame(payload))
        self.appended += 1
        if self.fsync == "always":
            self.fs.fsync(self._fh)
        elif self.fsync == "interval":
            self._since_sync += 1
            if self._since_sync >= self.fsync_interval:
                self.fs.fsync(self._fh)
                self._since_sync = 0

    def sync(self) -> None:
        if self._fh is not None:
            self.fs.fsync(self._fh)
            self._since_sync = 0

    def replay(self) -> list[WALRecord]:
        """Parse the journal back into records, truncating any torn or
        corrupt tail in place (the file is cut back to its last clean
        frame; :attr:`truncations` counts it).  Never raises on damage."""
        self._close_handle()
        if not self.fs.exists(self.path):
            return []
        blob = self.fs.read_bytes(self.path)
        payloads, clean = read_frames(blob)
        records: list[WALRecord] = []
        off = 0
        for p in payloads:
            if len(p) < _WAL_HEADER.size or (
                (len(p) - _WAL_HEADER.size) % 4 != 0
            ):
                # CRC-valid but structurally short: treat as damage from
                # this record on (defensive; framing bugs, not bit rot)
                clean = off
                break
            stream, seq, rnd, flags = _WAL_HEADER.unpack_from(p)
            chunk = np.frombuffer(p[_WAL_HEADER.size:], np.float32).copy()
            records.append(WALRecord(stream, seq, rnd, flags, chunk))
            off += FRAME_HEADER.size + len(p)
        if clean < len(blob):
            self.truncations += 1
            self.fs.truncate(self.path, clean)
        return records

    def reset(self) -> None:
        """Start a fresh journal (called right after a checkpoint makes the
        current one redundant).  Removal is atomic; a crash between the
        checkpoint publish and this reset leaves stale records whose
        sequence numbers the restore path filters out."""
        self._close_handle()
        self.fs.remove(self.path)
        self._since_sync = 0

    def _close_handle(self) -> None:
        if self._fh is not None:
            try:
                self.fs.close(self._fh)
            finally:
                self._fh = None

    def close(self) -> None:
        """Flush (per policy — ``never`` stays unflushed) and close."""
        if self._fh is not None and self.fsync != "never":
            try:
                self.fs.fsync(self._fh)
            except OSError:
                pass
        self._close_handle()
