"""Fault-tolerant fleet supervisor over a pool of monitor engines.

A field deployment runs for weeks: microphones emit garbage, a driver bug
raises mid-forward, a dispatch hangs, a worker process dies.  The
supervisor keeps the *fleet* alive through all of it while preserving the
repo's central numeric contract — per-sample activation scales make every
window's score independent of its co-batch, so recovery can be held to a
bitwise standard, not a tolerance:

* **worker pool** — global streams are partitioned into contiguous groups,
  one :class:`~repro.serving.engine.MonitorEngine` per group, all built
  from the *same immutable baked artifact* (weights are never part of any
  recovery path, so rebuilding a worker is cheap and exact);
* **execution lanes** — with ``lanes="threads"`` every worker gets a named
  lane thread that runs its engine's ingest→dispatch→harvest beat, so one
  worker's host feature extraction overlaps another worker's device
  scoring through the dispatch core's in-flight rotation.  Ingest enters a
  shared front-of-fleet :class:`~repro.serving.batching.IngestQueue` and
  is routed to workers through the ``_route`` table at the top of each
  round on the supervisor thread, so delivery (admission, chunk faults,
  journaling) is identical to the sequential fleet; fleet-level mutations
  (eviction, retirement, spawning) are deferred to the supervisor thread
  at the end of the round.  Per-stream outputs are bitwise equal across
  {lane-parallel fleet, sequential fleet, monolithic engine} — the lane
  conformance tests pin all three, with and without fault plans;
* **health** — each worker carries a heartbeat (clock time of its last
  successful round); a round that overruns ``dispatch_deadline_s`` on the
  supervisor's clock is classified as a *stall* rather than a crash;
* **crash recovery** — after every successful round a worker's state is
  snapshotted (``last_good``) and its push journal cleared; on a crash,
  stall, or kill the supervisor rebuilds the engine from the artifact,
  ``restore``s ``last_good``, replays the journal (chunks pushed since the
  snapshot), and re-runs the round.  The transactional
  :meth:`~repro.serving.engine.MonitorEngine.step` guarantees the failed
  attempt committed nothing, so the re-run scores the *same* windows —
  recovery is lossless and bitwise.  The re-run happens *inside* the same
  revive/retire loop, so a second consecutive failure (or a transient
  error during the recovery re-run itself) is absorbed the same way,
  bounded by ``max_rebuilds`` — ``step()`` never raises on worker faults;
* **reassignment** — a worker that keeps dying (``rebuilds >
  max_rebuilds``) is retired: its revived per-stream state (ring
  snapshots, tracker arrays, events, counters) is spliced into a surviving
  worker rebuilt for the combined stream set.  The migrated streams keep
  their exact EMA trajectories and window indices, so even a permanently
  dead worker costs zero samples and zero numeric drift;
* **durability** — with ``state_dir`` the same ``last_good`` + journal
  machinery is mirrored to disk (:mod:`repro.serving.durability`): each
  worker's snapshots go to a versioned CRC-framed checkpoint store, every
  delivered chunk is appended to a per-worker write-ahead journal *before*
  it reaches the engine, and a fleet meta-checkpoint — always written last,
  always the restore authority — pins topology, counters, admission state
  and per-worker checkpoint versions.  :meth:`restore_from_dir` rebuilds
  the fleet after a SIGKILL / power loss from artifact + newest valid meta
  + pinned checkpoints + WAL replay (torn tails truncated, never raised);
  the driver then re-delivers each stream from the restored
  ``pushed_chunks`` cursor and the resumed run is bitwise identical to an
  uninterrupted one (``tests/test_durability.py`` pins this cold-restart
  contract; disk faults are injected through the
  :class:`~repro.serving.faults.FaultyFilesystem` seam);
* **elasticity** — the same snapshot/splice machinery powers deliberate
  resizing for the SLO loop (:mod:`repro.serving.controller`):
  :meth:`spawn_worker` splits the most-loaded worker's streams into a new
  worker, :meth:`retire_worker` folds a worker back into the survivors,
  and :meth:`retune_admission` swaps the fleet's admission budgets — all
  bitwise lossless for every stream.

Fault injection (:mod:`repro.serving.faults`) enters through exactly two
seams — chunk faults in :meth:`push`, worker faults via the engine's
``fault_hook`` — and is ``None`` in production.  Worker faults are keyed on
``(round, worker)`` and each worker's beat runs in its own named lane, so a
plan injects deterministically into the same lane with and without
concurrency.  The chaos suite in ``tests/test_fault_tolerance.py`` drives
seeded plans through this class and asserts the fleet never crashes and
unaffected streams are bitwise identical to a fault-free run.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time

import numpy as np

from repro.models.cnn1d import CNNConfig
from repro.serving.batching import AdmissionPolicy, IngestQueue
from repro.serving.durability import (
    WAL_DROPPED,
    WAL_FAULTED,
    CheckpointStore,
    ChunkWAL,
    LocalFilesystem,
)
from repro.serving.engine import MonitorEngine, WindowScore
from repro.serving.faults import (
    FaultPlan,
    FaultyFilesystem,
    InjectedFault,
    StalledForward,
)
from repro.serving.quantized_params import QuantizedParams
from repro.serving.tracker import TrackEvent

#: engine counters that describe the whole engine's history (scalars), as
#: opposed to the per-stream arrays; a spawned worker starts these at zero
#: so fleet-level sums stay conserved across a split.
_SCALAR_COUNTERS = (
    "windows_scored", "forward_calls", "padded_slots", "rounds",
    "dropped_samples",
)


class _Worker:
    """Bookkeeping for one engine in the pool (not part of the public API)."""

    def __init__(self, idx: int, engine: MonitorEngine | None,
                 streams: list[int]):
        self.idx = idx
        self.engine: MonitorEngine | None = engine
        self.streams = list(streams)  # global ids; position = local stream id
        # state after the last good round (None only for a worker being
        # rebuilt dead from the durable meta-checkpoint)
        self.last_good = None if engine is None else engine.snapshot()
        self.journal: list[tuple[int, np.ndarray]] = []  # pushes since then
        # per-global-stream delivery cursor / transport-fault count at the
        # moment last_good was taken: a durable checkpoint of last_good must
        # pin the same cursor, or WAL replay and driver re-delivery would
        # double- or under-apply chunks after a cold restart
        self.good_pushed: dict[int, int] = {int(g): 0 for g in self.streams}
        self.good_faulted: dict[int, int] = {int(g): 0 for g in self.streams}
        self.rebuilds = 0
        self.alive = True
        self.last_heartbeat: float | None = None
        # Deferred fleet-level actions: a lane must never splice streams into
        # another worker (its lane may be mid-round), so eviction and
        # retirement are recorded here and applied by the supervisor thread
        # at the end of the round.
        self.pending_evict: list[int] = []
        self.retire_pending = False


class _ExecutionLane:
    """One worker's execution lane: a named daemon thread that runs the
    worker's round beat when the supervisor signals it, independently of
    every other lane.  The lane name (``lane-<worker>``) shows up in
    faulthandler dumps and ties fault injection — keyed on the worker
    index — to the thread that executes it."""

    def __init__(self, idx: int):
        self.name = f"lane-{idx}"
        self._work: queue.SimpleQueue = queue.SimpleQueue()
        self._done: queue.SimpleQueue = queue.SimpleQueue()
        self._thread = threading.Thread(
            target=self._loop, name=self.name, daemon=True
        )
        self._thread.start()

    def submit(self, fn, *args) -> None:
        self._work.put((fn, args))

    def result(self):
        ok, val = self._done.get()
        if ok:
            return val
        raise val

    def _loop(self):
        while True:
            item = self._work.get()
            if item is None:
                return
            fn, args = item
            try:
                self._done.put((True, fn(*args)))
            except BaseException as exc:  # noqa: BLE001 — relayed to caller
                self._done.put((False, exc))

    def close(self):
        self._work.put(None)
        self._thread.join(timeout=5.0)


class _LanePool:
    """The fleet's named execution lanes, one per worker index.  Lanes are
    created on demand (spawned workers get a fresh lane) and retired lanes
    simply idle — a lane is only ever driven by the supervisor thread."""

    def __init__(self):
        self._lanes: dict[int, _ExecutionLane] = {}

    def ensure(self, idx: int) -> None:
        if idx not in self._lanes:
            self._lanes[idx] = _ExecutionLane(idx)

    def name(self, idx: int) -> str | None:
        lane = self._lanes.get(idx)
        return None if lane is None else lane.name

    def submit(self, idx: int, fn, *args) -> None:
        self._lanes[idx].submit(fn, *args)

    def result(self, idx: int):
        return self._lanes[idx].result()

    def close(self):
        for lane in self._lanes.values():
            lane.close()
        self._lanes.clear()


def _merge_snapshots(dst: dict, src: dict) -> dict:
    """Splice ``src``'s per-stream state after ``dst``'s: the combined
    snapshot restores into an engine built for the combined stream count.
    Per-stream fields concatenate; whole-engine counters add; pending
    eviction ids (local stream indices) are re-based onto the combined
    numbering."""
    tracker = {
        k: (dst["tracker"][k] + src["tracker"][k]
            if k == "events"
            else np.concatenate([dst["tracker"][k], src["tracker"][k]]))
        for k in dst["tracker"]
    }
    counters = {}
    for k, v in dst["counters"].items():
        sv = src["counters"][k]
        counters[k] = (
            np.concatenate([v, sv]) if isinstance(v, np.ndarray) else v + sv
        )
    n_dst = len(dst["rings"])
    pending = list(dst.get("pending_evictions", [])) + [
        n_dst + int(l) for l in src.get("pending_evictions", [])
    ]
    return {
        "rings": list(dst["rings"]) + list(src["rings"]),
        "pending_evictions": pending,
        "tracker": tracker,
        "counters": counters,
    }


def _subset_snapshot(snap: dict, keep: list[int], *, zero_scalars: bool = False) -> dict:
    """Project a snapshot onto the ``keep`` local-stream indices (in order):
    the inverse of :func:`_merge_snapshots`, used when eviction removes
    streams from a worker and when :meth:`FleetSupervisor.spawn_worker`
    splits one.  Per-stream fields are sliced; pending eviction ids are
    remapped (dropped streams' pending evictions vanish with them);
    whole-engine scalar counters are kept as-is (they describe the engine's
    history, which includes the departed streams) unless ``zero_scalars``
    — the spawn path zeroes them on the spun-off half so fleet-level sums
    stay conserved."""
    tracker = {
        k: ([snap["tracker"][k][i] for i in keep]
            if k == "events"
            else np.asarray(snap["tracker"][k])[keep])
        for k in snap["tracker"]
    }
    counters = {}
    for k, v in snap["counters"].items():
        if isinstance(v, np.ndarray):
            counters[k] = np.asarray(v)[keep]
        else:
            counters[k] = 0 if (zero_scalars and k in _SCALAR_COUNTERS) else v
    remap = {int(old): new for new, old in enumerate(keep)}
    pending = [
        remap[int(l)]
        for l in snap.get("pending_evictions", [])
        if int(l) in remap
    ]
    return {
        "rings": [snap["rings"][i] for i in keep],
        "pending_evictions": pending,
        "tracker": tracker,
        "counters": counters,
    }


class FleetSupervisor:
    """Health-checked pool of monitor engines with lossless recovery.

    Parameters
    ----------
    artifact:
        A pre-baked :class:`QuantizedParams`.  The supervisor deliberately
        refuses an fp32 checkpoint: workers must be rebuildable from an
        immutable shared artifact, and quantise-once is what makes a
        rebuilt worker numerically identical to the dead one.
    n_streams / n_workers:
        Global stream count, partitioned contiguously over the workers.
    lanes:
        ``None`` (default) steps the workers sequentially on the caller's
        thread.  ``"threads"`` gives each worker a named execution lane:
        all live workers' round beats run concurrently (host feature
        extraction for one overlaps device scoring for another) and
        :meth:`push` becomes a non-blocking enqueue onto a shared ingest
        queue drained at the top of each round.  Per-stream results are
        bitwise identical either way.
    dispatch_deadline_s:
        A worker round that takes longer than this (on ``clock``) is
        classified as a stall in the incident log.
    max_rebuilds:
        After this many revivals a worker is retired and its streams are
        migrated (statefully, bitwise) to the least-loaded survivor.
    clock:
        Zero-arg monotonic-seconds callable, or an object with ``now()``
        (e.g. :class:`~repro.serving.faults.FaultClock` in tests).
    faults:
        Optional :class:`FaultPlan` — the deterministic chaos harness.
        ``None`` (production) makes every fault seam a no-op.  A plan with
        disk faults auto-wraps the filesystem seam in
        :class:`~repro.serving.faults.FaultyFilesystem` (unless ``fs`` is
        given explicitly).
    state_dir:
        Directory for durable crash-safe state (``None`` = in-memory
        recovery only).  Each worker gets a versioned
        :class:`~repro.serving.durability.CheckpointStore` of its
        ``last_good`` snapshots plus a
        :class:`~repro.serving.durability.ChunkWAL` of delivered chunks;
        a ``fleet/`` meta-checkpoint pins the topology, counters and
        checkpoint versions.  Restart via :meth:`restore_from_dir`.
    fs / fsync / fsync_interval / checkpoint_interval / retain_checkpoints:
        Durability knobs (with ``state_dir``): the injectable filesystem
        seam, the WAL fsync policy (``always`` | ``interval`` | ``never``),
        checkpoint cadence in rounds (1 = every round, the exact-restart
        setting), and how many checkpoint versions to keep per store.
    """

    def __init__(
        self,
        artifact: QuantizedParams,
        cfg: CNNConfig,
        *,
        n_streams: int,
        n_workers: int = 2,
        lanes: str | None = None,
        dispatch_deadline_s: float = 30.0,
        max_rebuilds: int = 3,
        clock=None,
        faults: FaultPlan | None = None,
        state_dir: str | None = None,
        fs=None,
        fsync: str = "interval",
        fsync_interval: int = 8,
        checkpoint_interval: int = 1,
        retain_checkpoints: int = 3,
        **engine_kw,
    ):
        if not isinstance(artifact, QuantizedParams):
            raise ValueError(
                "FleetSupervisor requires a pre-baked QuantizedParams "
                "artifact (quantize_params(...)): worker recovery rebuilds "
                "engines from it, so it must be immutable and shared"
            )
        if n_streams < 1:
            raise ValueError(f"n_streams must be >= 1, got {n_streams}")
        if not 1 <= n_workers <= n_streams:
            raise ValueError(
                f"n_workers must be in 1..{n_streams} (one stream per worker "
                f"minimum), got {n_workers}"
            )
        if dispatch_deadline_s <= 0:
            raise ValueError(
                f"dispatch_deadline_s must be positive, got {dispatch_deadline_s}"
            )
        if lanes not in (None, "threads"):
            raise ValueError(
                f"lanes must be None (sequential) or 'threads', got {lanes!r}"
            )
        self._qp = artifact
        self.cfg = cfg
        self.n_streams = n_streams
        self.dispatch_deadline_s = float(dispatch_deadline_s)
        self.max_rebuilds = int(max_rebuilds)
        self._engine_kw = dict(engine_kw)
        self._clock_obj = clock if clock is not None else time.monotonic
        self._now = getattr(self._clock_obj, "now", self._clock_obj)
        self.faults = faults
        self.round = 0  # ingest/scoring round counter (fault plans key on it)
        self.incidents: list[dict] = []
        self._incident_lock = threading.Lock()
        # chunk-fault observability (distinct from the engines' sanitize
        # counters: these count what the *transport* did, per global stream)
        self.faulted_chunks = np.zeros(n_streams, np.int64)
        # Fleet-level admission: ``max_streams`` is a *fleet* cap, so the
        # first-come gate lives here (workers would otherwise each admit
        # their first max_streams local streams); the rest of the policy —
        # per-round fairness budget, overflow eviction — stays per worker
        # and travels down via engine_kw.  Evicted streams are removed from
        # their worker outright (the reassignment machinery, in reverse);
        # pushes to refused or evicted streams are counted and dropped.
        adm = self._engine_kw.get("admission")
        self._max_streams = None if adm is None else adm.max_streams
        if self._max_streams is not None:
            self._engine_kw["admission"] = dataclasses.replace(
                adm, max_streams=None
            )
        self._seen: set[int] = set()
        self._refused: set[int] = set()
        self.evicted: set[int] = set()
        self.refused_chunks = np.zeros(n_streams, np.int64)
        self._evicted_events: dict[int, list[TrackEvent]] = {}
        # Final per-stream counter totals of evicted streams, stashed at
        # eviction time so ``served_windows``/``deferred_windows`` keep
        # reporting them after the worker is rebuilt without the stream.
        self._final_counters: dict[int, dict[str, int]] = {}

        # -- durable state (checkpoints + write-ahead chunk journals) ------
        # ``pushed_chunks`` is the per-global-stream delivery cursor: every
        # driver push attempt (admitted, faulted, refused) advances it, so a
        # restarted driver knows exactly which chunks the restored state
        # already embeds and re-delivers only the rest.
        if checkpoint_interval < 1:
            raise ValueError(
                f"checkpoint_interval must be >= 1, got {checkpoint_interval}"
            )
        self.state_dir = state_dir
        self.checkpoint_interval = int(checkpoint_interval)
        self._fsync = fsync
        self._fsync_interval = int(fsync_interval)
        self._retain_checkpoints = int(retain_checkpoints)
        self.pushed_chunks = np.zeros(n_streams, np.int64)
        self.replayed_chunks = 0  # chunks rebuilt from WAL on restore
        self.wal_errors = 0  # WAL appends/resets lost to disk faults
        self.ckpt_errors = 0  # checkpoint saves/loads lost to disk faults
        self._ckpt_seq = 0  # monotonic version shared by worker+fleet ckpts
        self._ckpt_versions: dict[int, int] = {}  # worker -> last saved ver
        self._splice_dirty = False  # topology changed since the last persist
        self._fs = None
        self._fleet_store: CheckpointStore | None = None
        self._stores: dict[int, CheckpointStore] = {}
        self._wals: dict[int, ChunkWAL] = {}
        if state_dir is not None:
            f = fs if fs is not None else LocalFilesystem()
            if fs is None and faults is not None and faults.has_disk_faults:
                f = FaultyFilesystem(f, faults, clock=self._clock_obj)
            self._fs = f
            self._fleet_store = CheckpointStore(
                os.path.join(state_dir, "fleet"), fs=f,
                retain=self._retain_checkpoints,
            )

        groups = np.array_split(np.arange(n_streams), n_workers)
        self.workers = [
            _Worker(i, self._build_engine(len(g)), [int(s) for s in g])
            for i, g in enumerate(groups)
        ]
        self._route: dict[int, tuple[int, int]] = {}
        for w in self.workers:
            for local, g in enumerate(w.streams):
                self._route[g] = (w.idx, local)
        self.lanes = lanes
        self._lanes: _LanePool | None = None
        self._ingest: IngestQueue | None = None
        if lanes == "threads":
            self._lanes = _LanePool()
            for w in self.workers:
                self._lanes.ensure(w.idx)
            self._ingest = IngestQueue()
        for w in self.workers:
            self._attach_worker_storage(w.idx)

    def _build_engine(self, n_streams: int) -> MonitorEngine:
        return MonitorEngine(
            self._qp, self.cfg, n_streams=n_streams, **self._engine_kw
        )

    def _attach_worker_storage(self, idx: int) -> None:
        """Create (idempotently) the checkpoint store + WAL for one worker
        index.  No-op without a state dir."""
        if self.state_dir is None or idx in self._wals:
            return
        root = os.path.join(self.state_dir, f"worker-{idx:03d}")
        self._stores[idx] = CheckpointStore(
            root, fs=self._fs, retain=self._retain_checkpoints
        )
        self._wals[idx] = ChunkWAL(
            os.path.join(root, "wal.log"), fs=self._fs,
            fsync=self._fsync, fsync_interval=self._fsync_interval,
        )

    def _stamp_good(self, w: _Worker) -> None:
        """Mark the worker's current engine state as its last good state
        and pin the per-stream delivery cursors / fault counts that state
        embeds (what a durable checkpoint of it must record)."""
        w.last_good = w.engine.snapshot()
        w.journal.clear()
        w.good_pushed = {
            int(g): int(self.pushed_chunks[g]) for g in w.streams
        }
        w.good_faulted = {
            int(g): int(self.faulted_chunks[g]) for g in w.streams
        }

    # -- ingest --------------------------------------------------------------

    def push(self, stream: int, samples: np.ndarray) -> int:
        """Route one chunk to its worker (journaled for crash replay).

        Chunks for streams refused at the fleet admission cap, or evicted
        for persistent overflow, are dropped (counted in
        ``refused_chunks``) — only a stream id the fleet was never built
        for raises.

        With execution lanes the push is a non-blocking append onto the
        shared front-of-fleet ingest queue (safe while a round is in
        flight); delivery — admission, chunk faults, journaling — happens
        on the supervisor thread at the top of the next :meth:`step`,
        through the identical routing path, and the return value is 0
        (overflow is still visible in ``dropped_samples``)."""
        if self._ingest is not None:
            if not 0 <= stream < self.n_streams:
                raise ValueError(
                    f"stream index {stream} out of range for a fleet with "
                    f"{self.n_streams} stream(s)"
                )
            # np.array copies: the caller may reuse its chunk buffer before
            # the queue is drained.
            self._ingest.append(
                (stream, np.array(samples, np.float32).reshape(-1))
            )
            return 0
        return self._ingest_one(stream, samples)

    def _ingest_one(self, stream: int, samples: np.ndarray) -> int:
        """Deliver one chunk: fleet admission, fault injection, journal,
        worker push.  Runs on the supervisor thread in both lane modes."""
        if stream in self.evicted or stream in self._refused:
            self.refused_chunks[stream] += 1
            self.pushed_chunks[stream] += 1  # the cursor counts refusals too
            return 0
        if stream not in self._route:
            raise ValueError(
                f"stream index {stream} out of range for a fleet with "
                f"{self.n_streams} stream(s)"
            )
        if stream not in self._seen:
            if (
                self._max_streams is not None
                and len(self._seen) >= self._max_streams
            ):
                self._refused.add(stream)
                self.refused_chunks[stream] += 1
                self.pushed_chunks[stream] += 1
                return 0
            self._seen.add(stream)
        seq = int(self.pushed_chunks[stream])
        self.pushed_chunks[stream] += 1
        w_idx, local = self._route[stream]
        w = self.workers[w_idx]
        x = np.asarray(samples, np.float32).reshape(-1)

        fault = (
            self.faults.chunk_fault(self.round, stream) if self.faults else None
        )
        flags = 0
        if fault is not None:
            self.faulted_chunks[stream] += 1
            flags = WAL_FAULTED
            if fault.kind == "drop_chunk":
                # the transport ate it — a WAL marker record keeps the
                # delivery cursor and fault counter exact across a restart
                # even though nothing reaches the engine
                self._journal_disk(
                    w, stream=stream, seq=seq,
                    flags=WAL_FAULTED | WAL_DROPPED,
                )
                return 0
            if fault.kind == "corrupt_chunk":
                x = x.copy()
                x[::7] = np.nan  # deterministic poison pattern
            elif fault.kind == "jitter_chunk" and len(x) >= 2:
                # content-preserving re-segmentation: same samples, two
                # pushes sharing one cursor seq; only the first record
                # carries FAULTED so replay counts the fault once
                cut = max(1, min(len(x) - 1, int(len(x) * fault.magnitude)))
                return self._deliver(
                    w, local, x[:cut], stream=stream, seq=seq, flags=flags
                ) + self._deliver(w, local, x[cut:], stream=stream, seq=seq)
        return self._deliver(w, local, x, stream=stream, seq=seq, flags=flags)

    def _deliver(self, w: _Worker, local: int, chunk: np.ndarray, *,
                 stream: int, seq: int, flags: int = 0) -> int:
        # Journal BEFORE delivery — in memory for in-process revives, on
        # disk for cold restarts: if the push itself dies mid-flight both
        # replays still re-attempt it.  The journals store the raw chunk
        # (post-transport-fault, pre-sanitize); replaying through
        # engine.push re-applies the same deterministic sanitize decisions
        # and counters.
        w.journal.append((local, chunk.copy()))
        self._journal_disk(w, stream=stream, seq=seq, chunk=chunk, flags=flags)
        return w.engine.push(local, chunk)

    def _journal_disk(self, w: _Worker, *, stream: int, seq: int,
                      chunk: np.ndarray | None = None, flags: int = 0) -> None:
        wal = self._wals.get(w.idx)
        if wal is None:
            return
        try:
            wal.append(stream=stream, seq=seq, round_=self.round,
                       chunk=chunk, flags=flags)
        except (OSError, InjectedFault):
            # durability degraded (counted), never fatal: the chunk is
            # still delivered and still in the in-memory journal
            self.wal_errors += 1

    # -- scoring -------------------------------------------------------------

    def step(self) -> list[WindowScore]:
        """Score one fleet round: at most one window per stream, across all
        live workers.  Never raises on worker faults — crashes, stalls and
        kills are caught, logged to :attr:`incidents`, and recovered
        losslessly before the round completes.

        With execution lanes every live worker's beat runs concurrently in
        its named lane; results are joined in worker order, and deferred
        fleet-level actions (eviction, retirement) are applied serially on
        this thread afterwards, so the observable per-stream behaviour is
        identical to the sequential fleet."""
        if self._ingest is not None:
            for stream, samples in self._ingest.drain():
                self._ingest_one(stream, samples)
        live = [w for w in self.workers if w.alive]
        if self._lanes is None:
            results = [self._step_worker(w) for w in live]
        else:
            for w in live:
                self._lanes.submit(w.idx, self._step_worker, w)
            results = [self._lanes.result(w.idx) for w in live]
        out: list[WindowScore] = []
        for r in results:
            out.extend(r)
        # Deferred fleet-level mutations, serialized in worker order: a lane
        # must never rebuild another worker's engine mid-round.
        for w in live:
            if w.alive and w.pending_evict:
                evictions, w.pending_evict = list(w.pending_evict), []
                self._evict(w, evictions)
            if w.alive and w.retire_pending:
                w.retire_pending = False
                self._reassign(w)
        self.round += 1
        self._persist()
        return out

    def _step_worker(self, w: _Worker) -> list[WindowScore]:
        hook = None
        if self.faults is not None:
            for f in self.faults.worker_faults(self.round, w.idx):
                if f.kind == "kill_worker":
                    # the process died between rounds: the engine object is
                    # simply gone — rebuild from artifact + snapshot + journal
                    w.engine = None
                    self._incident(w, "kill", "worker process died")
                    self._revive(w)
                    if w.retire_pending:  # retires into another worker
                        return []
                elif f.kind == "raise_forward":
                    hook = self._raise_hook(f.magnitude)
                elif f.kind == "stall_forward":
                    hook = self._stall_hook(f.magnitude)

        # The revive/retry loop (never raises on worker faults): each failed
        # attempt — including a failure during a recovery re-run — is logged,
        # the worker revived, and the identical round re-scored; the rebuild
        # counter bounds the loop, tipping a persistently-failing worker into
        # retirement instead of letting a second consecutive fault escape.
        while True:
            t0 = self._now()
            # re-install on every attempt: the hooks are stateful (a
            # transient fault raises on its first k attempts, then clears)
            w.engine.fault_hook = hook
            try:
                scored = w.engine.step()
                break
            except Exception as exc:  # noqa: BLE001 — the point is to survive
                elapsed = self._now() - t0
                stalled = elapsed > self.dispatch_deadline_s
                self._incident(
                    w,
                    "stall" if stalled else "crash",
                    f"{type(exc).__name__}: {exc} (round took {elapsed:.3f}s)",
                )
                self._revive(w)
                if w.retire_pending:
                    return []
                # transactional step committed nothing, so the re-run scores
                # the exact same windows the failed attempt peeked
            finally:
                if w.engine is not None:
                    w.engine.fault_hook = None

        # Collect evictions BEFORE snapshotting last_good: a snapshot taken
        # between de-admission and collection would otherwise revive into a
        # stream that is refused but never evicted (no event stash, stale
        # route, journal growing forever).
        evictions = w.engine.take_evictions()
        self._stamp_good(w)
        w.last_heartbeat = self._now()
        # map local -> global ids BEFORE eviction renumbers w.streams
        out = [
            dataclasses.replace(ws, stream=w.streams[ws.stream]) for ws in scored
        ]
        if evictions:
            w.pending_evict.extend(evictions)
        return out

    def _raise_hook(self, magnitude: float = 0.0):
        # magnitude = consecutive failing attempts (0/1 = classic one crash):
        # the hook object survives the revive, so the recovery re-run fails
        # too until the budget is spent — the back-to-back-failure case the
        # revive/retry loop exists for.
        state = {"left": max(1, int(magnitude))}

        def hook(ids):
            if state["left"] > 0:
                state["left"] -= 1
                raise InjectedFault("injected forward crash")

        return hook

    def _stall_hook(self, magnitude: float):
        hang = max(float(magnitude), 2.0 * self.dispatch_deadline_s)
        state = {"left": 1}  # one hang; the revived worker's re-run proceeds

        def hook(ids):
            if state["left"] <= 0:
                return
            state["left"] -= 1
            # simulate the hang on the injectable clock, then fail the way a
            # real watchdog does: abandon the dispatch
            advance = getattr(self._clock_obj, "advance", None)
            if advance is not None:
                advance(hang)
            raise StalledForward(f"forward hung {hang:.1f}s past deadline")

        return hook

    # -- recovery ------------------------------------------------------------

    def _revive(self, w: _Worker):
        """Rebuild a dead/crashed worker: fresh engine from the baked
        artifact, restore the last-good snapshot, replay the journal.  The
        result is bitwise the state at the moment of death.  A worker past
        its rebuild budget is flagged for retirement — applied on the
        supervisor thread at the end of the round, never inside a lane."""
        w.rebuilds += 1
        engine = self._build_engine(len(w.streams))
        engine.restore(w.last_good)
        for local, chunk in w.journal:
            engine.push(local, chunk)
        w.engine = engine
        if w.rebuilds > self.max_rebuilds:
            w.retire_pending = True

    def _reassign(self, w: _Worker, *, kind: str = "reassign",
                  detail: str | None = None):
        """Retire a worker: migrate its streams — with their full revived
        state — into the least-loaded survivor, rebuilt for the combined
        stream set.  Migration is bitwise lossless.  Used both for workers
        that keep dying (``kind="reassign"``) and for deliberate scale-down
        (:meth:`retire_worker`, ``kind="retire"``)."""
        survivors = [o for o in self.workers if o.alive and o is not w]
        if not survivors:
            # nowhere to move the streams: keep limping on rebuilds
            return
        target = min(survivors, key=lambda o: len(o.streams))
        merged = _merge_snapshots(target.engine.snapshot(), w.engine.snapshot())
        engine = self._build_engine(len(target.streams) + len(w.streams))
        engine.restore(merged)
        target.engine = engine
        base = len(target.streams)
        migrated = list(w.streams)
        target.streams.extend(migrated)
        for off, g in enumerate(migrated):
            self._route[g] = (target.idx, base + off)
        # the merged engine IS the new last-good state; pending journal
        # entries from both workers are already baked into it
        self._stamp_good(target)
        self._incident(
            w,
            kind,
            detail
            or f"retired after {w.rebuilds} rebuilds; streams "
               f"{migrated} -> worker {target.idx}",
        )
        w.alive = False
        w.engine = None
        w.streams = []
        w.journal.clear()
        self._splice_dirty = True

    def _evict(self, w: _Worker, locals_: list[int]):
        """Remove persistently-overflowing streams from a worker: the
        reassignment machinery run in reverse.  The worker is rebuilt from a
        snapshot projected onto its surviving streams
        (:func:`_subset_snapshot`) — survivors keep their exact ring
        contents, EMA trajectories and window indices — while the evicted
        streams' already-closed track events and final per-stream counter
        totals are stashed (for :meth:`finalize` and the fleet counter
        gathers) and further pushes to them are refused."""
        drop = set(locals_)
        keep = [l for l in range(len(w.streams)) if l not in drop]
        snap = w.engine.snapshot()
        evicted_globals = sorted(w.streams[l] for l in drop)
        for l in drop:
            g = w.streams[l]
            self.evicted.add(g)
            self._evicted_events[g] = list(snap["tracker"]["events"][l])
            self._final_counters[g] = {
                k: int(np.asarray(v)[l])
                for k, v in snap["counters"].items()
                if isinstance(v, np.ndarray)
            }
            del self._route[g]
        self._incident(
            w,
            "evict",
            f"streams {evicted_globals} evicted after persistent ring "
            f"overflow",
        )
        if not keep:
            # every stream evicted: nothing left to serve
            w.alive = False
            w.engine = None
            w.streams = []
            w.journal.clear()
            self._splice_dirty = True
            return
        engine = self._build_engine(len(keep))
        engine.restore(_subset_snapshot(snap, keep))
        w.engine = engine
        w.streams = [w.streams[l] for l in keep]
        for local, g in enumerate(w.streams):
            self._route[g] = (w.idx, local)
        # the projected engine IS the new last-good state; the journal was
        # cleared by the round that triggered the eviction
        self._stamp_good(w)
        self._splice_dirty = True

    def _incident(self, w: _Worker, kind: str, detail: str):
        # lock-protected: lanes report their own incidents concurrently;
        # within one worker the order stays causal.
        with self._incident_lock:
            self.incidents.append(
                {"round": self.round, "worker": w.idx, "kind": kind,
                 "detail": detail}
            )

    # -- durability (cold-restart checkpoints + WAL) ---------------------------

    def _persist(self, *, force: bool = False) -> None:
        """Publish the fleet's durable view: each live worker's last-good
        checkpoint (snapshot + the delivery cursors it embeds), WAL resets
        for journals those checkpoints made redundant, then the fleet
        meta-checkpoint that pins it all together.  Runs on the supervisor
        thread at the end of a round (every ``checkpoint_interval`` rounds,
        or forced after a topology splice).

        The meta is written *last* and is the restore authority: a crash
        anywhere mid-persist leaves worker checkpoints the meta never
        references (orphans, skipped on restore) or WALs the meta's cursors
        already cover (stale prefixes, filtered on replay) — never a state
        that restores wrong.  Disk faults are counted
        (``ckpt_errors``/``wal_errors``), not raised: durability degrades
        to the previous checkpoint + WAL replay + driver re-delivery, but
        serving never stops."""
        if self.state_dir is None:
            return
        if not (force or self._splice_dirty
                or self.round % self.checkpoint_interval == 0):
            return
        self._ckpt_seq += 1
        ver = self._ckpt_seq
        for w in self.workers:
            if not w.alive or w.last_good is None:
                continue
            payload = {
                "snapshot": w.last_good,
                "pushed": dict(w.good_pushed),
                "faulted": dict(w.good_faulted),
            }
            try:
                self._stores[w.idx].save(ver, payload)
            except (OSError, InjectedFault):
                self.ckpt_errors += 1
                continue  # keep the WAL: it still covers the gap
            self._ckpt_versions[w.idx] = ver
            if not w.journal:
                # empty journal -> every WAL record is baked into last_good
                try:
                    self._wals[w.idx].reset()
                except (OSError, InjectedFault):
                    self.wal_errors += 1
        adm = self._engine_kw.get("admission")
        meta = {
            "round": self.round,
            "ckpt_seq": ver,
            "n_streams": self.n_streams,
            "max_streams": self._max_streams,
            "admission": None if adm is None else dataclasses.asdict(adm),
            "workers": [
                {"idx": w.idx, "alive": w.alive,
                 "streams": list(map(int, w.streams)),
                 "rebuilds": w.rebuilds}
                for w in self.workers
            ],
            "versions": dict(self._ckpt_versions),
            "seen": sorted(self._seen),
            "refused": sorted(self._refused),
            "evicted": sorted(self.evicted),
            "pushed_chunks": self.pushed_chunks.copy(),
            "faulted_chunks": self.faulted_chunks.copy(),
            "refused_chunks": self.refused_chunks.copy(),
            "evicted_events": {
                g: list(v) for g, v in self._evicted_events.items()
            },
            "final_counters": {
                g: dict(v) for g, v in self._final_counters.items()
            },
            "incidents": [dict(i) for i in self.incidents],
        }
        try:
            self._fleet_store.save(ver, meta)
        except (OSError, InjectedFault):
            self.ckpt_errors += 1
            return  # keep _splice_dirty: retry the full publish next round
        self._splice_dirty = False
        # a dead worker's journal is redundant once a meta that records the
        # splice is on disk (its state lives in a survivor's checkpoint)
        for idx, wal in self._wals.items():
            w = self.workers[idx] if idx < len(self.workers) else None
            if w is not None and not w.alive and wal.appended:
                try:
                    wal.reset()
                except (OSError, InjectedFault):
                    self.wal_errors += 1

    @property
    def wal_truncations(self) -> int:
        """Torn/corrupt WAL tails truncated by replay across the fleet."""
        return sum(w.truncations for w in self._wals.values())

    @classmethod
    def restore_from_dir(cls, artifact: QuantizedParams, cfg: CNNConfig, *,
                         state_dir: str, fs=None, **kw):
        """Rebuild a fleet from its durable on-disk state: artifact + newest
        valid fleet meta-checkpoint + per-worker checkpoints (pinned to the
        versions the meta references — a newer orphan is never resurrected)
        + WAL replay, with any torn/corrupt WAL tail truncated, never
        raised.  Returns ``None`` when the state dir holds no loadable
        meta (caller starts a fresh fleet).

        After restore, ``pushed_chunks`` is the per-stream delivery cursor:
        the driver re-delivers each stream's chunks from that ordinal on
        (then re-runs rounds from ``self.round``) and the resumed run is
        bitwise identical to an uninterrupted one."""
        probe_fs = fs if fs is not None else LocalFilesystem()
        meta_store = CheckpointStore(
            os.path.join(state_dir, "fleet"), fs=probe_fs
        )
        loaded = meta_store.load_latest()
        if loaded is None:
            return None
        _, meta = loaded
        kw.pop("n_streams", None)
        kw.pop("n_workers", None)
        sup = cls(artifact, cfg, n_streams=int(meta["n_streams"]),
                  n_workers=1, state_dir=state_dir, fs=fs, **kw)
        sup.round = int(meta["round"])
        sup._ckpt_seq = int(meta["ckpt_seq"])
        sup._ckpt_versions = {
            int(k): int(v) for k, v in meta["versions"].items()
        }
        sup._max_streams = meta["max_streams"]
        if meta["admission"] is not None:
            sup._engine_kw["admission"] = AdmissionPolicy(**meta["admission"])
        sup._seen = {int(s) for s in meta["seen"]}
        sup._refused = {int(s) for s in meta["refused"]}
        sup.evicted = {int(s) for s in meta["evicted"]}
        sup.pushed_chunks = np.asarray(meta["pushed_chunks"], np.int64).copy()
        sup.faulted_chunks = np.asarray(
            meta["faulted_chunks"], np.int64
        ).copy()
        sup.refused_chunks = np.asarray(
            meta["refused_chunks"], np.int64
        ).copy()
        sup._evicted_events = {
            int(g): list(v) for g, v in meta["evicted_events"].items()
        }
        sup._final_counters = {
            int(g): dict(v) for g, v in meta["final_counters"].items()
        }
        sup.incidents = [dict(i) for i in meta["incidents"]]

        workers: list[_Worker] = []
        sup._route = {}
        for rec in meta["workers"]:
            idx = int(rec["idx"])
            if not rec["alive"]:
                w = _Worker(idx, None, [])
                w.alive = False
                w.rebuilds = int(rec["rebuilds"])
                workers.append(w)
                continue
            streams = [int(g) for g in rec["streams"]]
            sup._attach_worker_storage(idx)
            engine = sup._build_engine(len(streams))
            w = _Worker(idx, engine, streams)
            w.rebuilds = int(rec["rebuilds"])
            pinned = sup._ckpt_versions.get(idx)
            ck = (
                sup._stores[idx].load_latest(at_or_before=pinned)
                if pinned is not None else None
            )
            if ck is not None and (
                len(ck[1]["snapshot"]["rings"]) != len(streams)
            ):
                ck = None  # checkpoint predates a splice the meta recorded
            if ck is None:
                # degraded restore: no usable checkpoint — start this
                # worker fresh and zero its cursors so the driver
                # re-delivers its streams from chunk 0
                sup.ckpt_errors += 1
                for g in streams:
                    sup.pushed_chunks[g] = 0
                    sup.faulted_chunks[g] = 0
                try:
                    sup._wals[idx].reset()
                except (OSError, InjectedFault):
                    sup.wal_errors += 1
                sup._stamp_good(w)
                sup._incident(
                    w, "restore-degraded",
                    "no loadable checkpoint; rebuilt fresh — the driver "
                    "must re-deliver from chunk 0",
                )
            else:
                _, payload = ck
                engine.restore(payload["snapshot"])
                for g, v in payload["pushed"].items():
                    sup.pushed_chunks[int(g)] = int(v)
                for g, v in payload["faulted"].items():
                    sup.faulted_chunks[int(g)] = int(v)
                sup._stamp_good(w)
                # WAL replay: everything delivered after that checkpoint.
                # The seq filter drops stale pre-checkpoint prefixes (a
                # reset that failed or never ran); it compares against the
                # checkpoint's cursor, not the advancing one, so jittered
                # pushes sharing a seq both replay.
                base = {g: int(sup.pushed_chunks[g]) for g in streams}
                local_of = {g: l for l, g in enumerate(streams)}
                for r in sup._wals[idx].replay():
                    g = int(r.stream)
                    if g not in local_of or r.seq < base[g]:
                        continue
                    if r.flags & WAL_FAULTED:
                        sup.faulted_chunks[g] += 1
                    if not (r.flags & WAL_DROPPED):
                        engine.push(local_of[g], r.chunk)
                        w.journal.append((local_of[g], r.chunk))
                        sup.replayed_chunks += 1
                    sup.pushed_chunks[g] = max(
                        sup.pushed_chunks[g], r.seq + 1
                    )
            workers.append(w)
        sup.workers = workers
        for w in workers:
            for local, g in enumerate(w.streams):
                sup._route[g] = (w.idx, local)
        if sup._lanes is not None:
            for w in workers:
                if w.alive:
                    sup._lanes.ensure(w.idx)
        return sup

    # -- elasticity (the SLO controller's actuators) --------------------------

    def spawn_worker(self) -> int | None:
        """Scale up: split the most-loaded live worker's streams in half and
        move the tail half — with its full per-stream state, via the same
        snapshot/splice machinery reassignment uses — into a brand-new
        worker (and lane).  Bitwise lossless for every stream; whole-engine
        scalar counters stay with the donor so fleet totals are conserved.
        Returns the new worker index, or None when no live worker has two
        streams to split."""
        donors = [w for w in self.workers if w.alive and len(w.streams) >= 2]
        if not donors:
            return None
        donor = max(donors, key=lambda o: len(o.streams))
        snap = donor.engine.snapshot()
        cut = len(donor.streams) // 2  # donor keeps the head half
        keep, move = list(range(cut)), list(range(cut, len(donor.streams)))
        moved = [donor.streams[l] for l in move]
        engine = self._build_engine(len(keep))
        engine.restore(_subset_snapshot(snap, keep))
        donor.engine = engine
        donor.streams = [donor.streams[l] for l in keep]
        self._stamp_good(donor)
        idx = len(self.workers)
        spawned_engine = self._build_engine(len(move))
        spawned_engine.restore(_subset_snapshot(snap, move, zero_scalars=True))
        spawned = _Worker(idx, spawned_engine, moved)
        spawned.last_heartbeat = self._now()
        self.workers.append(spawned)
        self._stamp_good(spawned)
        for local, g in enumerate(donor.streams):
            self._route[g] = (donor.idx, local)
        for local, g in enumerate(moved):
            self._route[g] = (idx, local)
        if self._lanes is not None:
            self._lanes.ensure(idx)
        self._attach_worker_storage(idx)
        self._incident(
            spawned, "spawn",
            f"streams {moved} <- worker {donor.idx} (scale-up)",
        )
        # splices must keep the on-disk view consistent: publish the new
        # topology now (spawn/retire run between rounds, not inside step)
        self._splice_dirty = True
        self._persist(force=True)
        return idx

    def retire_worker(self, idx: int | None = None, *,
                      reason: str = "scale-down") -> bool:
        """Scale down: retire one live worker (the least-loaded by default),
        splicing its streams — with their full state — into a surviving
        worker.  Bitwise lossless; refuses (returns False) when it is the
        last live worker."""
        live = [w for w in self.workers if w.alive]
        if len(live) < 2:
            return False
        w = self.workers[idx] if idx is not None else min(
            live, key=lambda o: len(o.streams)
        )
        if not w.alive:
            return False
        streams = list(w.streams)
        self._reassign(
            w, kind="retire", detail=f"{reason}: streams {streams} folded "
            f"into the survivors",
        )
        if not w.alive:
            self._persist(force=True)
        return not w.alive

    def retune_admission(self, admission: AdmissionPolicy) -> None:
        """Swap the fleet's admission policy in place (the SLO controller's
        budget actuator).  The fleet-level ``max_streams`` cap updates here;
        the per-round knobs land on every live worker's engine and on the
        kwargs future rebuilds use.  Note streams already refused at the old
        cap stay refused — first-come admission is sticky by design."""
        self._max_streams = admission.max_streams
        worker_adm = dataclasses.replace(admission, max_streams=None)
        self._engine_kw["admission"] = worker_adm
        for w in self.workers:
            if w.alive:
                w.engine.admission = worker_adm
        # the active policy rides the fleet meta-checkpoint so a cold
        # restart resumes with the retuned budgets, not the boot-time ones
        self._persist(force=True)

    @property
    def admission(self) -> AdmissionPolicy:
        """The currently-active fleet admission policy (fleet-level
        ``max_streams`` re-folded in)."""
        adm = self._engine_kw.get("admission") or AdmissionPolicy()
        return dataclasses.replace(adm, max_streams=self._max_streams)

    # -- introspection / lifecycle -------------------------------------------

    @property
    def n_live_workers(self) -> int:
        return sum(1 for w in self.workers if w.alive)

    @property
    def windows_scored(self) -> int:
        return sum(w.engine.windows_scored for w in self.workers if w.alive)

    @property
    def forward_calls(self) -> int:
        return sum(w.engine.forward_calls for w in self.workers if w.alive)

    @property
    def padded_slots(self) -> int:
        return sum(w.engine.padded_slots for w in self.workers if w.alive)

    @property
    def dropped_samples(self) -> int:
        return sum(w.engine.dropped_samples for w in self.workers if w.alive)

    @property
    def served_windows(self) -> np.ndarray:
        """Windows actually scored, per *global* stream (fairness
        observability); evicted streams keep their final totals."""
        return self._gather_per_stream("served_windows")

    @property
    def deferred_windows(self) -> np.ndarray:
        """Ready windows deferred past their round by the per-stream cap /
        fairness budget, per global stream; evicted streams keep their
        final totals."""
        return self._gather_per_stream("deferred_windows")

    @property
    def slot_histogram(self) -> dict[int, int]:
        """Blocks dispatched per slot shape, summed over live workers."""
        out: dict[int, int] = {}
        for w in self.workers:
            if not w.alive:
                continue
            for k, v in w.engine.slot_histogram.items():
                out[k] = out.get(k, 0) + v
        return out

    def _gather_per_stream(self, attr: str) -> np.ndarray:
        out = np.zeros(self.n_streams, np.int64)
        for w in self.workers:
            if not w.alive:
                continue
            vals = getattr(w.engine, attr)
            for local, g in enumerate(w.streams):
                out[g] = vals[local]
        # evicted (and retired-with-their-worker) streams report the totals
        # stashed when they left the fleet, not zeros
        for g, totals in self._final_counters.items():
            out[g] = totals.get(attr, 0)
        return out

    def precompile(self) -> tuple[int, ...]:
        """Warm every worker's jit cache over its slot-shape ladder (one
        shared cache process-wide, so this is cheap past the first worker);
        returns the first live worker's ladder."""
        ladder: tuple[int, ...] = ()
        for w in self.workers:
            if w.alive:
                ladder = w.engine.precompile()
        return ladder

    def health(self) -> list[dict]:
        """Per-worker health: liveness, lane, stream assignment, rebuild
        count, heartbeat age on the supervisor's clock."""
        now = self._now()
        report = []
        for w in self.workers:
            report.append(
                {
                    "worker": w.idx,
                    "alive": w.alive,
                    "lane": (
                        None if self._lanes is None else self._lanes.name(w.idx)
                    ),
                    "streams": list(w.streams),
                    "rebuilds": w.rebuilds,
                    "heartbeat_age_s": (
                        None if w.last_heartbeat is None else now - w.last_heartbeat
                    ),
                    "rounds": None if w.engine is None else w.engine.rounds,
                }
            )
        return report

    def drain(self) -> list[WindowScore]:
        """Run rounds until no worker has a complete window buffered."""
        out: list[WindowScore] = []
        while True:
            scored = self.step()
            if not scored:
                return out
            out.extend(scored)

    def close(self) -> None:
        """Shut down the execution lanes (no-op for the sequential fleet)
        and publish a final durable checkpoint (no-op without a state dir).
        The supervisor remains usable afterwards only in sequential mode."""
        if self._lanes is not None:
            self._lanes.close()
            self._lanes = None
            # queued-but-undelivered ingest would be lost with the lanes;
            # deliver it so close() is not a silent drop
            if self._ingest is not None:
                for stream, samples in self._ingest.drain():
                    self._ingest_one(stream, samples)
                self._ingest = None
        if self.state_dir is not None:
            # chunks delivered since the last step stay journaled on disk
            # (their workers' journals are non-empty, so _persist leaves
            # those WALs alone and replay covers them)
            self._persist(force=True)
            for wal in self._wals.values():
                wal.close()

    def finalize(self) -> list[list[TrackEvent]]:
        """Flush still-open tracks; returns per-GLOBAL-stream event lists.
        Evicted streams report the events they had closed before eviction."""
        out: list[list[TrackEvent]] = [[] for _ in range(self.n_streams)]
        for g, evs in self._evicted_events.items():
            out[g] = list(evs)
        for w in self.workers:
            if not w.alive:
                continue
            events = w.engine.finalize()
            for local, g in enumerate(w.streams):
                out[g] = events[local]
        return out
