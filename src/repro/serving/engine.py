"""Multi-stream streaming detection engine (the paper's deployment scenario).

The headline SHIELD8-UAV use case is *continuous* acoustic monitoring: raw
microphone audio arrives as an unbounded stream, is cut into 0.8 s windows,
each window is scored by the 1D-F-CNN on the W8A8 datapath, and the temporal
tracker turns the per-window probabilities into stable detection events.
This module scales that loop to N concurrent streams:

* **per-stream ring buffers** (:class:`StreamRing`) absorb raw audio pushed
  in arbitrary chunk sizes and emit hop-aligned 0.8 s windows;
* **continuous micro-batching** packs each round's ready windows into slot
  blocks of one jitted :func:`~repro.serving.accelerator.accelerator_forward`
  program via the shared :class:`~repro.serving.batching.DispatchCore` (the
  same core ``launch/serve.py``'s ``BatchedServer`` runs on): fixed
  ``batch_slots`` blocks with silence-padded dead slots by default, or —
  with ``adaptive_slots=True`` — blocks grown/shrunk over a small
  pre-jittable ladder to fit the backlog, so one live stream dispatches a
  1-slot block instead of padding 7/8;
* **admission control** (:class:`~repro.serving.batching.AdmissionPolicy`)
  for fleet scale: cap the distinct streams admitted, cap windows drained
  per stream per round with a depth-fair round budget, and evict streams
  that persistently overflow their rings;
* a **vectorised tracker** (:class:`~repro.serving.tracker.VectorTemporalTracker`)
  advances all N streams' EMA/hysteresis/min-duration state in one numpy
  pass per round.

Because the accelerator path quantises activations with *per-sample* scales,
a window's probability is bitwise independent of whatever other streams it
was co-batched with — streaming one window at a time, 64 streams packed 8 to
a batch, or a batch split over a device mesh, produces the identical numbers
(the streaming-parity and sharded-conformance tests pin this).  ``shards=k``
routes every fixed-slot block through the ``shard_map``-based
:func:`~repro.serving.accelerator.accelerator_forward_sharded` (weights
replicated, activation rows split over a 1-D "streams" mesh), and dispatch
is double-buffered: the next block is submitted while the previous block's
device buffers are still in flight.  ``python -m repro.launch.monitor`` is
the demo driver and ``benchmarks/bench_serving.py`` the throughput harness
on top of this class.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import features
from repro.distributed.sharding import stream_mesh
from repro.kernels.backend import resolve_interpret
from repro.models.cnn1d import CNNConfig
from repro.serving.accelerator import (
    accelerator_forward,
    accelerator_forward_sharded,
    precompile_slot_shapes,
)
from repro.serving.batching import (
    AdmissionPolicy,
    BlockPool,
    DispatchCore,
    SlotPolicy,
    fair_allocation,
)
from repro.serving.quantized_params import (
    QuantizedParams,
    quantize_params,
    replicate_params,
)
from repro.serving.tracker import TrackEvent, VectorTemporalTracker


class StreamRing:
    """Fixed-capacity ring buffer over one stream's raw samples.

    ``push`` accepts arbitrary chunk sizes; ``pop_window`` emits the next
    hop-aligned window of ``window`` samples and advances the read head by
    ``hop`` (overlapping windows when ``hop < window``).  On overflow the
    oldest *whole hops* are dropped (keeping the stream hop-aligned) and
    counted in ``dropped`` — an always-on monitor degrades, it never blocks.
    """

    def __init__(self, window: int, hop: int, capacity_windows: int = 8):
        # Real exceptions, not asserts: ingest validation must survive
        # ``python -O`` — an always-on monitor is exactly the deployment
        # where optimised bytecode would silently skip the checks.
        if window <= 0:
            raise ValueError(f"window must be positive, got {window}")
        if hop <= 0:
            raise ValueError(f"hop must be positive, got {hop}")
        if capacity_windows < 1:
            raise ValueError(
                f"capacity_windows must be >= 1, got {capacity_windows}"
            )
        self.window = window
        self.hop = hop
        self.capacity = window + (capacity_windows - 1) * hop
        self._buf = np.zeros(self.capacity, np.float32)
        self._w = 0  # absolute count of samples written
        self._r = 0  # absolute index of the next window's first sample
        self.dropped = 0  # samples lost to overflow

    @property
    def buffered(self) -> int:
        """Samples currently held between the read and write heads."""
        return self._w - self._r

    @property
    def ready(self) -> int:
        """Number of complete windows currently extractable."""
        avail = self._w - self._r
        return 0 if avail < self.window else 1 + (avail - self.window) // self.hop

    def push(self, samples: np.ndarray) -> int:
        """Append raw audio; returns the number of samples dropped (0 unless
        the buffer overflowed)."""
        x = np.asarray(samples, np.float32).reshape(-1)
        avail = self._w - self._r
        total = avail + len(x)
        dropped = 0
        if total > self.capacity:
            need = total - self.capacity
            dropped = min(((need + self.hop - 1) // self.hop) * self.hop, total)
            # Oldest first: consume buffered backlog, then (for a chunk
            # bigger than the whole buffer) the incoming head passes through
            # unrecorded — both read and write heads advance over it so the
            # stream stays hop-aligned end to end.
            drop_buffered = min(dropped, avail)
            self._r += drop_buffered
            skip = dropped - drop_buffered
            self._w += skip
            self._r += skip
            x = x[skip:]
            self.dropped += dropped
        pos = self._w % self.capacity
        first = min(len(x), self.capacity - pos)
        self._buf[pos : pos + first] = x[:first]
        self._buf[: len(x) - first] = x[first:]
        self._w += len(x)
        return dropped

    def peek_window(self) -> np.ndarray | None:
        """Next hop-aligned window *without* consuming it, or None if fewer
        than ``window`` samples are buffered.  Pair with :meth:`advance` once
        the window has actually been scored — the transactional round
        protocol the monitor engine uses so a failed forward never loses a
        window."""
        if self._w - self._r < self.window:
            return None
        idx = (self._r + np.arange(self.window)) % self.capacity
        return self._buf[idx].copy()

    def peek_windows(self, k: int) -> np.ndarray:
        """The next ``k`` hop-aligned windows *without* consuming them, as a
        ``(k, window)`` array — the multi-window generalisation of
        :meth:`peek_window` for a round that drains a backlog.  Raises if
        fewer than ``k`` complete windows are buffered."""
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if self.ready < k:
            raise ValueError(f"{k} window(s) requested, only {self.ready} ready")
        idx = (
            self._r
            + np.arange(k)[:, None] * self.hop
            + np.arange(self.window)[None, :]
        ) % self.capacity
        return self._buf[idx]  # fancy indexing: already a copy

    def advance(self):
        """Consume one hop off the front (commit the last peeked window)."""
        if self._w - self._r < self.window:
            raise ValueError("advance() without a complete window buffered")
        self._r += self.hop

    def pop_window(self) -> np.ndarray | None:
        """Next hop-aligned window, or None if fewer than ``window`` samples
        are buffered."""
        out = self.peek_window()
        if out is not None:
            self._r += self.hop
        return out

    # -- crash-recoverable state ---------------------------------------------

    def state_dict(self) -> dict:
        """Deep-copied snapshot: buffer contents plus the absolute read/write
        heads and the drop counter.  Restoring it reproduces the ring
        bitwise — every window popped after a restore is identical to the
        windows an uninterrupted ring would have popped."""
        return {
            "window": self.window,
            "hop": self.hop,
            "capacity": self.capacity,
            "buf": self._buf.copy(),
            "w": self._w,
            "r": self._r,
            "dropped": self.dropped,
        }

    def load_state_dict(self, sd: dict):
        for field in ("window", "hop", "capacity"):
            if sd[field] != getattr(self, field):
                raise ValueError(
                    f"state_dict {field}={sd[field]} does not match this "
                    f"ring's {field}={getattr(self, field)}"
                )
        self._buf = np.asarray(sd["buf"], np.float32).copy()
        self._w = int(sd["w"])
        self._r = int(sd["r"])
        self.dropped = int(sd["dropped"])


@dataclasses.dataclass(frozen=True)
class SanitizeReport:
    """What :meth:`SanitizePolicy.apply` did to one chunk."""

    rejected: bool = False  # chunk refused outright (reason below)
    reason: str | None = None  # "nonfinite" | "clipped" when rejected
    zeroed: int = 0  # non-finite samples replaced with 0.0
    clipped: bool = False  # chunk exceeded the clip-fraction threshold


@dataclasses.dataclass(frozen=True)
class SanitizePolicy:
    """Ingest hardening for one microphone chunk (the engine's ``push``).

    A field microphone that starts emitting NaN/Inf (broken ADC, saturated
    preamp, truncated UDP payload decoded as garbage) must degrade *its own*
    stream, never poison the fleet: a single NaN entering the ring would
    propagate through the forward into the tracker EMA, which never recovers
    (``0.4 * nan + 0.6 * ema`` is NaN forever).  The policy runs before any
    sample reaches the ring:

    * ``nonfinite="reject"`` drops a chunk containing any NaN/Inf sample;
      ``"zero"`` replaces just the poisoned samples with 0.0 and keeps the
      chunk (preserves window alignment at the cost of a dirty window).
    * ``clip_level``/``max_clip_fraction`` flag *clipped* chunks — more than
      ``max_clip_fraction`` of samples at or beyond ``clip_level`` full
      scale.  ``clipped_action="count"`` only counts them (clipping degrades
      features but is finite); ``"reject"`` drops the chunk.

    Per-stream reject/zero/clip counters live on the engine
    (``rejected_chunks``/``zeroed_samples``/``clipped_chunks``) so an
    operator can tell *which* microphone went bad and when.
    """

    nonfinite: str = "reject"  # "reject" | "zero"
    clip_level: float | None = None  # None disables clip detection
    max_clip_fraction: float = 0.05
    clipped_action: str = "count"  # "count" | "reject"

    def __post_init__(self):
        if self.nonfinite not in ("reject", "zero"):
            raise ValueError(
                f"nonfinite must be 'reject' or 'zero', got {self.nonfinite!r}"
            )
        if self.clipped_action not in ("count", "reject"):
            raise ValueError(
                f"clipped_action must be 'count' or 'reject', got "
                f"{self.clipped_action!r}"
            )
        if self.clip_level is not None and self.clip_level <= 0:
            raise ValueError(f"clip_level must be positive, got {self.clip_level}")
        if not 0.0 <= self.max_clip_fraction <= 1.0:
            raise ValueError(
                f"max_clip_fraction must be in [0, 1], got "
                f"{self.max_clip_fraction}"
            )

    def apply(self, x: np.ndarray) -> tuple[np.ndarray | None, SanitizeReport]:
        """Sanitize one chunk; returns ``(clean_chunk_or_None, report)``.
        The chunk is ``None`` exactly when the report says ``rejected``."""
        bad = ~np.isfinite(x)
        n_bad = int(bad.sum())
        if n_bad and self.nonfinite == "reject":
            return None, SanitizeReport(rejected=True, reason="nonfinite")
        clipped = False
        if self.clip_level is not None and len(x):
            finite_frac = float(
                np.mean(np.abs(np.where(bad, 0.0, x)) >= self.clip_level)
            )
            clipped = finite_frac > self.max_clip_fraction
            if clipped and self.clipped_action == "reject":
                return None, SanitizeReport(
                    rejected=True, reason="clipped", clipped=True
                )
        if n_bad:
            x = np.where(bad, np.float32(0.0), x)
        return x, SanitizeReport(zeroed=n_bad, clipped=clipped)


@dataclasses.dataclass
class WindowScore:
    """One scored window: raw probability plus the tracker's view of it."""

    stream: int
    window_idx: int  # per-stream window index (tracker idx)
    p_uav: float
    smoothed: float
    active: bool


class MonitorEngine:
    """N-stream continuous monitor over the quantised accelerator datapath.

    ``push`` raw audio per stream in any chunking; each ``step`` scores at
    most one ready window per stream (one *round*), micro-batched through
    the jitted forward in fixed ``batch_slots`` chunks.  ``drain`` loops
    until no stream has a complete window left; ``finalize`` flushes the
    trackers and returns per-stream event lists.

    ``shards``/``mesh`` select sharded-batch dispatch (each block split over
    the mesh's "streams" axis, bitwise identical results); ``inflight``
    bounds how many blocks may be in flight before the oldest is harvested.

    ``prune``/``policy`` bake a structured channel prune and a per-layer
    precision policy into the served artifact at construction time — the
    engine then serves the paper's deployed configuration (pruned flatten,
    mixed per-layer modes) with every parity guarantee intact.

    ``on_device_features=True`` fuses the DSP front-end into the jitted
    program: the engine submits raw ``(slots, 12800)`` window blocks and the
    artifact's baked ``feature_kind`` front-end runs in-graph, so host
    feature extraction no longer serializes with the double-buffered device
    dispatch.  The numpy front-end stays the oracle: its float64 features
    differ from the in-graph float32 ones within a per-kind tolerance
    (``features_jax.PARITY_ATOL``), while all *within-JAX* parity guarantees
    (streaming == batched == sharded) remain bitwise.
    """

    def __init__(
        self,
        params: dict | QuantizedParams,
        cfg: CNNConfig,
        *,
        n_streams: int,
        feature_kind: str = "mfcc20",
        on_device_features: bool = False,
        hop_samples: int | None = None,
        batch_slots: int = 8,
        precision: str = "int8",
        prune=None,  # PruneSpec baked into the served artifact
        policy=None,  # PrecisionPolicy resolving per-layer modes
        sanitize: SanitizePolicy | None = None,
        capacity_windows: int = 8,
        interpret: bool | None = None,
        shards: int | None = None,
        mesh: jax.sharding.Mesh | None = None,
        inflight: int = 2,
        adaptive_slots: bool = False,
        min_slots: int = 1,
        admission: AdmissionPolicy | None = None,
        ema_alpha: float = 0.4,
        enter_threshold: float = 0.65,
        exit_threshold: float = 0.35,
        min_duration: int = 2,
    ):
        if cfg.input_len != features.FEATURE_DIMS[feature_kind]:
            raise ValueError(
                f"model input_len {cfg.input_len} != {feature_kind} feature "
                f"dim {features.FEATURE_DIMS[feature_kind]}"
            )
        if n_streams < 1:
            raise ValueError(f"n_streams must be >= 1, got {n_streams}")
        if batch_slots < 1:
            raise ValueError(f"batch_slots must be >= 1, got {batch_slots}")
        self.cfg = cfg
        self.n_streams = n_streams
        self.feature_kind = feature_kind
        self.on_device_features = on_device_features
        self.batch_slots = batch_slots
        self.window = features.N_SAMPLES
        self.hop = hop_samples if hop_samples is not None else features.N_SAMPLES
        # Width of one micro-batch row: raw samples when the front-end is
        # fused into the device program, extracted features otherwise.
        self._in_width = features.N_SAMPLES if on_device_features else cfg.input_len
        self._interpret = resolve_interpret(interpret)
        # The served artifact: either pre-baked, or baked here from the fp32
        # checkpoint with the deployment decisions (default precision, prune
        # spec, per-layer policy, fused front-end) applied at quantise-once
        # time.
        if isinstance(params, QuantizedParams):
            if prune is not None or policy is not None:
                raise ValueError(
                    "prune/policy are quantise-once decisions and cannot be "
                    "applied to an already-baked QuantizedParams artifact; "
                    "pass the fp32 checkpoint instead"
                )
            if on_device_features and params.feature_kind != feature_kind:
                raise ValueError(
                    f"on_device_features=True needs an artifact baked for "
                    f"feature kind {feature_kind!r}, got "
                    f"{params.feature_kind!r}; re-bake with "
                    f"quantize_params(..., feature_kind={feature_kind!r})"
                )
            self._qp = params
        else:
            self._qp = quantize_params(
                params, cfg, mode=precision, prune=prune, policy=policy,
                feature_kind=feature_kind if on_device_features else None,
            )
        # Sharded-batch dispatch: split each fixed-slot block along a 1-D
        # device mesh ("streams" axis), weights replicated.  `shards=None`
        # keeps the single-device path; `shards=k` (including k=1, useful to
        # measure shard_map overhead) routes every forward through
        # accelerator_forward_sharded.
        if mesh is None and shards is not None:
            mesh = stream_mesh(shards)
        self._mesh = mesh
        self._mesh_axis = None
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError(
                    f"MonitorEngine needs a 1-D mesh (one batch-sharding "
                    f"axis), got axes {mesh.axis_names}"
                )
            if shards is not None and mesh.devices.size != shards:
                raise ValueError(
                    f"mesh has {mesh.devices.size} device(s) but shards="
                    f"{shards}; pass one or make them agree"
                )
            self._mesh_axis = mesh.axis_names[0]
            n_shards = mesh.shape[self._mesh_axis]
            if batch_slots % n_shards != 0:
                raise ValueError(
                    f"batch_slots {batch_slots} must divide evenly over "
                    f"{n_shards} shards"
                )
            self._qp = replicate_params(self._qp, mesh)
        self.shards = 1 if mesh is None else mesh.shape[self._mesh_axis]
        # Double-buffered async dispatch: up to `inflight` fixed-slot blocks
        # may be on-device concurrently; results are harvested (blocking)
        # only when the pipeline is full or the round ends.
        if inflight < 1:
            raise ValueError(f"inflight must be >= 1, got {inflight}")
        self._inflight = inflight
        self._rings = [
            StreamRing(self.window, self.hop, capacity_windows)
            for _ in range(n_streams)
        ]
        self.tracker = VectorTemporalTracker(
            n_streams,
            ema_alpha=ema_alpha,
            enter_threshold=enter_threshold,
            exit_threshold=exit_threshold,
            min_duration=min_duration,
        )
        # The shared continuous-batching core (serving/batching.py): ladder
        # of dispatchable slot shapes (fixed = always batch_slots, adaptive
        # = power-of-two multiples of the shard count), the preallocated
        # inflight+1 block-buffer rotation, and the slot-chunked dispatch
        # loop with the fault seam — the machinery launch/serve.py's
        # BatchedServer runs on too.
        self.slot_policy = SlotPolicy(
            batch_slots,
            adaptive=adaptive_slots,
            min_slots=min_slots,
            multiple=self.shards,
        )
        self.adaptive_slots = self.slot_policy.adaptive
        self._pool = BlockPool(self._in_width, inflight)
        self._core = DispatchCore(
            submit=self._submit_rows,
            harvest=lambda buf: np.asarray(buf.block_until_ready()),
            slot_policy=self.slot_policy,
            inflight=inflight,
        )
        # Stream admission / per-tenant fairness: the defaults reproduce the
        # classic behaviour (every stream admitted, one window per stream
        # per round, no budget, no eviction) exactly.
        self.admission = admission if admission is not None else AdmissionPolicy()
        self._admitted = np.ones(n_streams, bool)
        self._seen = np.zeros(n_streams, bool)
        self._n_seen = 0
        self._overflow_rounds = np.zeros(n_streams, np.int64)
        self._dropped_since_round = np.zeros(n_streams, np.int64)
        self._pending_evictions: list[int] = []
        # Incremental ready-window counts, updated O(1) on push/commit so a
        # 1,024-stream step() selects candidates with one vectorised compare
        # instead of rescanning every ring every round.
        self._ready_counts = np.zeros(n_streams, np.int64)
        # Ingest hardening: the sanitize policy runs on every push, per-
        # stream counters record what it did (None = trust the transport).
        self.sanitize = sanitize
        self.rejected_chunks = np.zeros(n_streams, np.int64)
        self.zeroed_samples = np.zeros(n_streams, np.int64)
        self.clipped_chunks = np.zeros(n_streams, np.int64)
        # observability counters for the bench / driver (forward_calls,
        # padded_slots and slot_histogram live on the core, exposed below)
        self.windows_scored = 0
        self.rounds = 0  # successfully committed scoring rounds
        self._dropped_samples = 0  # maintained incrementally by push()
        self.served_windows = np.zeros(n_streams, np.int64)
        self.deferred_windows = np.zeros(n_streams, np.int64)
        self.refused_chunks = np.zeros(n_streams, np.int64)

    # -- ingest --------------------------------------------------------------

    def push(self, stream: int, samples: np.ndarray) -> int:
        """Append raw audio to one stream; returns samples dropped (overflow).

        Admission gate: the first ``admission.max_streams`` *distinct*
        streams ever pushed are admitted; chunks for later streams — and for
        streams the engine has evicted — are refused (counted in
        ``refused_chunks``, returns 0) without touching any ring."""
        if not 0 <= stream < self.n_streams:
            raise ValueError(
                f"stream index {stream} out of range for an engine with "
                f"{self.n_streams} stream(s) (valid: 0..{self.n_streams - 1})"
            )
        if not self._seen[stream]:
            self._seen[stream] = True
            self._n_seen += 1
            max_streams = self.admission.max_streams
            if max_streams is not None and self._n_seen > max_streams:
                self._admitted[stream] = False
        if not self._admitted[stream]:
            self.refused_chunks[stream] += 1
            return 0  # refused at admission: nothing reached the ring
        x = np.asarray(samples, np.float32).reshape(-1)
        if self.sanitize is not None:
            x, rep = self.sanitize.apply(x)
            self.zeroed_samples[stream] += rep.zeroed
            if rep.clipped:
                self.clipped_chunks[stream] += 1
            if rep.rejected:
                self.rejected_chunks[stream] += 1
                return 0  # nothing reached the ring, nothing overflowed
        ring = self._rings[stream]
        dropped = ring.push(x)
        self._dropped_samples += dropped
        if dropped:
            self._dropped_since_round[stream] += dropped
        self._ready_counts[stream] = ring.ready
        return dropped

    def ready_windows(self) -> np.ndarray:
        """Per-stream count of complete, unscored windows (maintained
        incrementally on push/commit — no ring scan)."""
        return self._ready_counts.copy()

    @property
    def dropped_samples(self) -> int:
        return self._dropped_samples

    @property
    def admitted(self) -> np.ndarray:
        """Per-stream admission mask (False = refused at cap or evicted)."""
        return self._admitted.copy()

    def take_evictions(self) -> list[int]:
        """Stream ids evicted since the last call (overflow eviction); the
        fleet supervisor consumes these to rebuild the worker without the
        abusive streams via its reassignment machinery."""
        out, self._pending_evictions = self._pending_evictions, []
        return out

    # -- core counter shims (the dispatch loop lives in serving/batching) ----

    @property
    def fault_hook(self):
        """Fault-injection seam: when set, called with the round's items at
        the top of each dispatch, before anything is submitted — it may
        raise (simulated crash) or advance a fake clock (simulated stall).
        The transactional step() guarantees a raising hook leaves rings and
        tracker untouched.  Delegates to the shared core's ``pre_dispatch``;
        installed by the fleet supervisor's fault harness, never set in
        production serving."""
        return self._core.pre_dispatch

    @fault_hook.setter
    def fault_hook(self, hook):
        self._core.pre_dispatch = hook

    @property
    def forward_calls(self) -> int:
        return self._core.blocks_dispatched

    @forward_calls.setter
    def forward_calls(self, v: int):
        self._core.blocks_dispatched = int(v)

    @property
    def padded_slots(self) -> int:
        return self._core.padded_slots

    @padded_slots.setter
    def padded_slots(self, v: int):
        self._core.padded_slots = int(v)

    @property
    def slot_histogram(self) -> dict[int, int]:
        """Blocks dispatched per slot shape (adaptive sizing observability)."""
        return dict(self._core.slot_histogram)

    # -- scoring -------------------------------------------------------------

    def _submit(self, block: np.ndarray) -> jax.Array:
        """Dispatch one slot block; returns the in-flight device buffer
        (jax dispatch is async — this does not wait for the result)."""
        x = jnp.asarray(block)
        raw = self.on_device_features
        if self._mesh is not None:
            return accelerator_forward_sharded(
                self._qp, x, self.cfg, mesh=self._mesh,
                axis_name=self._mesh_axis, interpret=self._interpret,
                raw_windows=raw,
            )
        return accelerator_forward(
            self._qp, x, self.cfg, interpret=self._interpret, raw_windows=raw
        )

    def _submit_rows(self, rows, slots: int) -> jax.Array:
        """DispatchCore submit hook: pack live rows into the next rotation
        buffer of the chosen slot shape and dispatch it."""
        return self._submit(self._pool.pack(rows, slots))

    def _forward(self, rows: np.ndarray) -> np.ndarray:
        """Micro-batch (n, row_width) inputs — features, or raw windows when
        the front-end is fused — through the shared dispatch core: the slot
        policy picks each block's shape (fixed ``batch_slots``, or the
        adaptive ladder), blocks come from the preallocated buffer rotation,
        and up to ``inflight`` blocks overlap on device with harvest-time
        ``block_until_ready``."""
        return np.stack(self._core.dispatch(list(rows)))

    def precompile(self) -> tuple[int, ...]:
        """Trace the jitted forward once per dispatchable slot shape (the
        policy's ladder) so adaptive serving never hits a compile stall
        mid-round; returns the ladder."""
        precompile_slot_shapes(
            self._qp,
            self.cfg,
            self.slot_policy.ladder,
            row_width=self._in_width,
            mesh=self._mesh,
            axis_name=self._mesh_axis,
            interpret=self._interpret,
            raw_windows=self.on_device_features,
        )
        return self.slot_policy.ladder

    def step(self) -> list[WindowScore]:
        """Score one round over the admitted backlog.

        With the default :class:`~repro.serving.batching.AdmissionPolicy`
        this is the classic beat — at most one ready window per stream,
        every admitted stream served.  ``max_per_stream_per_round`` lets a
        backlogged stream drain several windows in one round;
        ``round_budget`` caps the round's total windows, allocated
        depth-fair (:func:`~repro.serving.batching.fair_allocation`) so a
        firehose stream can never displace another stream's first window.
        Windows beyond a stream's allocation stay buffered and are counted
        in ``deferred_windows``.

        Transactional: the round either completes — windows scored, rings
        advanced, tracker updated — or, if the forward raises, leaves every
        ring and the tracker exactly as they were (windows are *peeked* and
        only committed after scoring).  A supervisor that catches the raise
        can simply call ``step()`` again: the same windows are re-scored and
        the per-stream window indices never desync.

        Returns the per-window scores of this round (empty when no admitted
        stream had a complete window buffered).
        """
        adm = self.admission
        cand = np.flatnonzero((self._ready_counts > 0) & self._admitted)
        if cand.size == 0:
            return []
        ready = self._ready_counts[cand]
        want = np.minimum(ready, adm.max_per_stream_per_round)
        alloc = fair_allocation(want, adm.round_budget)
        # Gather stream-major: stream cand[i] contributes alloc[i]
        # consecutive windows starting at offs[i].
        offs = np.zeros(cand.size, np.int64)
        np.cumsum(alloc[:-1], out=offs[1:])
        wins = [
            self._rings[s].peek_windows(int(k))
            for s, k in zip(cand, alloc)
            if k
        ]
        stacked = np.concatenate(wins, axis=0)
        if self.on_device_features:
            rows = stacked  # raw windows; the front-end runs in-graph
        else:
            rows = features.batch_features(stacked, self.feature_kind)
        p_uav = self._forward(rows)[:, 1]  # may raise: nothing committed yet
        # Tracker rounds go depth by depth — every served stream's d-th
        # window lands in one masked vector update — so each stream's
        # probability sequence reaches its EMA in exactly push order and the
        # numbers stay bitwise identical to scoring one window per round.
        out: list[WindowScore] = []
        for d in range(int(alloc.max())):
            m = alloc > d
            sel = cand[m]
            full = np.zeros(self.n_streams, np.float64)
            mask = np.zeros(self.n_streams, bool)
            full[sel] = p_uav[offs[m] + d]  # exact float32 -> float64 widening
            mask[sel] = True
            state = self.tracker.update(full, mask)
            out.extend(
                WindowScore(
                    stream=int(s),
                    window_idx=int(state["idx"][s]),
                    p_uav=float(full[s]),
                    smoothed=float(state["smoothed"][s]),
                    active=bool(state["active"][s]),
                )
                for s in sel
            )
        # Commit: consume the scored windows only now that the forward and
        # the tracker rounds all succeeded.
        for s, k in zip(cand, alloc):
            for _ in range(int(k)):
                self._rings[s].advance()
            self._ready_counts[s] = self._rings[s].ready
        self.windows_scored += int(alloc.sum())
        self.rounds += 1
        self.served_windows[cand] += alloc
        self.deferred_windows[cand] += ready - alloc
        # Overflow eviction: a stream whose ring dropped samples in
        # ``evict_overflow_rounds`` consecutive committed rounds is
        # de-admitted; the supervisor collects it via take_evictions().
        overflowed = self._dropped_since_round > 0
        self._overflow_rounds = np.where(overflowed, self._overflow_rounds + 1, 0)
        self._dropped_since_round[:] = 0
        if adm.evict_overflow_rounds is not None:
            evict = np.flatnonzero(
                self._admitted
                & (self._overflow_rounds >= adm.evict_overflow_rounds)
            )
            for s in evict:
                self._admitted[s] = False
                self._pending_evictions.append(int(s))
        return out

    def drain(self) -> list[WindowScore]:
        """Run rounds until every buffered window has been scored."""
        out: list[WindowScore] = []
        while True:
            scored = self.step()
            if not scored:
                return out
            out.extend(scored)

    def finalize(self) -> list[list[TrackEvent]]:
        """Flush still-open tracks; returns per-stream event lists."""
        return self.tracker.finalize()

    # -- crash recovery ------------------------------------------------------

    def snapshot(self) -> dict:
        """Deep-copied snapshot of all serving state: every ring's buffer and
        read/write heads, the tracker's per-stream arrays and emitted events,
        and the observability counters.

        The contract (pinned by the fault-tolerance conformance tests): a
        fresh engine built from the *same baked artifact* that ``restore``s
        this snapshot and then receives the same pushes produces window
        scores and ``TrackEvent`` lists bitwise identical to the engine that
        never died.  Weights are deliberately NOT part of the snapshot — the
        artifact is immutable and shared, so a supervisor rebuilds workers
        from it and restores only the cheap mutable state.

        ``pending_evictions`` (streams de-admitted but not yet collected via
        :meth:`take_evictions`) is part of the snapshot: without it a revive
        from a snapshot taken between the de-admission and the collection
        would leave the stream de-admitted but never actually evicted — no
        event stash, a stale supervisor route, pushes journaled forever."""
        return {
            "rings": [r.state_dict() for r in self._rings],
            "pending_evictions": [int(s) for s in self._pending_evictions],
            "tracker": self.tracker.state_dict(),
            "counters": {
                "windows_scored": self.windows_scored,
                "forward_calls": self.forward_calls,
                "padded_slots": self.padded_slots,
                "rounds": self.rounds,
                "dropped_samples": self._dropped_samples,
                "rejected_chunks": self.rejected_chunks.copy(),
                "zeroed_samples": self.zeroed_samples.copy(),
                "clipped_chunks": self.clipped_chunks.copy(),
                "served_windows": self.served_windows.copy(),
                "deferred_windows": self.deferred_windows.copy(),
                "refused_chunks": self.refused_chunks.copy(),
                "overflow_rounds": self._overflow_rounds.copy(),
                "dropped_since_round": self._dropped_since_round.copy(),
                "admitted": self._admitted.copy(),
                "seen": self._seen.copy(),
            },
        }

    def restore(self, snap: dict):
        """Load a :meth:`snapshot` into this engine (same ``n_streams`` and
        window/hop geometry required)."""
        if len(snap["rings"]) != self.n_streams:
            raise ValueError(
                f"snapshot holds {len(snap['rings'])} stream(s) but this "
                f"engine was built for {self.n_streams}"
            )
        for ring, sd in zip(self._rings, snap["rings"]):
            ring.load_state_dict(sd)
        self.tracker.load_state_dict(snap["tracker"])
        c = snap["counters"]
        self.windows_scored = int(c["windows_scored"])
        self.forward_calls = int(c["forward_calls"])
        self.padded_slots = int(c["padded_slots"])
        self.rounds = int(c["rounds"])
        self._dropped_samples = int(c["dropped_samples"])
        self.rejected_chunks = np.asarray(c["rejected_chunks"], np.int64).copy()
        self.zeroed_samples = np.asarray(c["zeroed_samples"], np.int64).copy()
        self.clipped_chunks = np.asarray(c["clipped_chunks"], np.int64).copy()
        self.served_windows = np.asarray(c["served_windows"], np.int64).copy()
        self.deferred_windows = np.asarray(c["deferred_windows"], np.int64).copy()
        self.refused_chunks = np.asarray(c["refused_chunks"], np.int64).copy()
        self._overflow_rounds = np.asarray(c["overflow_rounds"], np.int64).copy()
        self._dropped_since_round = np.asarray(
            c["dropped_since_round"], np.int64
        ).copy()
        self._admitted = np.asarray(c["admitted"], bool).copy()
        self._seen = np.asarray(c["seen"], bool).copy()
        self._n_seen = int(self._seen.sum())
        # ``.get``: snapshots from before pending evictions were recorded
        # restore with none pending (their supervisors drained eagerly).
        self._pending_evictions = [
            int(s) for s in snap.get("pending_evictions", [])
        ]
        # ready counts are derived state: recompute from the restored rings
        self._ready_counts = np.array([r.ready for r in self._rings], np.int64)

    def snapshot_bytes(self) -> bytes:
        """:meth:`snapshot` serialised through the exact on-disk codec
        (:func:`repro.serving.durability.dumps_state`): dtypes, shapes and
        scalar counters survive the byte round-trip bit-for-bit."""
        from repro.serving.durability import dumps_state

        return dumps_state(self.snapshot())

    def restore_bytes(self, data: bytes) -> None:
        """Inverse of :meth:`snapshot_bytes`."""
        from repro.serving.durability import loads_state

        self.restore(loads_state(data))
