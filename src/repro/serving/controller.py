"""Closed-loop SLO autoscaler for the fleet supervisor.

PR 7 exported the raw signals — per-stream ``served_windows`` /
``deferred_windows``, fleet ``dropped_samples``, per-worker heartbeat age —
and the supervisor's snapshot/splice machinery already moves streams
between workers bitwise-losslessly.  This module closes the loop: a
:class:`FleetController` watches round latency percentiles (p50/p95/p99)
and drop/defer rates over a sliding window, compares them against a
declarative :class:`SLOTarget`, and resizes the fleet through three
actuators on :class:`~repro.serving.supervisor.FleetSupervisor`:

* ``spawn_worker()`` — scale up when latency or loss breaches the target:
  the most-loaded worker's streams split in half onto a new worker (and,
  with lanes, a new execution lane running concurrently);
* ``retire_worker()`` — scale down when every watched signal sits
  comfortably under target (margin-scaled), or immediately when a worker's
  heartbeat goes stale past ``max_heartbeat_age_s`` (presumed hung);
* ``retune_admission()`` — when the fleet is already at ``max_workers``
  and windows are being *deferred* (not dropped), widen the per-round
  admission budget instead of spawning.

Every actuation is bitwise lossless for every stream (the same invariant
the chaos suite pins for crash recovery), so the controller can act as
aggressively as its cooldown allows without ever perturbing the numbers —
autoscaling changes *when* windows are scored, never *what* they score.

The controller is deliberately deterministic and injectable: latencies
arrive via :meth:`observe` (the caller times its own rounds — tests inject
synthetic latencies), counters are read off the supervisor, and decisions
fire in a fixed priority order (liveness > pressure > headroom) with a
cooldown between actions so one burst cannot thrash the fleet.  Every
decision lands in :attr:`actions` with the metrics that justified it.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.serving.supervisor import FleetSupervisor


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """Declarative serving objective the controller steers toward.

    Any threshold left ``None`` is simply not watched.  ``min_workers`` /
    ``max_workers`` bound the fleet size the controller may steer to — it
    never spawns past the cap or retires below the floor.
    """

    round_p95_ms: float | None = None  # p95 round latency ceiling
    max_defer_rate: float | None = None  # deferred/(served+deferred) ceiling
    max_drop_rate: float | None = None  # overflow-dropped sample fraction
    max_heartbeat_age_s: float | None = None  # stale-worker liveness bound
    min_workers: int = 1
    max_workers: int = 8

    def __post_init__(self):
        if self.min_workers < 1:
            raise ValueError(f"min_workers must be >= 1, got {self.min_workers}")
        if self.max_workers < self.min_workers:
            raise ValueError(
                f"max_workers ({self.max_workers}) must be >= min_workers "
                f"({self.min_workers})"
            )
        for name in ("round_p95_ms", "max_defer_rate", "max_drop_rate",
                     "max_heartbeat_age_s"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")


def _percentile(values: list[float], q: float) -> float | None:
    return None if not values else float(np.percentile(values, q))


class FleetController:
    """Watches a fleet's SLO signals and resizes it against a target.

    Parameters
    ----------
    fleet:
        The supervisor to steer (sequential or lane-parallel).
    slo:
        The :class:`SLOTarget` to hold.
    window:
        Sliding-window length, in rounds, over which latencies and counter
        deltas are aggregated.
    cooldown_rounds:
        Rounds to hold fire after any action (lets the previous action's
        effect show up in the window before judging again).
    scale_down_margin:
        Scale-down requires every watched signal below ``margin * target``
        — hysteresis so the fleet doesn't oscillate at the threshold.
    budget_growth:
        Multiplier applied to the admission round budget (or the per-stream
        cap when no budget is set) by the retune actuator.
    """

    def __init__(
        self,
        fleet: FleetSupervisor,
        slo: SLOTarget,
        *,
        window: int = 16,
        cooldown_rounds: int = 4,
        scale_down_margin: float = 0.5,
        budget_growth: int = 2,
    ):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if not 0 < scale_down_margin < 1:
            raise ValueError(
                f"scale_down_margin must be in (0, 1), got {scale_down_margin}"
            )
        self.fleet = fleet
        self.slo = slo
        self.cooldown_rounds = int(cooldown_rounds)
        self.scale_down_margin = float(scale_down_margin)
        self.budget_growth = int(budget_growth)
        self._round_ms: collections.deque = collections.deque(maxlen=window)
        self._served_d: collections.deque = collections.deque(maxlen=window)
        self._deferred_d: collections.deque = collections.deque(maxlen=window)
        self._dropped_d: collections.deque = collections.deque(maxlen=window)
        self._last = self._counters()
        self._cooldown = 0
        #: audit log: one dict per actuation, with the metrics behind it
        self.actions: list[dict] = []

    # -- observation ---------------------------------------------------------

    def _counters(self) -> dict:
        f = self.fleet
        return {
            "served": int(f.served_windows.sum()),
            "deferred": int(f.deferred_windows.sum()),
            "dropped": int(f.dropped_samples),
        }

    def observe(self, round_ms: float) -> None:
        """Record one completed fleet round: its wall-clock latency plus the
        served/deferred/dropped deltas since the previous observation."""
        self._round_ms.append(float(round_ms))
        cur = self._counters()
        self._served_d.append(cur["served"] - self._last["served"])
        self._deferred_d.append(cur["deferred"] - self._last["deferred"])
        # dropped_samples sums live workers only, so retiring a worker can
        # step the total; clamp deltas at 0 rather than report phantom drops
        self._dropped_d.append(max(0, cur["dropped"] - self._last["dropped"]))
        self._last = cur

    def metrics(self) -> dict:
        """Aggregate SLO signals over the sliding window."""
        lat = list(self._round_ms)
        served = sum(self._served_d)
        deferred = sum(self._deferred_d)
        dropped = sum(self._dropped_d)
        health = self.fleet.health()
        ages = [
            h["heartbeat_age_s"]
            for h in health
            if h["alive"] and h["heartbeat_age_s"] is not None
        ]
        return {
            "rounds": len(lat),
            "p50_ms": _percentile(lat, 50),
            "p95_ms": _percentile(lat, 95),
            "p99_ms": _percentile(lat, 99),
            "defer_rate": deferred / max(1, served + deferred),
            # dropped counts samples, served counts windows: normalise drops
            # per served window so the rate is dimensionless and bounded-ish
            "drop_rate": dropped / max(1, dropped + served),
            "max_heartbeat_age_s": max(ages) if ages else None,
            "n_live": self.fleet.n_live_workers,
        }

    # -- decision ------------------------------------------------------------

    def _breach(self, m: dict) -> str | None:
        """Name of the first watched signal above target, or None."""
        slo = self.slo
        if (
            slo.round_p95_ms is not None
            and m["p95_ms"] is not None
            and m["rounds"] >= self._round_ms.maxlen
            and m["p95_ms"] > slo.round_p95_ms
        ):
            return "p95_ms"
        if slo.max_drop_rate is not None and m["drop_rate"] > slo.max_drop_rate:
            return "drop_rate"
        if slo.max_defer_rate is not None and m["defer_rate"] > slo.max_defer_rate:
            return "defer_rate"
        return None

    def _headroom(self, m: dict) -> bool:
        """True when every watched signal sits under margin * target."""
        slo, margin = self.slo, self.scale_down_margin
        if m["rounds"] < self._round_ms.maxlen:
            return False  # not enough evidence to shrink on
        if slo.round_p95_ms is not None and not (
            m["p95_ms"] is not None and m["p95_ms"] < margin * slo.round_p95_ms
        ):
            return False
        if slo.max_drop_rate is not None and not (
            m["drop_rate"] < margin * slo.max_drop_rate
        ):
            return False
        if slo.max_defer_rate is not None and not (
            m["defer_rate"] < margin * slo.max_defer_rate
        ):
            return False
        return True

    def _stale_worker(self) -> int | None:
        if self.slo.max_heartbeat_age_s is None:
            return None
        stale = [
            h["worker"]
            for h in self.fleet.health()
            if h["alive"]
            and h["heartbeat_age_s"] is not None
            and h["heartbeat_age_s"] > self.slo.max_heartbeat_age_s
        ]
        return stale[0] if stale else None

    def _grown_admission(self):
        adm = self.fleet.admission
        if adm.round_budget is not None:
            return dataclasses.replace(
                adm, round_budget=adm.round_budget * self.budget_growth
            )
        return dataclasses.replace(
            adm,
            max_per_stream_per_round=(
                adm.max_per_stream_per_round * self.budget_growth
            ),
        )

    def actuate(self) -> dict | None:
        """Judge the current window and fire at most one actuator.  Returns
        the action record (also appended to :attr:`actions`), or None."""
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        m = self.metrics()
        slo = self.slo
        action: dict | None = None

        # 1) liveness: a stale heartbeat means a presumed-hung worker; fold
        #    its streams into a survivor (lossless) rather than wait on it
        stale = self._stale_worker()
        if stale is not None and m["n_live"] > slo.min_workers:
            if self.fleet.retire_worker(stale, reason="stale heartbeat"):
                action = {"kind": "retire_stale", "worker": stale}

        # 2) pressure: a breached target wants more parallelism — spawn a
        #    worker (a lane, when lanes are on); at the size cap, widen the
        #    admission budget instead if the pain is deferral
        if action is None:
            breach = self._breach(m)
            if breach is not None:
                if m["n_live"] < slo.max_workers:
                    idx = self.fleet.spawn_worker()
                    if idx is not None:
                        action = {"kind": "spawn", "worker": idx,
                                  "breach": breach}
                elif breach == "defer_rate":
                    adm = self._grown_admission()
                    self.fleet.retune_admission(adm)
                    action = {
                        "kind": "retune",
                        "breach": breach,
                        "round_budget": adm.round_budget,
                        "max_per_stream_per_round": adm.max_per_stream_per_round,
                    }

        # 3) headroom: everything comfortably under target — give back a
        #    worker (fold the least-loaded into the survivors, lossless)
        if (
            action is None
            and m["n_live"] > slo.min_workers
            and self._headroom(m)
        ):
            if self.fleet.retire_worker(reason="SLO headroom"):
                action = {"kind": "retire"}

        if action is not None:
            action["round"] = self.fleet.round
            action["metrics"] = m
            self.actions.append(action)
            self._cooldown = self.cooldown_rounds
        return action

    def step(self, round_ms: float) -> dict | None:
        """Convenience: :meth:`observe` then :meth:`actuate`."""
        self.observe(round_ms)
        return self.actuate()
