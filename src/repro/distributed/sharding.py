"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code names tensor dimensions with *logical* axes ("batch", "heads",
"mlp", ...).  A ``ShardingRules`` table maps logical axes onto mesh axes;
``logical_to_spec`` resolves a logical shape to a ``PartitionSpec``, dropping
any mesh axis that does not evenly divide the dimension (the fallback is
replication, recorded in ``FALLBACKS`` so the dry-run can report it — e.g.
gemma-2b's kv_heads=1 can never shard over a 16-way model axis).

Activations are constrained in-graph via ``constrain`` which reads an
ambient context (set by the launcher); with no context it is a no-op, so the
same model code runs on 1 CPU device and on the 512-chip production mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: mesh axis name used by the serving layer's sharded-batch dispatch
STREAM_AXIS = "streams"


def stream_mesh(shards: int, *, axis: str = STREAM_AXIS) -> Mesh:
    """1-D serving mesh: the first ``shards`` local devices on one axis.

    The monitor engine splits its fixed ``batch_slots`` along this axis
    (weights replicated, activation rows sharded) — the software analogue of
    the paper's "more streams per watt" sequential scaling.  On CPU,
    simulated devices come from ``XLA_FLAGS=--xla_force_host_platform_
    device_count=N`` (set before the first jax import).
    """
    devs = np.asarray(jax.devices())
    if shards < 1 or shards > devs.size:
        raise ValueError(
            f"stream_mesh: need 1 <= shards <= {devs.size} local devices, got "
            f"{shards} (on CPU, raise the device count via XLA_FLAGS="
            f"--xla_force_host_platform_device_count before importing jax)"
        )
    return Mesh(devs[:shards].reshape(shards), (axis,))

# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "decode_batch": ("pod", "data"),
    "seq": (),  # replicated by default; long-context rules shard it
    "kv_seq": (),
    # decode KV caches when kv_heads cannot use the model axis (MQA/GQA with
    # few kv heads): shard the *sequence* dim over model instead — softmax
    # combines with a tiny per-step collective (ring-decode attention).
    "kv_seq_model": ("model",),
    "embed": (),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "experts": ("model",),
    "expert_capacity": (),
    "ssm_heads": ("model",),
    "ssm_state": (),
    "conv_kernel": (),
    "layers": (),
    "frontend": (),
    "classes": ("model",),
}

#: long-context serving rules: shard the KV/sequence axis over "data"
#: (ring-attention style cache partitioning) since decode batch is tiny.
LONG_CONTEXT_OVERRIDES = {
    "kv_seq": ("data",),
    "kv_seq_model": ("data", "model"),
    "decode_batch": ("pod",),
}


class ShardingRules:
    def __init__(self, mesh: Mesh, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        self.fallbacks: list[tuple[str, int, str]] = []  # (logical, size, reason)

    def mesh_axes_for(self, logical: Optional[str]) -> tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.rules.get(logical, ())
        return tuple(a for a in axes if a in self.mesh.axis_names)

    def spec(self, logical_shape: Sequence[Optional[str]], dims: Optional[Sequence[int]] = None) -> P:
        """Resolve logical axis names (+ optional dim sizes for divisibility).

        A mesh axis may shard at most one dimension of a tensor: earlier
        dimensions win (e.g. MoE expert weights ("experts","embed","mlp")
        give the model axis to "experts"; "mlp" falls back to replicated).
        Non-divisible mappings also fall back; both are logged.
        """
        parts = []
        used: set[str] = set()
        for i, name in enumerate(logical_shape):
            axes = self.mesh_axes_for(name)
            kept = []
            total = 1
            for a in axes:
                if a in used:
                    self.fallbacks.append((name or "?", -1, f"{a} already used in tensor"))
                    continue
                n = self.mesh.shape[a]
                if dims is not None:
                    size = dims[i]
                    if size % (total * n) != 0:
                        self.fallbacks.append((name or "?", size, f"{a}={n} !| {size}"))
                        continue
                kept.append(a)
                total *= n
            used.update(kept)
            if not kept:
                parts.append(None)
            elif len(kept) == 1:
                parts.append(kept[0])
            else:
                parts.append(tuple(kept))
        return P(*parts)

    def sharding(self, logical_shape, dims=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_shape, dims))


_ACTIVE = threading.local()


def active_rules() -> Optional[ShardingRules]:
    return getattr(_ACTIVE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_ACTIVE, "rules", None)
    _ACTIVE.rules = rules
    try:
        yield rules
    finally:
        _ACTIVE.rules = prev


def kv_seq_axis(n_kv_heads: int) -> str:
    """Logical axis for KV-cache sequence dims: "kv_seq_model" when the kv
    heads cannot occupy the model axis (must match the launcher's choice in
    launch/specs.py, or resharding all-gathers appear around every cache)."""
    rules = active_rules()
    if rules is None:
        return "kv_seq"
    msize = dict(rules.mesh.shape).get("model", 1)
    return "kv_seq" if n_kv_heads % msize == 0 else "kv_seq_model"


def constrain(x: jax.Array, logical_shape: Sequence[Optional[str]]) -> jax.Array:
    """Annotate an activation with its logical sharding (no-op off-mesh)."""
    rules = active_rules()
    if rules is None:
        return x
    spec = rules.spec(logical_shape, dims=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def is_logical_leaf(t) -> bool:
    return isinstance(t, tuple) and all(isinstance(i, (str, type(None))) for i in t)


def tree_shardings(rules: ShardingRules, abstract_tree, logical_tree):
    """Build a NamedSharding pytree for params: ``logical_tree`` mirrors the
    abstract param tree, with tuples of logical axis names at the leaves.

    Mapped over ``logical_tree`` first (its tuple leaves would otherwise be
    traversed as pytree nodes)."""
    return jax.tree_util.tree_map(
        lambda logical, aval: rules.sharding(logical, dims=aval.shape),
        logical_tree,
        abstract_tree,
        is_leaf=is_logical_leaf,
    )
