"""Distributed embedding gather (hillclimb lever: the one-hot-matmul fix).

With the embedding table sharded on the vocab axis, GSPMD lowers
``jnp.take(table, ids)`` to a one-hot matmul against the local vocab shard:
T x V/16 x D MACs per device — for gemma-2b train_4k that is 6.6e13 FLOPs
per device, ~2.5x the entire transformer forward.  The classic fix (Megatron
VocabParallelEmbedding) is a shard-local gather + mask + psum:

    each shard gathers ids that fall inside its vocab range (clipped
    dynamic-gather, zero elsewhere) and the partial embeddings all-reduce —
    collective cost = one activation all-reduce, compute cost ~ 0.

Enabled by ``ArchConfig.sharded_embed_gather`` (off for the paper-faithful
baseline; on in the optimized variants).  Falls back to plain take when no
mesh rules are active or the vocab axis is unsharded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import active_rules


def embedding_gather(table: jax.Array, ids: jax.Array) -> jax.Array:
    """(V, D) table, (...,) int32 ids -> (..., D); vocab-parallel when the
    active sharding rules shard the vocab axis."""
    rules = active_rules()
    if rules is None:
        return jnp.take(table, ids, axis=0)
    vocab_axes = rules.mesh_axes_for("vocab")
    vocab_axes = tuple(a for a in vocab_axes if table.shape[0] % rules.mesh.shape[a] == 0)
    if not vocab_axes:
        return jnp.take(table, ids, axis=0)
    mesh = rules.mesh
    n_shards = int(np.prod([mesh.shape[a] for a in vocab_axes]))
    shard_v = table.shape[0] // n_shards
    batch_axes = rules.mesh_axes_for("batch")

    table_spec = P(vocab_axes if len(vocab_axes) > 1 else vocab_axes[0], None)
    ids_spec = P(batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None))
    out_spec = P(ids_spec[0] if len(ids_spec) else None, None)

    def local_gather(tbl, ids_l):
        # rank of this shard along the vocab axes (row-major combine)
        idx = jax.lax.axis_index(vocab_axes[0])
        for a in vocab_axes[1:]:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        lo = idx * shard_v
        rel = ids_l - lo
        hit = (rel >= 0) & (rel < shard_v)
        rel = jnp.clip(rel, 0, shard_v - 1)
        out = jnp.take(tbl, rel.reshape(-1), axis=0)
        out = jnp.where(hit.reshape(-1, 1), out, 0)
        for a in vocab_axes:
            out = jax.lax.psum(out, a)
        return out.reshape(ids_l.shape + (tbl.shape[1],))

    flat_ids = ids.reshape(ids.shape[0], -1)
    out = shard_map(
        local_gather,
        mesh=mesh,
        in_specs=(table_spec, P(ids_spec[0] if len(ids_spec) else None, None)),
        out_specs=P(out_spec[0], None, None),
        check_rep=False,
    )(table, flat_ids)
    return out.reshape(ids.shape + (table.shape[1],))
