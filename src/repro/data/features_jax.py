"""JAX twin of the numpy DSP front-end (:mod:`repro.data.features`).

The serving path fuses feature extraction into the jitted accelerator
program: ``accelerator_forward(..., raw_windows=True)`` takes raw
``(B, 12800)`` audio windows and the first in-graph stage is this module's
:func:`feature_rows`.  All constant operands (Hann windows, frame-gather
indices, mel filterbank, DCT-II matrix, Welch segment window) are built once
per feature kind in numpy and closed over as jit constants — tracing never
rebuilds them.

Two numerical contracts, deliberately different in strength:

* **numpy vs JAX is tolerance-bounded, NOT bitwise.**  The numpy path
  (:func:`repro.data.features.feature_vector`) is the float64 oracle; this
  path computes in float32 on-device.  ``PARITY_ATOL`` documents the
  per-kind bound the parity tests enforce.

* **within the JAX path, row i is bitwise independent of its co-batch.**
  Every op in the pipeline is either batched with strictly per-row
  arithmetic — framing/gather, windowing, FFT (each 1-D transform is an
  independent computation; no cross-transform arithmetic exists),
  elementwise math, and reductions over per-row axes — or, for the two
  projections where that does NOT hold (mel filterbank and DCT-II: XLA gemm
  blocking reassociates the contraction as the M dimension grows, which is
  measurably batch-shape-dependent on CPU, and ``vmap``-ed batched gemm
  re-blocks the same way), run under ``jax.lax.map`` so each row gets the
  identical fixed-shape matmul regardless of batch size, slot position, or
  co-batch content.  The streaming == batched == sharded conformance
  guarantee needs feature bits that survive re-batching and shard-local
  recomputation; tests/test_features_jax.py pins the property across batch
  sizes, permutations and silence padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.features import (
    FEATURE_DIMS,
    HOP,
    N_FFT,
    N_SAMPLES,
    dct_ii,
    mel_filterbank,
)

#: per-kind max-abs-deviation bound of the float32 JAX path against the
#: float64 numpy oracle, on unit-RMS-normalised feature vectors (enforced
#: with margin by tests/test_features_jax.py).  The bound covers real audio
#: windows; a degenerate all-constant window (e.g. exact silence) normalises
#: to 0 in float64 but to an arbitrary finite constant in float32 — the
#: engine discards those (dead-slot) outputs, so only finiteness holds there.
PARITY_ATOL = {
    "mfcc20": 5e-3,
    "mel128": 5e-3,
    "psd": 5e-3,
    "zcr": 1e-4,
}


@functools.lru_cache(maxsize=8)
def _hann32(n: int) -> np.ndarray:
    return np.hanning(n).astype(np.float32)


@functools.lru_cache(maxsize=8)
def _frame_idx(n_samples: int, n_fft: int, hop: int) -> np.ndarray:
    """Gather indices into the centre-padded signal: (frames, n_fft)."""
    n_frames = 1 + n_samples // hop
    return np.arange(n_fft)[None, :] + hop * np.arange(n_frames)[:, None]


@functools.lru_cache(maxsize=8)
def _mel32(n_mels: int) -> np.ndarray:
    """(bins, n_mels) float32 mel projection (transposed for right-matmul)."""
    return mel_filterbank(n_mels).astype(np.float32).T


@functools.lru_cache(maxsize=8)
def _dct32(n_out: int, n_in: int) -> np.ndarray:
    """(n_in, n_out) float32 DCT-II projection (transposed)."""
    return dct_ii(n_out, n_in).astype(np.float32).T


# ---------------------------------------------------------------------------
# Batched DSP with strictly per-row arithmetic (leading axis = batch)
# ---------------------------------------------------------------------------


def _project_rows(x: jax.Array, m: np.ndarray) -> jax.Array:
    """(B, F, K) @ (K, M) -> (B, F, M) with per-row-bitwise guarantees.

    The one place the batched formulation would leak across rows: XLA lowers
    both ``reshape+matmul`` and a ``vmap``-ed matmul to gemms whose blocking
    (and therefore contraction association) changes with the batched M
    dimension.  ``lax.map`` pins each row to the identical (F, K) @ (K, M)
    gemm instead; the projections are small (<2 MFLOP/row), so the scan cost
    is noise next to the batched FFTs.
    """
    return jax.lax.map(lambda q: q @ m, x)


def _stft_power(x: jax.Array, n_fft: int = N_FFT, hop: int = HOP) -> jax.Array:
    """(B, n) -> (B, frames, n_fft//2+1) power spectrogram.

    ``re^2 + im^2`` rather than ``abs(z)^2``: same quantity without the
    hypot/sqrt round-trip (the float64 oracle keeps numpy's ``abs**2``; the
    difference is far inside PARITY_ATOL).
    """
    pad = n_fft // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad)), mode="reflect")
    frames = xp[:, _frame_idx(x.shape[1], n_fft, hop)] * _hann32(n_fft)
    spec = jnp.fft.rfft(frames, axis=-1)
    return spec.real**2 + spec.imag**2


def _melspectrogram(x: jax.Array, n_mels: int) -> jax.Array:
    """(B, n) -> (B, frames, n_mels) log-mel energies."""
    return jnp.log10(_project_rows(_stft_power(x), _mel32(n_mels)) + 1e-10)


def _mfcc(x: jax.Array, n_mfcc: int = 20, n_mels: int = 64) -> jax.Array:
    return _project_rows(_melspectrogram(x, n_mels), _dct32(n_mfcc, n_mels))


def _welch_psd(x: jax.Array, n_bins: int = 512) -> jax.Array:
    seg = 2 * n_bins
    n_seg = x.shape[1] // seg
    segs = x[:, : n_seg * seg].reshape(-1, n_seg, seg) * _hann32(seg)
    spec = jnp.fft.rfft(segs, axis=-1)
    p = jnp.mean(spec.real**2 + spec.imag**2, axis=1)[:, :n_bins]
    return jnp.log10(p + 1e-10)


def _zcr(x: jax.Array, n_frames: int = 128) -> jax.Array:
    hop = x.shape[1] // n_frames
    frames = x[:, : n_frames * hop].reshape(-1, n_frames, hop)
    signs = jnp.sign(frames)
    signs = jnp.where(signs == 0, 1.0, signs)
    return jnp.mean(jnp.abs(jnp.diff(signs, axis=2)) > 0, axis=2)


def _normalize(v: jax.Array) -> jax.Array:
    """Zero-mean, unit-RMS (paper §IV-A), per row."""
    v = v - jnp.mean(v, axis=1, keepdims=True)
    rms = jnp.sqrt(jnp.mean(v**2, axis=1, keepdims=True))
    return v / (rms + 1e-8)


def _feature_batch(x: jax.Array, kind: str) -> jax.Array:
    """(B, n_samples) raw windows -> (B, FEATURE_DIMS[kind]).

    Mirrors :func:`repro.data.features.feature_vector` op for op, in float32.
    """
    bsz = x.shape[0]
    peak = jnp.max(jnp.abs(x), axis=1, keepdims=True) + 1e-9
    x = x / peak
    if kind == "mfcc20":
        m = _mfcc(x, 20)[:, :51].reshape(bsz, -1)
        pooled = _melspectrogram(x, 64).mean(axis=1)
        p = _welch_psd(x, 512)
        p10 = p[:, :510].reshape(bsz, 10, 51).mean(axis=2)
        z = _zcr(x)
        aux = jnp.stack([z.mean(axis=1), z.std(axis=1)], axis=1)
        v = jnp.concatenate([m, pooled, p10, aux], axis=1)
    elif kind == "mel128":
        logmel = _melspectrogram(x, 128)[:, :48]
        v = logmel.reshape(bsz, 8, 6, 128).mean(axis=2).reshape(bsz, -1)
    elif kind == "psd":
        v = _welch_psd(x, 512)
    elif kind == "zcr":
        v = _zcr(x, 128)
    else:
        raise ValueError(f"unknown feature kind {kind!r}")
    return _normalize(v)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def feature_rows(windows: jax.Array, kind: str) -> jax.Array:
    """(B, n_samples) raw windows -> (B, M) features, traceable in-graph.

    This is the stage ``accelerator_forward(..., raw_windows=True)`` fuses in
    front of the quantised datapath.  Row i's bits cannot depend on the batch
    it rode in with (see module docstring).
    """
    if kind not in FEATURE_DIMS:
        raise ValueError(f"unknown feature kind {kind!r}")
    return _feature_batch(windows.astype(jnp.float32), kind)


@functools.partial(jax.jit, static_argnames=("kind",))
def batch_features_jax(windows: jax.Array, kind: str = "mfcc20") -> jax.Array:
    """Standalone jitted batched front-end (the host-callable twin of
    :func:`repro.data.features.batch_features`)."""
    return feature_rows(windows, kind)
