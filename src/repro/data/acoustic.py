"""Synthetic UAV / non-UAV acoustic dataset (SHIELD8-UAV §IV-A, simulated).

The paper curates real quadrotor recordings plus environmental/airport
backgrounds (AudioSet, Pixabay).  None of that is available offline, so we
synthesise a physically-motivated substitute:

* **UAV**: rotor blade-pass-frequency (BPF) harmonic stacks.  A quadrotor's
  acoustic signature is the sum over four motors of harmonics of
  ``BPF = n_blades * rps``, each motor slightly detuned, with AM (load
  changes), FM jitter (RPM wander / startup transients), plus broadband
  motor/prop hiss.  Distance/orientation variation becomes gain + lowpass.
* **background**: wind (pink noise), bird chirps (fast FM tones), distant
  aircraft (low-frequency harmonic rumble — the deliberately confusable
  class for the airport scenario), traffic hum, quiet ambience.

Augmentation follows the paper: additive Gaussian noise over a controlled
SNR range.  The *relative* claims of Table II / Figs. 4-5 (precision-mode
ordering, feature-set ordering, SNR trends) are what this dataset supports;
absolute accuracies are dataset-specific (noted in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.features import N_SAMPLES, SR


def _t() -> np.ndarray:
    return np.arange(N_SAMPLES) / SR


def synth_uav(rng: np.random.Generator) -> np.ndarray:
    """One 0.8 s quadrotor window."""
    t = _t()
    n_motors = rng.integers(2, 5)
    base_rps = rng.uniform(45.0, 110.0)  # rotor revs/s
    n_blades = 2
    sig = np.zeros_like(t)
    for _ in range(n_motors):
        rps = base_rps * rng.uniform(0.96, 1.04)  # per-motor detune
        bpf = n_blades * rps
        # RPM wander -> FM jitter
        fm = 1.0 + 0.01 * rng.uniform(0.2, 1.0) * np.cumsum(
            rng.standard_normal(N_SAMPLES)
        ) / np.sqrt(np.arange(1, N_SAMPLES + 1)) / 8.0
        phase = 2 * np.pi * np.cumsum(bpf * fm) / SR
        decay = rng.uniform(0.6, 1.2)
        n_harm = int(min(20, (SR / 2 - 100) / bpf))
        for k in range(1, n_harm + 1):
            amp = k ** (-decay) * rng.uniform(0.7, 1.3)
            sig += amp * np.sin(k * phase + rng.uniform(0, 2 * np.pi))
    # AM from load changes
    am = 1.0 + rng.uniform(0.05, 0.3) * np.sin(2 * np.pi * rng.uniform(1, 8) * t)
    sig *= am
    # broadband prop hiss, high-frequency emphasis
    hiss = np.diff(rng.standard_normal(N_SAMPLES + 1))
    sig += rng.uniform(0.05, 0.25) * np.abs(sig).mean() / (np.abs(hiss).mean() + 1e-9) * hiss
    # distance: gain + one-pole lowpass
    lp = _onepole(sig, rng.uniform(0.2, 0.95))
    return (lp / (np.std(lp) + 1e-9)).astype(np.float32)


def _onepole(x: np.ndarray, alpha: float) -> np.ndarray:
    """One-pole lowpass y[n] = (1-a) x[n] + a y[n-1] via truncated-kernel conv.

    A Python sample loop is too slow for 12.8k-sample windows at dataset
    scale; the IIR is equivalent to convolution with (1-a) a^k, truncated
    where the kernel decays below 1e-4.
    """
    k = int(np.ceil(np.log(1e-4) / np.log(max(alpha, 1e-6))))
    k = max(1, min(k, 512))
    kern = (1.0 - alpha) * alpha ** np.arange(k)
    return np.convolve(x, kern)[: len(x)]


def _chirp(t, f0, f1, dur_frac, rng):
    n = len(t)
    start = rng.integers(0, max(1, int(n * (1 - dur_frac))))
    length = int(n * dur_frac)
    seg = np.zeros(n)
    tt = t[:length]
    f = np.linspace(f0, f1, length)
    seg[start : start + length] = np.sin(2 * np.pi * np.cumsum(f) / SR) * np.hanning(length)
    return seg


def synth_background(rng: np.random.Generator) -> np.ndarray:
    """One 0.8 s non-UAV window, drawn from 6 environment classes.

    Classes 2 and 5 are deliberately *confusable*: harmonic machinery whose
    fundamentals overlap the quadrotor BPF band — the airport/urban clutter
    that makes the paper's task sit near 90% rather than at ceiling.
    """
    t = _t()
    kind = rng.integers(0, 6)
    if kind == 0:  # wind: pink-ish noise
        w = rng.standard_normal(N_SAMPLES)
        sig = _onepole(w, 0.97) * 8.0 + 0.1 * w
    elif kind == 1:  # bird chirps: fast FM tones 2-6 kHz
        sig = 0.05 * rng.standard_normal(N_SAMPLES)
        for _ in range(rng.integers(1, 4)):
            f0 = rng.uniform(2000, 5000)
            sig += _chirp(t, f0, f0 * rng.uniform(0.7, 1.4), rng.uniform(0.05, 0.2), rng)
    elif kind == 2:  # distant aircraft: low-frequency harmonic rumble (confusable!)
        f0 = rng.uniform(25.0, 70.0)
        sig = np.zeros_like(t)
        for k in range(1, 12):
            sig += k ** rng.uniform(-1.6, -0.9) * np.sin(2 * np.pi * k * f0 * t + rng.uniform(0, 6.28))
        sig += _onepole(rng.standard_normal(N_SAMPLES), 0.995) * 15.0
    elif kind == 3:  # traffic hum
        sig = _onepole(rng.standard_normal(N_SAMPLES), 0.99) * 10.0
        sig += 0.3 * np.sin(2 * np.pi * rng.uniform(80, 120) * t)
    elif kind == 4:  # quiet ambience
        sig = 0.3 * _onepole(rng.standard_normal(N_SAMPLES), 0.9)
    else:  # generator / mower: harmonic stack INSIDE the UAV BPF band, with
        # AM and slight FM wander — the hardest negative
        f0 = rng.uniform(80.0, 200.0)
        fm = 1.0 + 0.005 * np.cumsum(rng.standard_normal(N_SAMPLES)) / np.sqrt(
            np.arange(1, N_SAMPLES + 1)
        )
        phase = 2 * np.pi * np.cumsum(f0 * fm) / SR
        sig = np.zeros_like(t)
        decay = rng.uniform(0.7, 1.3)
        for k in range(1, int(min(18, (SR / 2 - 100) / f0)) + 1):
            sig += k ** (-decay) * np.sin(k * phase + rng.uniform(0, 6.28))
        sig *= 1.0 + rng.uniform(0.05, 0.25) * np.sin(2 * np.pi * rng.uniform(1, 6) * t)
        sig += 0.1 * _onepole(rng.standard_normal(N_SAMPLES), 0.9)
        sig = _onepole(sig, rng.uniform(0.1, 0.8))
    return (sig / (np.std(sig) + 1e-9)).astype(np.float32)


def add_noise_snr(x: np.ndarray, snr_db: float, rng: np.random.Generator) -> np.ndarray:
    """Additive Gaussian noise at a target SNR (paper's augmentation)."""
    p_sig = np.mean(x**2)
    p_noise = p_sig / (10.0 ** (snr_db / 10.0))
    return x + rng.standard_normal(len(x)).astype(np.float32) * np.sqrt(p_noise)


@dataclasses.dataclass
class AcousticDataset:
    audio: np.ndarray  # (N, n_samples) float32
    labels: np.ndarray  # (N,) int32, 1 = UAV
    snr_db: np.ndarray  # (N,) float32 (inf = clean)


def make_dataset(
    n: int,
    seed: int = 0,
    snr_range: tuple[float, float] = (-5.0, 30.0),
    p_clean: float = 0.25,
) -> AcousticDataset:
    rng = np.random.default_rng(seed)
    audio = np.empty((n, N_SAMPLES), np.float32)
    labels = np.empty(n, np.int32)
    snrs = np.full(n, np.inf, np.float32)
    for i in range(n):
        label = int(rng.random() < 0.5)
        x = synth_uav(rng) if label else synth_background(rng)
        if rng.random() > p_clean:
            snr = rng.uniform(*snr_range)
            x = add_noise_snr(x, snr, rng)
            snrs[i] = snr
        audio[i] = x
        labels[i] = label
    return AcousticDataset(audio=audio, labels=labels, snr_db=snrs)


def make_snr_sweep(n_per_snr: int, snrs_db: list[float], seed: int = 1):
    """Matched clean-signal sets re-noised at each SNR (Figs. 4-5 harness)."""
    rng = np.random.default_rng(seed)
    clean = np.empty((n_per_snr, N_SAMPLES), np.float32)
    labels = np.empty(n_per_snr, np.int32)
    for i in range(n_per_snr):
        labels[i] = int(rng.random() < 0.5)
        clean[i] = synth_uav(rng) if labels[i] else synth_background(rng)
    out = {}
    for snr in snrs_db:
        noisy = np.stack([add_noise_snr(c, snr, rng) for c in clean])
        out[snr] = (noisy, labels)
    return out
