"""Acoustic feature extraction (SHIELD8-UAV §IV-A) in pure numpy.

The paper extracts MFCC, pooled mel-spectrogram coefficients, power spectral
density (PSD) and zero-crossing rate (ZCR) with librosa; librosa is not
available offline, so the equivalent DSP is implemented here (STFT → mel
filterbank → DCT-II MFCCs, Welch PSD, framewise ZCR) and unit-tested for the
standard identities (Parseval, DC response, filterbank partition-of-unity).

Every feature set yields a fixed-length 1-D vector (the 1D-F-CNN consumes
``x ∈ R^{1×M}``); lengths are chosen so the canonical deployed model (MFCC-20)
reproduces the paper's flatten size exactly: M=1096 → 3 pools → 137 frames ×
256 ch = 35,072 (Table I).
"""
from __future__ import annotations

import functools

import numpy as np

SR = 16_000
WINDOW_S = 0.8  # paper: 0.8-second windows
N_SAMPLES = int(SR * WINDOW_S)  # 12,800
N_FFT = 1024
HOP = 256

#: feature-set name -> model input length M
FEATURE_DIMS = {
    "mfcc20": 1096,  # 20 MFCC x 51 frames + 64 pooled-mel + 10 log10(PSD) + 2 ZCR
    "mel128": 1024,  # 128 mel bands x 8 pooled time segments
    "psd": 512,  # 512-bin log10 Welch PSD
    "zcr": 128,  # 128-frame ZCR sequence
}


def frame_signal(x: np.ndarray, n_fft: int = N_FFT, hop: int = HOP) -> np.ndarray:
    """Centre-padded frames, librosa-compatible count: 1 + len//hop."""
    pad = n_fft // 2
    xp = np.pad(x, (pad, pad), mode="reflect")
    n_frames = 1 + len(x) // hop
    idx = np.arange(n_fft)[None, :] + hop * np.arange(n_frames)[:, None]
    return xp[idx]


@functools.lru_cache(maxsize=8)
def _hann(n: int) -> np.ndarray:
    """Cached Hann window (np.hanning rebuilds a cosine table per call)."""
    return np.hanning(n)


def stft_power(x: np.ndarray, n_fft: int = N_FFT, hop: int = HOP) -> np.ndarray:
    """Power spectrogram, shape (frames, n_fft//2+1)."""
    frames = frame_signal(x, n_fft, hop) * _hann(n_fft)[None, :]
    spec = np.fft.rfft(frames, axis=-1)
    return np.abs(spec) ** 2


@functools.lru_cache(maxsize=8)
def mel_filterbank(n_mels: int, n_fft: int = N_FFT, sr: int = SR, fmin: float = 20.0, fmax: float = 7600.0) -> np.ndarray:
    """Triangular mel filterbank (Slaney-style, area-normalised), (n_mels, bins)."""

    def hz_to_mel(f):
        return 2595.0 * np.log10(1.0 + np.asarray(f) / 700.0)

    def mel_to_hz(m):
        return 700.0 * (10.0 ** (np.asarray(m) / 2595.0) - 1.0)

    pts = mel_to_hz(np.linspace(hz_to_mel(fmin), hz_to_mel(fmax), n_mels + 2))
    bins = np.fft.rfftfreq(n_fft, 1.0 / sr)
    fb = np.zeros((n_mels, len(bins)))
    for i in range(n_mels):
        lo, ctr, hi = pts[i], pts[i + 1], pts[i + 2]
        up = (bins - lo) / max(ctr - lo, 1e-9)
        down = (hi - bins) / max(hi - ctr, 1e-9)
        fb[i] = np.maximum(0.0, np.minimum(up, down))
        norm = fb[i].sum()
        if norm > 0:
            fb[i] /= norm
    return fb


def melspectrogram(x: np.ndarray, n_mels: int) -> np.ndarray:
    """(frames, n_mels) log-mel energies."""
    p = stft_power(x)
    mel = p @ mel_filterbank(n_mels).T
    return np.log10(mel + 1e-10)


@functools.lru_cache(maxsize=8)
def dct_ii(n_out: int, n_in: int) -> np.ndarray:
    """Orthonormal DCT-II matrix (n_out, n_in); cached like mel_filterbank
    (rebuilt per *window* otherwise — the oracle path shouldn't be
    gratuitously slow)."""
    k = np.arange(n_out)[:, None]
    n = np.arange(n_in)[None, :]
    m = np.cos(np.pi * k * (2 * n + 1) / (2 * n_in))
    m[0] *= 1.0 / np.sqrt(2)
    return m * np.sqrt(2.0 / n_in)


def mfcc(x: np.ndarray, n_mfcc: int = 20, n_mels: int = 64) -> np.ndarray:
    """(frames, n_mfcc) MFCCs."""
    logmel = melspectrogram(x, n_mels)
    return logmel @ dct_ii(n_mfcc, n_mels).T


def welch_psd(x: np.ndarray, n_bins: int = 512) -> np.ndarray:
    """Welch-averaged log10 PSD, length n_bins."""
    seg = 2 * n_bins
    n_seg = len(x) // seg
    segs = x[: n_seg * seg].reshape(n_seg, seg) * _hann(seg)[None, :]
    p = np.mean(np.abs(np.fft.rfft(segs, axis=-1)) ** 2, axis=0)[:n_bins]
    return np.log10(p + 1e-10)


def zcr(x: np.ndarray, n_frames: int = 128) -> np.ndarray:
    """Per-frame zero-crossing rate, length n_frames."""
    hop = len(x) // n_frames
    frames = x[: n_frames * hop].reshape(n_frames, hop)
    signs = np.sign(frames)
    signs[signs == 0] = 1
    return np.mean(np.abs(np.diff(signs, axis=1)) > 0, axis=1)


def _normalize(v: np.ndarray) -> np.ndarray:
    """Amplitude normalisation (paper §IV-A): zero-mean, unit-RMS."""
    v = v - np.mean(v)
    rms = np.sqrt(np.mean(v**2))
    return v / (rms + 1e-8)


def feature_vector(x: np.ndarray, kind: str = "mfcc20") -> np.ndarray:
    """Extract the 1×M feature vector for one 0.8 s window."""
    x = np.asarray(x, np.float64)
    peak = np.max(np.abs(x)) + 1e-9
    x = x / peak  # amplitude normalisation of the raw window
    if kind == "mfcc20":
        m = mfcc(x, 20)[:51].reshape(-1)  # 1020
        pooled = melspectrogram(x, 64).mean(axis=0)  # 64
        p = welch_psd(x, 512)
        p10 = p[:510].reshape(10, 51).mean(axis=1)  # 10 coarse PSD bands
        z = zcr(x)
        aux = np.array([z.mean(), z.std()])  # 2
        v = np.concatenate([m, pooled, p10, aux])
    elif kind == "mel128":
        logmel = melspectrogram(x, 128)[:48]  # (48, 128)
        v = logmel.reshape(8, 6, 128).mean(axis=1).reshape(-1)  # 8 pooled segments
    elif kind == "psd":
        v = welch_psd(x, 512)
    elif kind == "zcr":
        v = zcr(x, 128)
    else:
        raise ValueError(f"unknown feature kind {kind!r}")
    assert v.shape == (FEATURE_DIMS[kind],), (kind, v.shape)
    return _normalize(v).astype(np.float32)


def batch_features(windows: np.ndarray, kind: str = "mfcc20") -> np.ndarray:
    """(N, n_samples) raw windows -> (N, M) feature matrix."""
    return np.stack([feature_vector(w, kind) for w in windows])
