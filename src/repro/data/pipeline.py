"""Async, sharding-aware data pipeline (the AXI-DMA staging analogue).

A background thread produces batches ahead of the training step (double
buffering hides host latency exactly like the accelerator's on-chip staging
buffers hide AXI transfers), and batches are placed against the mesh's batch
sharding before being handed to the step function.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class PrefetchingLoader:
    """Wraps a batch-producing callable with a prefetch thread."""

    def __init__(
        self,
        make_batch: Callable[[int], dict],
        *,
        prefetch: int = 2,
        sharding=None,
    ):
        self._make = make_batch
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            batch = self._make(step)
            if batch is None:
                self._put(None)
                return
            if self._sharding is not None:
                batch = {
                    k: jax.device_put(v, self._sharding.get(k) if isinstance(self._sharding, dict) else self._sharding)
                    for k, v in batch.items()
                }
            if not self._put(batch):
                return  # close() raced us while the queue was full
            step += 1

    def _put(self, batch) -> bool:
        """Enqueue, re-checking the stop flag while the queue is full.

        A plain ``Queue.put`` blocks forever on a full queue, so a worker
        parked there would never see ``close()`` set the flag — the shutdown
        deadlock this timeout loop exists to break.
        """
        while not self._stop.is_set():
            try:
                self._q.put(batch, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def __iter__(self) -> Iterator[dict]:
        while True:
            batch = self._q.get()
            if batch is None:
                return
            yield batch

    def close(self, timeout: float = 5.0):
        """Stop the worker and join it; safe to call with a full queue.

        Bounded: a ``make_batch`` stuck inside a blocking call cannot
        observe the stop flag, so after ``timeout`` seconds the daemon
        thread is abandoned rather than hanging shutdown forever.
        """
        self._stop.set()
        deadline = time.monotonic() + timeout
        # Drain so a worker mid-`put` can cycle its timeout loop and exit.
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.1)
        # Discard anything enqueued after the last drain, then leave one
        # sentinel so any consumer still iterating terminates cleanly.
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        try:
            self._q.put_nowait(None)
        except queue.Full:
            pass


def synthetic_lm_batches(vocab: int, batch: int, seq: int, seed: int = 0, n_steps: Optional[int] = None):
    """Deterministic synthetic token stream (markov-ish structure so loss can
    actually fall) for the end-to-end train driver."""
    rng = np.random.default_rng(seed)
    trans = rng.integers(0, vocab, size=(256,))

    def make(step: int):
        if n_steps is not None and step >= n_steps:
            return None
        r = np.random.default_rng(seed * 1_000_003 + step)
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = r.integers(0, vocab, size=batch)
        noise = r.random((batch, seq))
        nxt = r.integers(0, vocab, size=(batch, seq))
        for t in range(seq):
            det = trans[toks[:, t] % 256]
            toks[:, t + 1] = np.where(noise[:, t] < 0.85, det, nxt[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return make
