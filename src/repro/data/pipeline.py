"""Async, sharding-aware data pipeline (the AXI-DMA staging analogue).

A background thread produces batches ahead of the training step (double
buffering hides host latency exactly like the accelerator's on-chip staging
buffers hide AXI transfers), and batches are placed against the mesh's batch
sharding before being handed to the step function.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class PrefetchingLoader:
    """Wraps a batch-producing callable with a prefetch thread."""

    def __init__(
        self,
        make_batch: Callable[[int], dict],
        *,
        prefetch: int = 2,
        sharding=None,
    ):
        self._make = make_batch
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            batch = self._make(step)
            if batch is None:
                self._q.put(None)
                return
            if self._sharding is not None:
                batch = {
                    k: jax.device_put(v, self._sharding.get(k) if isinstance(self._sharding, dict) else self._sharding)
                    for k, v in batch.items()
                }
            self._q.put(batch)
            step += 1

    def __iter__(self) -> Iterator[dict]:
        while True:
            batch = self._q.get()
            if batch is None:
                return
            yield batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def synthetic_lm_batches(vocab: int, batch: int, seq: int, seed: int = 0, n_steps: Optional[int] = None):
    """Deterministic synthetic token stream (markov-ish structure so loss can
    actually fall) for the end-to-end train driver."""
    rng = np.random.default_rng(seed)
    trans = rng.integers(0, vocab, size=(256,))

    def make(step: int):
        if n_steps is not None and step >= n_steps:
            return None
        r = np.random.default_rng(seed * 1_000_003 + step)
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = r.integers(0, vocab, size=batch)
        noise = r.random((batch, seq))
        nxt = r.integers(0, vocab, size=(batch, seq))
        for t in range(seq):
            det = trans[toks[:, t] % 256]
            toks[:, t + 1] = np.where(noise[:, t] < 0.85, det, nxt[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return make
