"""Tables III/IV — FPGA resources + system latency vs published baselines."""
from __future__ import annotations

from benchmarks.common import row
from repro.core import timing_model as TM


def main():
    ours = TM.resource_estimate()
    for name, r in TM.PUBLISHED_FPGA_RESOURCES.items():
        tag = " (analytic model)" if name.startswith("Proposed") else " (published)"
        row(
            f"table3/{name.replace(' ', '_')}",
            "",
            f"LUTs={r['luts']} FFs={r['ffs']} BRAM/DSP={r['bram_dsp']} P={r['power_w']}W{tag}",
        )
    row(
        "table3/model_check",
        "",
        f"analytic row: LUTs={ours['luts']} FFs={ours['ffs']} BRAM={ours['bram_dsp']} "
        f"(published: 2268/3250/8)",
    )
    lat = TM.shield8_latency(pruned=True)
    ms = lat["seconds"] * 1e3
    row("table4/proposed_latency", "", f"{ms:.1f} ms @100MHz W=4 ({lat['total']:,} cycles + 13ms AXI)")
    for name, pub_ms in TM.PUBLISHED_LATENCY_MS.items():
        if name.startswith("Proposed"):
            continue
        red = (1 - ms / pub_ms) * 100
        row(f"table4/vs_{name.split(' ')[0]}", "", f"{pub_ms} ms published -> {red:.1f}% reduction")
    e = TM.energy_joules(lat["seconds"])
    row("table4/energy_per_inference", "", f"{e*1e3:.1f} mJ @ {TM.FPGA_POWER_W} W")


if __name__ == "__main__":
    main()
