"""Shared benchmark utilities: timed jitted calls, CSV row emission, and a
machine-readable JSON sink (``BENCH_kernels.json``) so the perf trajectory
is diffable across PRs."""
from __future__ import annotations

import json
import time

import jax
import numpy as np

_RECORDS: list[dict] = []

#: the round-latency percentile columns every serving row carries
PERCENTILE_KEYS = ("round_p50_ms", "round_p95_ms", "round_p99_ms")


def percentile_fields(round_s, *, scale: float = 1e3, digits: int = 3) -> dict:
    """Round-latency percentile columns (p50/p95/p99, milliseconds by
    default via ``scale``) for a list of per-round durations in seconds.

    Zero recorded rounds — SMOKE runs and very short scenes legitimately
    score everything in the warmup/drain path — degrade to null fields
    instead of letting ``np.percentile`` raise on an empty list."""
    if len(round_s) == 0:
        return {k: None for k in PERCENTILE_KEYS}
    p50, p95, p99 = np.percentile(np.asarray(round_s) * scale, [50, 95, 99])
    return {
        "round_p50_ms": round(float(p50), digits),
        "round_p95_ms": round(float(p95), digits),
        "round_p99_ms": round(float(p99), digits),
    }


def format_percentiles(fields: dict) -> str:
    """Human summary of :func:`percentile_fields` output for a row's derived
    string; null-safe (``'round latency n/a (0 rounds)'``)."""
    if any(fields.get(k) is None for k in PERCENTILE_KEYS):
        return "round latency n/a (0 rounds)"
    return (
        f"round latency p50/p95/p99 {fields['round_p50_ms']:.1f}/"
        f"{fields['round_p95_ms']:.1f}/{fields['round_p99_ms']:.1f} ms"
    )


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us_per_call: float | str, derived: str, **extra):
    """Emit one CSV row and record it for the JSON sink.  ``extra`` keys
    (e.g. ``speedup_vs``) land verbatim in the JSON record."""
    print(f"{name},{us_per_call},{derived}")
    rec: dict = {"derived": derived, **extra}
    try:
        rec["median_us"] = round(float(us_per_call), 3)
    except (TypeError, ValueError):
        rec["median_us"] = None
    _RECORDS.append({"name": name, **rec})


def write_json(path: str = "BENCH_kernels.json", prefix: str = "kernels/") -> str:
    """Persist every recorded row whose name starts with ``prefix``."""
    data = {
        r["name"]: {k: v for k, v in r.items() if k != "name"}
        for r in _RECORDS
        if r["name"].startswith(prefix)
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
