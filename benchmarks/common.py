"""Shared benchmark utilities: timed jitted calls, CSV row emission, and a
machine-readable JSON sink (``BENCH_kernels.json``) so the perf trajectory
is diffable across PRs."""
from __future__ import annotations

import json
import time

import jax

_RECORDS: list[dict] = []


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us_per_call: float | str, derived: str, **extra):
    """Emit one CSV row and record it for the JSON sink.  ``extra`` keys
    (e.g. ``speedup_vs``) land verbatim in the JSON record."""
    print(f"{name},{us_per_call},{derived}")
    rec: dict = {"derived": derived, **extra}
    try:
        rec["median_us"] = round(float(us_per_call), 3)
    except (TypeError, ValueError):
        rec["median_us"] = None
    _RECORDS.append({"name": name, **rec})


def write_json(path: str = "BENCH_kernels.json", prefix: str = "kernels/") -> str:
    """Persist every recorded row whose name starts with ``prefix``."""
    data = {
        r["name"]: {k: v for k, v in r.items() if k != "name"}
        for r in _RECORDS
        if r["name"].startswith(prefix)
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
