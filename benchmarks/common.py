"""Shared benchmark utilities: timed jitted calls, CSV row emission, and a
machine-readable JSON sink (``BENCH_kernels.json``) so the perf trajectory
is diffable across PRs."""
from __future__ import annotations

import csv
import json
import statistics
import sys
import time
from pathlib import Path

import jax
import numpy as np

_RECORDS: list[dict] = []

#: short env digest attached to every row once a bench registers it
#: (see ``benchmarks.bench_env``); None = row produced outside a pinned env
_ENV_FINGERPRINT: str | None = None

#: the round-latency percentile columns every serving row carries
PERCENTILE_KEYS = ("round_p50_ms", "round_p95_ms", "round_p99_ms")


def set_env_fingerprint(fp: str | None) -> None:
    """Register the pinned-environment digest; every subsequent ``row``
    carries it as the ``env_fingerprint`` field."""
    global _ENV_FINGERPRINT
    _ENV_FINGERPRINT = fp


def percentile_fields(round_s, *, scale: float = 1e3, digits: int = 3) -> dict:
    """Round-latency percentile columns (p50/p95/p99, milliseconds by
    default via ``scale``) for a list of per-round durations in seconds.

    Zero recorded rounds — SMOKE runs and very short scenes legitimately
    score everything in the warmup/drain path — degrade to null fields
    instead of letting ``np.percentile`` raise on an empty list."""
    if len(round_s) == 0:
        return {k: None for k in PERCENTILE_KEYS}
    p50, p95, p99 = np.percentile(np.asarray(round_s) * scale, [50, 95, 99])
    return {
        "round_p50_ms": round(float(p50), digits),
        "round_p95_ms": round(float(p95), digits),
        "round_p99_ms": round(float(p99), digits),
    }


def format_percentiles(fields: dict) -> str:
    """Human summary of :func:`percentile_fields` output for a row's derived
    string; null-safe (``'round latency n/a (0 rounds)'``)."""
    if any(fields.get(k) is None for k in PERCENTILE_KEYS):
        return "round latency n/a (0 rounds)"
    return (
        f"round latency p50/p95/p99 {fields['round_p50_ms']:.1f}/"
        f"{fields['round_p95_ms']:.1f}/{fields['round_p99_ms']:.1f} ms"
    )


def median_us(times_s) -> float:
    """True median (``statistics.median``) of per-call seconds, in
    microseconds: for an even sample count this is the mean of the two
    middle samples — the old ``times[len(times)//2]`` index pick silently
    returned the upper-mid element instead."""
    return statistics.median(times_s) * 1e6


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return median_us(times)


def row(name: str, us_per_call: float | str, derived: str, **extra):
    """Emit one CSV row and record it for the JSON sink.  ``extra`` keys
    (e.g. ``speedup_vs``) land verbatim in the JSON record.

    The CSV goes through the ``csv`` module with minimal quoting: ``derived``
    strings routinely contain commas ("drop 0.0%, reject 0.0%") and a bare
    f-string print made those rows unparseable."""
    writer = csv.writer(sys.stdout, quoting=csv.QUOTE_MINIMAL, lineterminator="\n")
    writer.writerow([name, us_per_call, derived])
    rec: dict = {"derived": derived, **extra}
    try:
        rec["median_us"] = round(float(us_per_call), 3)
    except (TypeError, ValueError):
        rec["median_us"] = None
    if _ENV_FINGERPRINT is not None and "env_fingerprint" not in rec:
        rec["env_fingerprint"] = _ENV_FINGERPRINT
    _RECORDS.append({"name": name, **rec})


def write_json(
    path: str = "BENCH_kernels.json", prefix: str = "kernels/", merge: bool = False
) -> str:
    """Persist every recorded row whose name starts with ``prefix``.

    ``merge=True`` updates an existing JSON in place (rows not re-measured
    this run survive) — this is how the committed baseline carries both the
    full-shape rows and the SMOKE rows the CI perf gate compares against."""
    data: dict = {}
    if merge and Path(path).exists():
        text = Path(path).read_text()
        data = json.loads(text) if text.strip() else {}  # mktemp'd file is empty
    data.update(
        {
            r["name"]: {k: v for k, v in r.items() if k != "name"}
            for r in _RECORDS
            if r["name"].startswith(prefix)
        }
    )
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
