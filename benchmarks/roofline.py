"""Roofline assembly: dry-run artifacts -> per-cell compute/memory/collective
terms, dominant bottleneck, and MODEL_FLOPS utilisation ratio.

Hardware constants (TPU v5e per chip: 197 TFLOP/s bf16, 394 TOP/s int8,
819 GB/s HBM, ~50 GB/s/link ICI) come from ``benchmarks.hw`` — the one
shared module ``bench_kernels`` also derives its ``roofline_us`` row fields
from, so the two can never drift apart again.

Conventions (documented in EXPERIMENTS.md):
* FLOPs/bytes come from the *cost* variant (fully unrolled — nothing hidden
  in while bodies).  SSM/RWKV time-scan recurrence FLOPs are invisible to
  XLA there; an analytic correction term is added (formula below).
* collective bytes are per-chip post-SPMD shapes, all-reduce counted 2x
  (ring), and the term assumes one active ICI link per chip (conservative;
  a 2D-torus axis pair would halve it).
* memory term uses cost-variant 'bytes accessed' (XLA's HBM traffic upper
  bound: every op's operands+outputs, fusion-aware).
* MODEL_FLOPS = 6*N*D train / 2*N*D prefill (N = params, active for MoE;
  D = tokens processed; decode D = batch).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.hw import (
    V5E_PEAK_BF16_FLOPS as PEAK_FLOPS,
    V5E_PEAK_HBM_BPS as PEAK_HBM,
    V5E_PEAK_ICI_BPS as PEAK_ICI,
)
from repro.configs import get_config
from repro.launch.specs import SHAPES
CHIPS = {"pod_16x16": 256, "multipod_2x16x16": 512}

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def recurrence_flops_correction(arch: str, shape_name: str) -> float:
    """Analytic FLOPs of SSM/RWKV time-scan bodies (global, full batch).

    rwkv6:  per step/layer ~ 4*B*H*N^2   (decay*S, k^T v, r·S, u-bonus)
    mamba2: per step/layer ~ 6*B*H*N*P   (decay*S, dt*B x, C^T S)
    Decode steps have T=1 and are already visible to XLA (no loop).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return 0.0
    t = shape.seq_len
    b = shape.global_batch
    total = 0.0
    for kind in cfg.pattern:
        if kind == "rwkv6":
            h = cfg.d_model // cfg.rwkv_head_dim
            n = cfg.rwkv_head_dim
            total += 4.0 * b * t * h * n * n * cfg.n_groups
        elif kind == "mamba2":
            d_in = cfg.ssm_expand * cfg.d_model
            h = d_in // cfg.ssm_head_dim
            total += 6.0 * b * t * h * cfg.ssm_state * cfg.ssm_head_dim * cfg.n_groups
    if shape.kind == "train":
        total *= 3.0  # fwd + bwd
    return total


def model_flops(rec: dict, shape_name: str) -> float:
    shape = SHAPES[shape_name]
    n = rec.get("n_params_active") or rec.get("n_params")
    if shape.kind == "train":
        d = shape.seq_len * shape.global_batch
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.seq_len * shape.global_batch
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def cell_roofline(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    chips = CHIPS[rec["mesh"]]
    cost = rec["variants"].get("cost", {})
    fit = rec["variants"].get("fit", {})
    if "error" in cost or "flops_per_device" not in cost:
        cost = fit  # fall back (flagged)
    if "error" in cost:
        return None
    corr = recurrence_flops_correction(rec["arch"], rec["shape"]) / chips
    flops_dev = (cost["flops_per_device"] or 0.0) + corr
    bytes_dev = cost["bytes_accessed"] if "bytes_accessed" in cost else cost["bytes_per_device"]
    coll_dev = cost["collectives"]["total_bytes"]
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = (bytes_dev or 0.0) / PEAK_HBM
    t_coll = coll_dev / PEAK_ICI
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec, rec["shape"])
    ratio = mf / (flops_dev * chips) if flops_dev else 0.0
    bound = max(terms.values())
    fit_mem = fit.get("memory", {})
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": flops_dev * chips,
        "useful_ratio": ratio,
        "recurrence_corr_global": corr * chips,
        "roofline_fraction": (mf / PEAK_FLOPS / chips) / bound if bound else 0.0,
        "tpu_peak_gb": fit_mem.get("tpu_peak_bytes_est", 0) / 1e9,
        "fits_16gb": fit_mem.get("tpu_peak_bytes_est", 1e18) < 16e9,
        "tag": rec.get("tag", ""),
    }


def load_cells(out_dir: Path = ARTIFACTS, tag: str = "", mesh: str = "pod_16x16") -> list[dict]:
    """Roofline cells (single-pod by default — the §Roofline convention)."""
    cells = []
    for p in sorted(out_dir.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("tag", "") != tag:
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        if "cost" not in rec.get("variants", {}):
            continue
        r = cell_roofline(rec)
        if r:
            cells.append(r)
    return cells


def main():
    from benchmarks.common import row

    cells = load_cells()
    for c in cells:
        row(
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}",
            "",
            f"compute={c['t_compute_s']*1e3:.2f}ms memory={c['t_memory_s']*1e3:.2f}ms "
            f"collective={c['t_collective_s']*1e3:.2f}ms dominant={c['dominant']} "
            f"useful={c['useful_ratio']*100:.1f}% roofline_frac={c['roofline_fraction']*100:.1f}% "
            f"fit={c['tpu_peak_gb']:.1f}GB",
        )
    if not cells:
        row("roofline/none", "", "no dry-run artifacts found — run repro.launch.dryrun first")


if __name__ == "__main__":
    main()
