"""Table II — detection metrics per feature set x precision mode.

Trains (or loads cached) one 1D-F-CNN per feature set on the synthetic UAV
corpus and evaluates under FP32/BF16/INT8/FXP8 emulation.  Claims validated:
BF16 ~= FP32; INT8/FXP8 within 2.5%; feature-set ordering (MFCC/Mel >>
ZCR).  Absolute numbers are dataset-specific (synthetic corpus — see
EXPERIMENTS.md).
"""
from __future__ import annotations

import os

from benchmarks.common import row, time_call
from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.training import loop
from repro.training.detector_artifact import get_detector, sensitivity_policy

FEATURES = ["mfcc20", "mel128", "psd", "zcr"]
PAPER_FP32 = {"mfcc20": 89.91, "mel128": 89.13, "psd": 87.87, "zcr": 60.64}


def main(fast: bool = False):
    feats = FEATURES[:1] if fast else FEATURES
    fp32_acc = {}
    for kind in feats:
        det = get_detector(kind)
        n_tr, n_va = det["split"]
        test_x, test_y = det["feats"][n_tr + n_va :], det["labels"][n_tr + n_va :]
        for prec in Precision:
            pol = PrecisionPolicy.uniform(prec)
            logits = loop.predict(det["params"], test_x, det["cfg"], policy=pol)
            m = loop.evaluate_logits(logits, test_y)
            if prec == Precision.FP32:
                fp32_acc[kind] = m.accuracy
            drop = (fp32_acc[kind] - m.accuracy) * 100
            row(
                f"table2/{kind}/{prec.value}",
                "",
                f"acc={m.accuracy*100:.2f}% prec={m.precision*100:.2f}% "
                f"rec={m.recall*100:.2f}% f1={m.f1*100:.2f}% drop={drop:.2f}pp "
                f"(paper fp32: {PAPER_FP32[kind]})",
            )
        # sensitivity-assigned mixed precision (the paper's actual mode)
        pol = sensitivity_policy(det)
        logits = loop.predict(det["params"], test_x, det["cfg"], policy=pol)
        m = loop.evaluate_logits(logits, test_y)
        row(
            f"table2/{kind}/mixed_sensitivity",
            "",
            f"acc={m.accuracy*100:.2f}% rules={pol.to_json()}",
        )


if __name__ == "__main__":
    main(fast=bool(os.environ.get("FAST")))
