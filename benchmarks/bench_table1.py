"""Table I — dense-layer feature reduction and hardware benefits."""
from __future__ import annotations

import jax

from benchmarks.common import row
from repro.core import timing_model as TM
from repro.models import cnn1d


def main():
    params = cnn1d.init_params(jax.random.PRNGKey(0), cnn1d.CANONICAL)
    _, _, spec = cnn1d.prune_model(params, cnn1d.CANONICAL, keep=64, trim_frames=1)
    row("table1/flatten_before", "", f"{spec.flatten_before} (paper: 35072)")
    row("table1/flatten_after", "", f"{spec.flatten_after} (paper: 8704)")
    row("table1/size_reduction", "", f"{spec.reduction*100:.1f}% (paper: 75%)")
    dense_before = spec.flatten_before * cnn1d.CANONICAL.hidden
    dense_after = spec.flatten_after * cnn1d.CANONICAL.hidden
    row("table1/dense_macs", "", f"{dense_before} -> {dense_after} ({(1-dense_after/dense_before)*100:.1f}% lower)")
    row("table1/serialized_cycles", "", f"{spec.flatten_before} -> {spec.flatten_after}")
    lat_p = TM.shield8_latency(pruned=True)["seconds"] * 1e3
    lat_u = TM.shield8_latency(pruned=False)["seconds"] * 1e3
    row("table1/latency_ms", "", f"unpruned {lat_u:.1f} -> pruned {lat_p:.1f} (paper deployed: 116)")


if __name__ == "__main__":
    main()
