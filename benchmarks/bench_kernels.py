"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-path
timing; the derived column carries the TPU-roofline expectation).

The headline section races the two conv datapaths at the paper's canonical
detector shapes: the materialised-im2col path (patch tensor in HBM +
separate bias/ReLU pass) against the fused kernel (in-kernel im2col +
epilogue).  Results land in ``BENCH_kernels.json`` via ``common.row``.

Set ``SMOKE=1`` to restrict to the smallest shape (the CI smoke budget).
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call, write_json
from repro.kernels import ops

V5E_BF16 = 197e12
V5E_INT8 = 394e12
V5E_HBM = 819e9


def _smoke() -> bool:
    return bool(os.environ.get("SMOKE"))


def _conv_inputs(rng, b, l, c):
    x = jnp.asarray(rng.standard_normal((b, l, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, c, c)) * 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(c), jnp.float32)
    return x, w, bias


def _conv_layer_old(x, w, bias):
    # Seed datapath: HBM patch tensor, dequant store, then a separate
    # full-tensor activation pass.
    return ops.cordic_activation(ops.conv1d_q(x, w, bias), "relu")


def _conv_layer_fused(x, w, bias):
    # One kernel: in-VMEM im2col, int32 accumulate, bias+ReLU on the
    # accumulator tile, single store.
    return ops.conv1d_fused(x, w, bias, act="relu")


def bench_frontend():
    """DSP front-end microbench: per-window numpy loop (float64 oracle) vs
    the batched float32 JAX front-end that serves fused into the accelerator
    program, per feature kind."""
    from repro.data import features, features_jax

    rng = np.random.default_rng(2)
    b = 8 if _smoke() else 64
    w = rng.standard_normal((b, features.N_SAMPLES)).astype(np.float32)
    kinds = ("mfcc20",) if _smoke() else sorted(features.FEATURE_DIMS)
    wj = jnp.asarray(w)
    for kind in kinds:
        us_np = time_call(features.batch_features, w, kind, warmup=1, iters=3)
        row(
            f"kernels/frontend_numpy_{kind}_B{b}",
            f"{us_np:.0f}",
            f"per-window numpy float64 loop (the serving oracle), {b} windows",
        )
        us_jax = time_call(
            lambda a, k=kind: features_jax.batch_features_jax(a, k),
            wj, warmup=1, iters=3,
        )
        row(
            f"kernels/frontend_jax_{kind}_B{b}",
            f"{us_jax:.0f}",
            f"batched float32 JAX front-end (per-row bits), {b} windows; "
            f"{us_np / us_jax:.2f}x vs numpy loop",
            speedup_vs_numpy=round(us_np / us_jax, 3),
        )


def bench_conv_paths():
    rng = np.random.default_rng(1)
    b = 8 if _smoke() else 64
    channels = (64,) if _smoke() else (64, 128, 256)
    for c in channels:
        x, w, bias = _conv_inputs(rng, b, 1096, c)
        flops = 2 * b * 1096 * 3 * c * c
        tpu_us = flops / V5E_INT8 * 1e6
        us_old = time_call(_conv_layer_old, x, w, bias, warmup=1, iters=2)
        row(
            f"kernels/conv_layer_im2col_{b}x1096x{c}",
            f"{us_old:.0f}",
            f"interpret-mode; materialised im2col + separate ReLU pass; "
            f"{flops/1e6:.0f} MFLOP; v5e-int8 roofline ~{tpu_us:.1f} us",
        )
        us_new = time_call(_conv_layer_fused, x, w, bias, warmup=1, iters=2)
        row(
            f"kernels/conv_layer_fused_{b}x1096x{c}",
            f"{us_new:.0f}",
            f"interpret-mode; fused in-kernel im2col + bias/ReLU epilogue; "
            f"{us_old/us_new:.2f}x vs im2col path; v5e-int8 roofline ~{tpu_us:.1f} us",
            speedup_vs_im2col=round(us_old / us_new, 3),
        )


def main():
    rng = np.random.default_rng(0)
    shapes = [(256, 1096, 64)] if _smoke() else [(256, 1096, 64), (1024, 1024, 1024)]
    for m, k, n in shapes:
        xq = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        wq = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        xs = jnp.ones((m, 1), jnp.float32)
        ws = jnp.ones((1, n), jnp.float32)
        us = time_call(ops.quant_matmul, xq, wq, xs, ws, warmup=1, iters=3)
        flops = 2 * m * k * n
        tpu_us = flops / V5E_INT8 * 1e6
        row(
            f"kernels/quant_matmul_{m}x{k}x{n}",
            f"{us:.0f}",
            f"interpret-mode; {flops/1e6:.1f} MFLOP; v5e-int8 roofline ~{tpu_us:.2f} us",
        )
    x = jnp.asarray(rng.uniform(-4, 4, (4096, 128)), jnp.float32)
    for mode in ("tanh",) if _smoke() else ("tanh", "gelu", "exp"):
        us = time_call(lambda xx, mm=mode: ops.cordic_activation(xx, mm), x, warmup=1, iters=3)
        byts = x.size * 8
        row(
            f"kernels/cordic_{mode}",
            f"{us:.0f}",
            f"interpret-mode; {x.size} elem; v5e HBM-bound ~{byts/V5E_HBM*1e6:.2f} us",
        )

    bench_conv_paths()
    bench_frontend()

    # SMOKE is a health check, not a measurement: skip the sign-off (training
    # the detector artifact blows the smoke budget) and don't clobber the
    # committed canonical BENCH_kernels.json with smoke-only rows.
    if _smoke():
        return
    try:
        import jax

        from repro.serving.accelerator import deviation_report
        from repro.training.detector_artifact import get_detector

        det = get_detector("mfcc20")
        n_tr, n_va = det["split"]
        xs = jnp.asarray(det["feats"][n_tr + n_va : n_tr + n_va + 64])
        rep = deviation_report(det["params"], xs, det["cfg"])
        row(
            "kernels/accelerator_path_signoff",
            "",
            f"max_prob_dev={rep['max_prob_dev']:.4f} "
            f"decision_agreement={rep['decision_agreement']*100:.1f}% "
            "(full W8A8+CORDIC datapath vs fp32)",
        )
    except Exception as e:  # noqa: BLE001 — artifact may be absent in CI
        row("kernels/accelerator_path_signoff", "", f"skipped: {e}")

    write_json("BENCH_kernels.json")


if __name__ == "__main__":
    main()
