"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-path
timing; every row carries its TPU-roofline expectation as machine-readable
``roofline_us`` / ``roofline_frac`` fields derived from ``benchmarks.hw``).

The headline section races the two conv datapaths at the paper's canonical
detector shapes: the materialised-im2col path (patch tensor in HBM +
separate bias/ReLU pass) against the fused kernel (in-kernel im2col +
epilogue).  Results land in ``BENCH_kernels.json`` via ``common.row``; the
speedup *ratio* fields are what ``scripts/perf_gate.py`` gates CI on.

Set ``SMOKE=1`` to restrict to the smallest shape (the CI smoke budget;
3 cheap timed reps instead of the full-run count).  ``BENCH_OUT=<path>`` writes
the JSON to that path (the perf gate compares such a fresh file against the
committed baseline); without it a SMOKE run writes nothing.
"""
from __future__ import annotations

import os

# Pinned bench environment — must land before the first jax import so the
# XLA flags (host device count, step-marker placement) actually apply.
from benchmarks import bench_env

bench_env.apply(host_devices=1)

import jax.numpy as jnp
import numpy as np

from benchmarks import common, hw
from benchmarks.common import row, time_call, write_json
from repro.kernels import ops


def _smoke() -> bool:
    return bool(os.environ.get("SMOKE"))


def _iters(default: int) -> int:
    """SMOKE runs are a health check at the smallest shapes, not a
    measurement — but the perf gate compares their speedup ratios, so they
    take a median of 3 cheap timed reps (a single rep lets one GC/compile
    hiccup swing a ratio past the noise band) instead of the full-run count."""
    return 3 if _smoke() else default


def _conv_inputs(rng, b, l, c):
    x = jnp.asarray(rng.standard_normal((b, l, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, c, c)) * 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(c), jnp.float32)
    return x, w, bias


def _conv_layer_old(x, w, bias):
    # Seed datapath: HBM patch tensor, dequant store, then a separate
    # full-tensor activation pass.
    return ops.cordic_activation(ops.conv1d_q(x, w, bias), "relu")


def _conv_layer_fused(x, w, bias):
    # One kernel: in-VMEM im2col, int32 accumulate, bias+ReLU on the
    # accumulator tile, single store.
    return ops.conv1d_fused(x, w, bias, act="relu")


def _roofline_fields(roofline_us: float, measured_us: float) -> dict:
    frac = hw.roofline_frac(roofline_us, measured_us)
    return {
        "roofline_us": round(roofline_us, 3),
        "roofline_frac": round(frac, 9) if frac is not None else None,
    }


def bench_frontend():
    """DSP front-end microbench: per-window numpy loop (float64 oracle) vs
    the batched float32 JAX front-end that serves fused into the accelerator
    program, per feature kind."""
    from repro.data import features, features_jax

    rng = np.random.default_rng(2)
    b = 8 if _smoke() else 64
    w = rng.standard_normal((b, features.N_SAMPLES)).astype(np.float32)
    kinds = ("mfcc20",) if _smoke() else sorted(features.FEATURE_DIMS)
    wj = jnp.asarray(w)
    for kind in kinds:
        us_np = time_call(features.batch_features, w, kind, warmup=1, iters=_iters(3))
        row(
            f"kernels/frontend_numpy_{kind}_B{b}",
            f"{us_np:.0f}",
            f"per-window numpy float64 loop (the serving oracle), {b} windows",
        )
        us_jax = time_call(
            lambda a, k=kind: features_jax.batch_features_jax(a, k),
            wj, warmup=1, iters=_iters(3),
        )
        row(
            f"kernels/frontend_jax_{kind}_B{b}",
            f"{us_jax:.0f}",
            f"batched float32 JAX front-end (per-row bits), {b} windows; "
            f"{us_np / us_jax:.2f}x vs numpy loop",
            speedup_vs_numpy=round(us_np / us_jax, 3),
        )


def bench_conv_paths():
    rng = np.random.default_rng(1)
    b = 8 if _smoke() else 64
    channels = (64,) if _smoke() else (64, 128, 256)
    for c in channels:
        x, w, bias = _conv_inputs(rng, b, 1096, c)
        flops = 2 * b * 1096 * 3 * c * c
        tpu_us = hw.compute_roofline_us(flops, "int8")
        us_old = time_call(_conv_layer_old, x, w, bias, warmup=1, iters=_iters(2))
        row(
            f"kernels/conv_layer_im2col_{b}x1096x{c}",
            f"{us_old:.0f}",
            f"interpret-mode; materialised im2col + separate ReLU pass; "
            f"{flops/1e6:.0f} MFLOP; v5e-int8 roofline ~{tpu_us:.1f} us",
            **_roofline_fields(tpu_us, us_old),
        )
        us_new = time_call(_conv_layer_fused, x, w, bias, warmup=1, iters=_iters(2))
        row(
            f"kernels/conv_layer_fused_{b}x1096x{c}",
            f"{us_new:.0f}",
            f"interpret-mode; fused in-kernel im2col + bias/ReLU epilogue; "
            f"{us_old/us_new:.2f}x vs im2col path; v5e-int8 roofline ~{tpu_us:.1f} us",
            speedup_vs_im2col=round(us_old / us_new, 3),
            **_roofline_fields(tpu_us, us_new),
        )


def main():
    common.set_env_fingerprint(bench_env.fingerprint_id())
    row(
        "kernels/bench_env",
        "",
        "pinned bench environment (olmax idiom: forced host device count, "
        "step-marker placement, tcmalloc detection)",
        env=bench_env.fingerprint(),
    )

    rng = np.random.default_rng(0)
    shapes = [(256, 1096, 64)] if _smoke() else [(256, 1096, 64), (1024, 1024, 1024)]
    for m, k, n in shapes:
        xq = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        wq = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        xs = jnp.ones((m, 1), jnp.float32)
        ws = jnp.ones((1, n), jnp.float32)
        us = time_call(ops.quant_matmul, xq, wq, xs, ws, warmup=1, iters=_iters(3))
        flops = 2 * m * k * n
        tpu_us = hw.compute_roofline_us(flops, "int8")
        row(
            f"kernels/quant_matmul_{m}x{k}x{n}",
            f"{us:.0f}",
            f"interpret-mode; {flops/1e6:.1f} MFLOP; v5e-int8 roofline ~{tpu_us:.2f} us",
            **_roofline_fields(tpu_us, us),
        )
    x = jnp.asarray(rng.uniform(-4, 4, (4096, 128)), jnp.float32)
    for mode in ("tanh",) if _smoke() else ("tanh", "gelu", "exp"):
        us = time_call(
            lambda xx, mm=mode: ops.cordic_activation(xx, mm), x,
            warmup=1, iters=_iters(3),
        )
        byts = x.size * 8  # fp32 in + fp32 out
        tpu_us = hw.hbm_roofline_us(byts)
        row(
            f"kernels/cordic_{mode}",
            f"{us:.0f}",
            f"interpret-mode; {x.size} elem; v5e HBM-bound ~{tpu_us:.2f} us",
            **_roofline_fields(tpu_us, us),
        )

    bench_conv_paths()
    bench_frontend()

    out = os.environ.get("BENCH_OUT")
    if _smoke():
        # SMOKE is a health check: skip the sign-off (training the detector
        # artifact blows the smoke budget) and never clobber the committed
        # canonical BENCH_kernels.json — but DO write the smoke rows when the
        # caller asked for a fresh file (``BENCH_OUT``: the CI perf gate).
        # ``BENCH_MERGE=1`` merges into an existing file instead: that is how
        # the smoke-shape rows land in the committed baseline
        # (SMOKE=1 BENCH_OUT=BENCH_kernels.json BENCH_MERGE=1).
        if out:
            write_json(out, merge=bool(os.environ.get("BENCH_MERGE")))
        return
    try:
        import jax

        from repro.serving.accelerator import deviation_report
        from repro.training.detector_artifact import get_detector

        det = get_detector("mfcc20")
        n_tr, n_va = det["split"]
        xs = jnp.asarray(det["feats"][n_tr + n_va : n_tr + n_va + 64])
        rep = deviation_report(det["params"], xs, det["cfg"])
        row(
            "kernels/accelerator_path_signoff",
            "",
            f"max_prob_dev={rep['max_prob_dev']:.4f} "
            f"decision_agreement={rep['decision_agreement']*100:.1f}% "
            "(full W8A8+CORDIC datapath vs fp32)",
        )
    except Exception as e:  # noqa: BLE001 — artifact may be absent in CI
        row("kernels/accelerator_path_signoff", "", f"skipped: {e}")

    # merge=True: the committed baseline also carries the SMOKE-shape rows
    # (regenerated via SMOKE=1 BENCH_OUT=BENCH_kernels.json) — a full run
    # must not delete them, and vice versa.
    write_json(out or "BENCH_kernels.json", merge=True)


if __name__ == "__main__":
    main()
