"""Pallas kernel microbenchmarks (interpret mode on CPU: correctness-path
timing; the derived column carries the TPU-roofline expectation)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.kernels import ops

V5E_BF16 = 197e12
V5E_INT8 = 394e12
V5E_HBM = 819e9


def main():
    rng = np.random.default_rng(0)
    for m, k, n in [(256, 1096, 64), (1024, 1024, 1024)]:
        xq = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        wq = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        xs = jnp.ones((m, 1), jnp.float32)
        ws = jnp.ones((1, n), jnp.float32)
        us = time_call(ops.quant_matmul, xq, wq, xs, ws, warmup=1, iters=3)
        flops = 2 * m * k * n
        tpu_us = flops / V5E_INT8 * 1e6
        row(
            f"kernels/quant_matmul_{m}x{k}x{n}",
            f"{us:.0f}",
            f"interpret-mode; {flops/1e6:.1f} MFLOP; v5e-int8 roofline ~{tpu_us:.2f} us",
        )
    x = jnp.asarray(rng.uniform(-4, 4, (4096, 128)), jnp.float32)
    for mode in ("tanh", "gelu", "exp"):
        us = time_call(lambda xx, mm=mode: ops.cordic_activation(xx, mm), x, warmup=1, iters=3)
        byts = x.size * 8
        row(
            f"kernels/cordic_{mode}",
            f"{us:.0f}",
            f"interpret-mode; {x.size} elem; v5e HBM-bound ~{byts/V5E_HBM*1e6:.2f} us",
        )

    # deployed-datapath sign-off: the trained detector fully on the kernels
    try:
        import jax

        from repro.serving.accelerator import deviation_report
        from repro.training.detector_artifact import get_detector

        det = get_detector("mfcc20")
        n_tr, n_va = det["split"]
        xs = jnp.asarray(det["feats"][n_tr + n_va : n_tr + n_va + 64])
        rep = deviation_report(det["params"], xs, det["cfg"])
        row(
            "kernels/accelerator_path_signoff",
            "",
            f"max_prob_dev={rep['max_prob_dev']:.4f} "
            f"decision_agreement={rep['decision_agreement']*100:.1f}% "
            "(full W8A8+CORDIC datapath vs fp32)",
        )
    except Exception as e:  # noqa: BLE001 — artifact may be absent in CI
        row("kernels/accelerator_path_signoff", "", f"skipped: {e}")


if __name__ == "__main__":
    main()
