"""Benchmark driver: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Set FAST=1 to restrict the
accuracy tables to the headline feature set.
"""
from __future__ import annotations

import os
import traceback


def main() -> None:
    from benchmarks import (
        bench_fig45,
        bench_kernels,
        bench_serving,
        bench_table1,
        bench_table2,
        bench_table34,
        bench_table5,
        roofline,
    )

    print("name,us_per_call,derived")
    sections = [
        ("table1", bench_table1.main),
        ("table2", lambda: bench_table2.main(fast=bool(os.environ.get("FAST")))),
        ("fig45", bench_fig45.main),
        ("table34", bench_table34.main),
        ("table5", bench_table5.main),
        ("kernels", bench_kernels.main),
        ("serving", bench_serving.main),
        ("roofline", roofline.main),
    ]
    failures = []
    for name, fn in sections:
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, e))
            print(f"{name}/ERROR,,{type(e).__name__}: {e}")
            traceback.print_exc()
    # BENCH_kernels.json (fused vs im2col conv rows included) is written by
    # bench_kernels.main itself — the single write site — so a failed section
    # here never clobbers the committed perf trajectory with partial data.
    if failures:
        raise SystemExit(f"{len(failures)} benchmark sections failed")


if __name__ == "__main__":
    main()
