"""Single source of truth for the TPU v5e hardware constants every bench
row's roofline expectation is derived from.

These numbers used to live twice — as module constants in
``benchmarks/roofline.py`` and hand-inlined into ``bench_kernels``'s
``derived`` strings — which is exactly how roofline claims drift: edit one
copy, the other keeps reporting the stale figure.  Everything that quotes a
peak now imports it from here, and the bench rows carry the derived
``roofline_us`` / ``roofline_frac`` values as machine-readable fields the
perf gate and future PRs can diff.
"""
from __future__ import annotations

#: TPU v5e, per chip
V5E_PEAK_BF16_FLOPS = 197e12  # bf16 matmul peak, FLOP/s
V5E_PEAK_INT8_OPS = 394e12  # int8 matmul peak, OP/s (2x bf16)
V5E_PEAK_HBM_BPS = 819e9  # HBM bandwidth, B/s
V5E_PEAK_ICI_BPS = 50e9  # per-link ICI bandwidth, B/s

#: compute peak per operand dtype — int8 kernels are judged against the
#: doubled MXU rate, float kernels against the bf16 rate.
PEAK_OPS_BY_DTYPE = {
    "int8": V5E_PEAK_INT8_OPS,
    "fxp8": V5E_PEAK_INT8_OPS,
    "bf16": V5E_PEAK_BF16_FLOPS,
    "fp32": V5E_PEAK_BF16_FLOPS,  # fp32 streams through the bf16 MXU path
}


def compute_roofline_us(flops: float, dtype: str = "int8") -> float:
    """Compute-bound roofline latency (microseconds) for ``flops`` total
    operations at the dtype's MXU peak."""
    return flops / PEAK_OPS_BY_DTYPE[dtype] * 1e6


def hbm_roofline_us(n_bytes: float) -> float:
    """Memory-bound roofline latency (microseconds) for ``n_bytes`` of HBM
    traffic at peak bandwidth."""
    return n_bytes / V5E_PEAK_HBM_BPS * 1e6


def roofline_frac(roofline_us: float, measured_us: float) -> float | None:
    """Fraction of the roofline actually achieved (1.0 = at the roofline;
    interpret-mode rows score far below it, and say so machine-readably)."""
    if not measured_us or measured_us <= 0:
        return None
    return roofline_us / measured_us
