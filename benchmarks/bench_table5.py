"""Table V — 40 nm ASIC comparison (published points + our scaled row)."""
from __future__ import annotations

from benchmarks.common import row
from repro.core import timing_model as TM


def main():
    for name, r in TM.PUBLISHED_ASIC.items():
        tag = " (paper's synthesis)" if name == "Proposed" else " (published)"
        row(
            f"table5/{name.replace(' ', '_')}",
            "",
            f"f={r['freq_ghz']}GHz area={r['area_mm2']}mm2 P={r['power_w']}W{tag}",
        )
    # ASIC-speed inference: same cycle model at 1.56 GHz, no AXI staging
    from repro.models.cnn1d import CANONICAL, layer_macs

    lat = TM.latency_seconds(
        layer_macs(CANONICAL, pruned_flatten=8704),
        flatten_size=8704,
        freq_hz=TM.ASIC_FREQ_HZ,
        include_axi=False,
    )
    row(
        "table5/asic_inference",
        "",
        f"{lat['seconds']*1e3:.2f} ms/inference @1.56GHz; "
        f"E={TM.energy_joules(lat['seconds'], TM.ASIC_POWER_W)*1e3:.2f} mJ",
    )


if __name__ == "__main__":
    main()
