"""Streaming monitor engine throughput/latency benchmark.

Drives :class:`repro.serving.engine.MonitorEngine` with synthetic raw-audio
streams at several concurrency levels and records aggregate windows/s and
per-window latency into ``BENCH_serving.json`` (same row machinery as the
kernel bench).  The model is the small detector shape on zcr features —
interpret-mode kernel timings; the derived column notes the configuration so
rows stay comparable across PRs.

Set ``SMOKE=1`` to restrict to the smallest stream count.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import row, write_json
from repro.data import features
from repro.models import cnn1d
from repro.serving.engine import MonitorEngine

STREAM_COUNTS = (1, 8, 64)
WINDOWS_PER_STREAM = 6
BATCH_SLOTS = 8
FEATURE = "zcr"


def _smoke() -> bool:
    return bool(os.environ.get("SMOKE"))


def bench_monitor(n_streams: int, params, cfg) -> dict:
    rng = np.random.default_rng(n_streams)
    engine = MonitorEngine(
        params, cfg,
        n_streams=n_streams,
        feature_kind=FEATURE,
        batch_slots=BATCH_SLOTS,
    )
    audio = rng.standard_normal(
        (n_streams, WINDOWS_PER_STREAM * features.N_SAMPLES)
    ).astype(np.float32)

    # Warmup: compile the fixed-slot forward once, outside the timed region.
    engine.push(0, audio[0, : features.N_SAMPLES])
    engine.drain()

    t0 = time.perf_counter()
    for s in range(n_streams):
        off = features.N_SAMPLES if s == 0 else 0  # stream 0's warmup window
        engine.push(s, audio[s, off:])
    scored = engine.drain()
    dt = time.perf_counter() - t0
    engine.finalize()
    n_win = len(scored)
    return {
        "windows": n_win,
        "windows_per_s": n_win / dt,
        "us_per_window": dt / n_win * 1e6,
        "forward_calls": engine.forward_calls,
        "padded_slots": engine.padded_slots,
    }


def main():
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS[FEATURE], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    counts = STREAM_COUNTS[:1] if _smoke() else STREAM_COUNTS
    for n in counts:
        r = bench_monitor(n, params, cfg)
        row(
            f"serving/monitor_{n}streams_x{WINDOWS_PER_STREAM}win",
            f"{r['us_per_window']:.0f}",
            f"interpret-mode; {r['windows_per_s']:.1f} windows/s aggregate; "
            f"{r['forward_calls']} forward calls ({BATCH_SLOTS} slots, "
            f"{r['padded_slots']} padded); zcr features, small detector",
            windows_per_s=round(r["windows_per_s"], 2),
            n_streams=n,
            batch_slots=BATCH_SLOTS,
        )
    if not _smoke():
        write_json("BENCH_serving.json", prefix="serving/")


if __name__ == "__main__":
    main()
