"""Streaming monitor engine throughput/latency benchmark.

Drives :class:`repro.serving.engine.MonitorEngine` with synthetic raw-audio
streams at several concurrency levels and records aggregate windows/s,
per-window latency, per-round latency percentiles (p50/p95/p99 over the
step() scoring beat) and ingest drop/reject rates into
``BENCH_serving.json`` (same row machinery as the kernel bench).  The model is the small detector shape on zcr features —
interpret-mode kernel timings; the derived column notes the configuration so
rows stay comparable across PRs.

Sharded rows drive the same engine through ``shards``-way sharded-batch
dispatch (1/2/4/8 shards over simulated CPU devices — the device-count
override below must land before the first jax import, so keep this module's
import order).  Set ``SMOKE=1`` to restrict to the smallest stream count and
a single 2-shard row.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

# Simulated device pool for the sharded-dispatch rows (before jax import).
# NOTE: this changes the measurement environment of *all* rows, including
# the pre-existing unsharded ones — every row records ``host_devices`` so
# cross-PR comparisons know which environment produced it (the PR-3
# rebaseline moved the unsharded rows onto the 8-device pool).
from repro.hostdevices import force_host_device_count

N_HOST_DEVICES = 8
force_host_device_count(N_HOST_DEVICES)

import jax
import numpy as np

from benchmarks.common import (
    format_percentiles,
    percentile_fields,
    row,
    write_json,
)
from repro.core.precision_policy import PrecisionPolicy
from repro.core.pruning import plan_prune
from repro.data import features
from repro.models import cnn1d
from repro.serving.batching import AdmissionPolicy
from repro.serving.engine import MonitorEngine, SanitizePolicy

STREAM_COUNTS = (1, 8, 64)
SHARD_COUNTS = (1, 2, 4, 8)
SHARDED_STREAMS = 8
WINDOWS_PER_STREAM = 6
BATCH_SLOTS = 8
FEATURE = "zcr"

# Front-end comparison rows: the paper-canonical mfcc20 feature set, host
# numpy front-end vs the fused on-device front-end, at equal stream counts.
# All layers fp32 (pure XLA) for BOTH legs: in interpret mode the Pallas
# int8 kernels cost ~40x their compiled-TPU time, which would mask the
# front-end difference entirely — on real hardware the classifier is
# microseconds and the pipeline is front-end-bound, which is exactly the
# regime the fp32-policy CNN reproduces on CPU.
FRONTEND_FEATURE = "mfcc20"
FRONTEND_STREAMS = (1, 8, 64)

# Deployment-cell rows (pruned / mixed-precision artifacts): a dense-heavy
# detector shape where the flatten->dense interface dominates, so the
# paper's 75% flatten cut shows up as serving throughput, not just FLOPs.
DEPLOY_FEATURE = "psd"  # 512-dim input -> 128 frames x 32 ch = 4096 flatten
DEPLOY_CHANNELS = (4, 32)
DEPLOY_STREAMS = 8
DEPLOY_KEEP = 8  # 32 -> 8 channels (+1 frame trim): 4096 -> 1016 (-75%)
DEPLOY_POLICY = "conv0/w=bf16,dense1/w=fp32"

# Fleet-scale bursty-arrival rows: streams wake in seeded waves and dump a
# whole multi-window burst at once, so the per-round backlog is ragged —
# the regime the adaptive slot ladder exists for.  The ring is deliberately
# smaller than the burst (2 windows vs 4) so the drop-rate column is a real
# measurement of ingest back-pressure, not a constant zero, and a round
# budget caps how much of the backlog one scoring beat may drain so the
# round-latency percentiles reflect a bounded beat, not one giant flush.
BURSTY_STREAMS = (256, 1024)
BURSTY_WINDOWS = 4
BURSTY_CAPACITY = 2
BURSTY_WAVES = 8
BURSTY_ROUND_BUDGET = 8 * BATCH_SLOTS

# Concurrent-fleet rows: the same fleet supervisor stepped sequentially vs
# with per-worker execution lanes (threads).  Lanes overlap one worker's
# host feature extraction with another's device scoring through the
# dispatch core's in-flight rotation; results stay bitwise identical
# (pinned by tests/test_lane_fleet.py), so the lane row is a pure
# wall-clock measurement.  Target: >=1.3x aggregate windows/s at 4 workers
# on a multi-core host.  The ratio is physically bounded by the host's
# core count — on a single-core runner (the CI container) there is no
# second core for the overlapped beat to run on, so the honest expectation
# there is ~1.0x minus thread overhead; every row records host_cpus so the
# ratio is read against the hardware that produced it.  Interpret-mode CPU
# numbers carry a run-to-run noise band of roughly +/-10%: track the
# ratio column across PRs, not any single row's absolute windows/s.
FLEET_STREAMS = 16
FLEET_WORKERS = 4
FLEET_WINDOWS = 6

# Durability-overhead rows: the same fleet leg with a --state-dir, across
# the fsync-policy x checkpoint-interval grid.  The interesting column is
# ``durable_vs_plain`` (per-window cost relative to the in-memory fleet
# benched in the same process): WAL appends ride the push path and the
# checkpoint publish rides step(), so the ratio is the whole durability
# tax.  ``always`` pays one disk flush per chunk (the worst case);
# ``never`` is pure serialization cost.  SMOKE runs one small cell so the
# CI leg still exercises the durable path end to end.
DURABLE_GRID = (
    ("always", 1), ("always", 4),
    ("interval", 1), ("interval", 4),
    ("never", 1), ("never", 4),
)
DURABLE_SMOKE_STREAMS = 4
DURABLE_SMOKE_WORKERS = 2
DURABLE_SMOKE_WINDOWS = 2


def _smoke() -> bool:
    return bool(os.environ.get("SMOKE"))


def bench_monitor(
    n_streams: int,
    params,
    cfg,
    *,
    shards: int | None = None,
    feature: str = FEATURE,
    prune=None,
    policy=None,
    on_device_features: bool = False,
    adaptive_slots: bool = False,
) -> dict:
    rng = np.random.default_rng(n_streams)
    engine = MonitorEngine(
        params, cfg,
        n_streams=n_streams,
        feature_kind=feature,
        on_device_features=on_device_features,
        batch_slots=BATCH_SLOTS,
        adaptive_slots=adaptive_slots,
        shards=shards,
        prune=prune,
        policy=policy,
        # live ingest-hardening accounting (no-op on this clean audio, but
        # the reject-rate column measures the deployed configuration)
        sanitize=SanitizePolicy(),
    )
    audio = rng.standard_normal(
        (n_streams, WINDOWS_PER_STREAM * features.N_SAMPLES)
    ).astype(np.float32)

    # Warmup: compile the forward outside the timed region — the whole slot
    # ladder when adaptive (a lone window would only compile the 1-slot
    # shape and the timed region would pay every other trace).
    if adaptive_slots:
        engine.precompile()
    engine.push(0, audio[0, : features.N_SAMPLES])
    engine.drain()
    engine.forward_calls = 0
    engine.padded_slots = 0

    delivered = 0
    pushed_chunks = 0
    round_s: list[float] = []
    t0 = time.perf_counter()
    for s in range(n_streams):
        off = features.N_SAMPLES if s == 0 else 0  # stream 0's warmup window
        engine.push(s, audio[s, off:])
        delivered += audio.shape[1] - off
        pushed_chunks += 1
    # Per-round latency: each step() scores at most one window per stream,
    # so a round is the fleet's end-to-end scoring beat — the percentiles
    # below are what an operator's round-latency SLO would measure.
    n_win = 0
    while True:
        r0 = time.perf_counter()
        scored = engine.step()
        if not scored:
            break
        round_s.append(time.perf_counter() - r0)
        n_win += len(scored)
    dt = time.perf_counter() - t0
    engine.finalize()
    return {
        "windows": n_win,
        "windows_per_s": n_win / dt,
        "us_per_window": dt / n_win * 1e6,
        "forward_calls": engine.forward_calls,
        "padded_slots": engine.padded_slots,
        "rounds": len(round_s),
        **percentile_fields(round_s),
        "drop_rate": round(engine.dropped_samples / delivered, 6),
        "reject_rate": round(
            float(engine.rejected_chunks.sum()) / pushed_chunks, 6
        ),
    }


def bench_fleet(
    params, cfg, *, lanes: str | None,
    n_streams: int = FLEET_STREAMS,
    n_workers: int = FLEET_WORKERS,
    n_windows: int = FLEET_WINDOWS,
    state_dir: str | None = None,
    fsync: str = "interval",
    checkpoint_interval: int = 1,
) -> dict:
    """One fleet leg (sequential or lane-parallel) over the same delivery
    schedule: every stream gets a full multi-window scene up front, then
    rounds drain it one window per stream per beat.  With ``state_dir``
    the leg runs durable (checkpoints + WAL per the fsync policy), which
    is what the durability-overhead rows measure."""
    from repro.serving.quantized_params import quantize_params
    from repro.serving.supervisor import FleetSupervisor

    rng = np.random.default_rng(n_streams)
    durable_kw = (
        dict(state_dir=state_dir, fsync=fsync,
             checkpoint_interval=checkpoint_interval)
        if state_dir is not None else {}
    )
    sup = FleetSupervisor(
        quantize_params(params, cfg, mode="int8"), cfg,
        n_streams=n_streams,
        n_workers=n_workers,
        lanes=lanes,
        feature_kind=FEATURE,
        batch_slots=BATCH_SLOTS,
        sanitize=SanitizePolicy(),
        **durable_kw,
    )
    audio = rng.standard_normal(
        (n_streams, n_windows * features.N_SAMPLES)
    ).astype(np.float32)

    # Warmup: one window through every stream so each worker's jit cache is
    # hot (shapes are shared process-wide, but the first leg pays the trace).
    for s in range(n_streams):
        sup.push(s, audio[s, : features.N_SAMPLES])
    sup.drain()

    round_s: list[float] = []
    n_win = 0
    t0 = time.perf_counter()
    for s in range(n_streams):
        sup.push(s, audio[s, features.N_SAMPLES:])
    while True:
        r0 = time.perf_counter()
        scored = sup.step()
        if not scored:
            break
        round_s.append(time.perf_counter() - r0)
        n_win += len(scored)
    dt = time.perf_counter() - t0
    sup.finalize()
    sup.close()
    return {
        "windows": n_win,
        "windows_per_s": n_win / dt,
        "us_per_window": dt / n_win * 1e6,
        "rounds": len(round_s),
        **percentile_fields(round_s),
    }


def bench_bursty(n_streams: int, params, cfg) -> dict:
    """Fleet-scale bursty arrival: streams wake in seeded waves, each dumps
    a 4-window burst into a 2-window ring, and a budgeted round drains the
    backlog depth-fairly on the adaptive slot ladder."""
    rng = np.random.default_rng(n_streams)
    engine = MonitorEngine(
        params, cfg,
        n_streams=n_streams,
        feature_kind=FEATURE,
        batch_slots=BATCH_SLOTS,
        adaptive_slots=True,
        capacity_windows=BURSTY_CAPACITY,
        admission=AdmissionPolicy(
            max_per_stream_per_round=BURSTY_CAPACITY,
            round_budget=BURSTY_ROUND_BUDGET,
        ),
        sanitize=SanitizePolicy(),
    )
    engine.precompile()  # whole slot ladder, outside the timed region
    chunk = BURSTY_WINDOWS * features.N_SAMPLES
    audio = rng.standard_normal((n_streams, chunk)).astype(np.float32)
    wave = rng.integers(0, BURSTY_WAVES, n_streams)

    delivered = 0
    round_s: list[float] = []
    n_win = 0
    t0 = time.perf_counter()
    for w in range(BURSTY_WAVES):
        for s in np.flatnonzero(wave == w):
            engine.push(s, audio[s])
            delivered += chunk
        r0 = time.perf_counter()
        scored = engine.step()
        if scored:  # an arrival-free wave is not a scoring round
            round_s.append(time.perf_counter() - r0)
            n_win += len(scored)
    while True:  # drain the tail of the backlog after the last wave
        r0 = time.perf_counter()
        scored = engine.step()
        if not scored:
            break
        round_s.append(time.perf_counter() - r0)
        n_win += len(scored)
    dt = time.perf_counter() - t0
    engine.finalize()
    return {
        "windows": n_win,
        "windows_per_s": n_win / dt,
        "us_per_window": dt / n_win * 1e6,
        "forward_calls": engine.forward_calls,
        "padded_slots": engine.padded_slots,
        "slot_histogram": dict(engine.slot_histogram),
        "served": int(engine.served_windows.sum()),
        "deferred": int(engine.deferred_windows.sum()),
        "rounds": len(round_s),
        **percentile_fields(round_s),
        "drop_rate": round(engine.dropped_samples / delivered, 6),
    }


# The front-end comparison runs in a subprocess on the DEFAULT single-device
# environment: this process's 8-simulated-device pool (needed only for the
# shard rows) splits the XLA CPU thread pool eight ways, which starves the
# fused in-graph FFTs while leaving the single-threaded numpy loop almost
# untouched — a simulation artifact that would understate the on-device win.
# Each emitted row records host_devices=1 accordingly.
FRONTEND_SCRIPT = """\
import os, json, sys, time
# The parent process baked --xla_force_host_platform_device_count=8 into
# XLA_FLAGS (inherited via os.environ); strip that flag — and only it, an
# outer override of anything else still wins — so this child really runs
# on the default single-device pool.
os.environ["XLA_FLAGS"] = " ".join(
    f for f in os.environ.get("XLA_FLAGS", "").split()
    if not f.startswith("--xla_force_host_platform_device_count")
)
import numpy as np
sys.path.insert(0, "src")
import jax
from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.data import features
from repro.models import cnn1d
from repro.serving.engine import MonitorEngine

counts = [int(c) for c in sys.argv[1:]]
wps = int(%d)
cfg = cnn1d.CNNConfig(
    input_len=features.FEATURE_DIMS["%s"], channels=(4, 8), hidden=8
)
params = cnn1d.init_params(jax.random.PRNGKey(2), cfg)
policy = PrecisionPolicy(rules={}, default=Precision.FP32)
out = []
for on_device in (False, True):
    for n in counts:
        rng = np.random.default_rng(n)
        engine = MonitorEngine(
            params, cfg, n_streams=n, feature_kind="%s",
            on_device_features=on_device, batch_slots=%d, policy=policy,
        )
        audio = rng.standard_normal((n, wps * features.N_SAMPLES)).astype(np.float32)
        engine.push(0, audio[0, : features.N_SAMPLES])
        engine.drain()  # compile outside the timed region
        # the warmup dispatch must not leak into the reported dispatch stats
        engine.forward_calls = 0
        engine.padded_slots = 0
        t0 = time.perf_counter()
        for s in range(n):
            off = features.N_SAMPLES if s == 0 else 0
            engine.push(s, audio[s, off:])
        scored = engine.drain()
        dt = time.perf_counter() - t0
        out.append({
            "on_device": on_device, "n_streams": n, "windows": len(scored),
            "windows_per_s": len(scored) / dt,
            "us_per_window": dt / len(scored) * 1e6,
            "forward_calls": engine.forward_calls,
            "padded_slots": engine.padded_slots,
            "host_devices": jax.device_count(),
        })
print("RESULT:" + json.dumps(out))
"""


def bench_frontend_rows():
    """Host numpy features vs the fused on-device front-end on the paper-
    canonical mfcc20 set at equal stream counts (acceptance: on-device >= 3x
    host at 8 streams, both rows from this same run).

    All layers fp32 (pure XLA) for BOTH legs: in interpret mode the Pallas
    int8 kernels cost ~40x their compiled-TPU time, which would mask the
    front-end difference entirely — on real hardware the classifier is
    microseconds and the pipeline is front-end-bound, which is exactly the
    regime the fp32-policy CNN reproduces on CPU.
    """
    import subprocess
    import sys

    counts = FRONTEND_STREAMS[:1] if _smoke() else FRONTEND_STREAMS
    script = FRONTEND_SCRIPT % (
        WINDOWS_PER_STREAM, FRONTEND_FEATURE, FRONTEND_FEATURE, BATCH_SLOTS
    )
    proc = subprocess.run(
        [sys.executable, "-c", script, *map(str, counts)],
        capture_output=True, text=True, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"front-end bench subprocess failed:\n{proc.stderr[-2000:]}")
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    results = json.loads(line[len("RESULT:"):])
    host_rate = {
        r["n_streams"]: r["windows_per_s"] for r in results if not r["on_device"]
    }
    for r in results:
        leg = "devfe" if r["on_device"] else "hostfe"
        vs = (
            f"; {r['windows_per_s'] / host_rate[r['n_streams']]:.2f}x vs "
            f"host front-end"
            if r["on_device"]
            else ""
        )
        row(
            f"serving/monitor_{FRONTEND_FEATURE}_{leg}_{r['n_streams']}streams_x{WINDOWS_PER_STREAM}win",
            f"{r['us_per_window']:.0f}",
            f"{'fused on-device' if r['on_device'] else 'host numpy'} "
            f"{FRONTEND_FEATURE} front-end{vs}; fp32-policy CNN (XLA; "
            f"front-end-bound regime — interpret-mode int8 kernels would "
            f"mask the front-end); {r['windows_per_s']:.1f} windows/s "
            f"aggregate; {r['forward_calls']} forward calls "
            f"({BATCH_SLOTS} slots, {r['padded_slots']} padded); subprocess "
            f"on the default device pool (see FRONTEND_SCRIPT note)",
            windows_per_s=round(r["windows_per_s"], 2),
            n_streams=r["n_streams"],
            batch_slots=BATCH_SLOTS,
            feature=FRONTEND_FEATURE,
            on_device_features=r["on_device"],
            host_devices=r["host_devices"],
        )


def main():
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS[FEATURE], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    counts = STREAM_COUNTS[:1] if _smoke() else STREAM_COUNTS
    for n in counts:
        r = bench_monitor(n, params, cfg)
        a = bench_monitor(n, params, cfg, adaptive_slots=True)
        row(
            f"serving/monitor_adaptive_{n}streams_x{WINDOWS_PER_STREAM}win",
            f"{a['us_per_window']:.0f}",
            f"interpret-mode; adaptive slot ladder (max {BATCH_SLOTS}); "
            f"{a['windows_per_s']:.1f} windows/s aggregate "
            f"({a['windows_per_s'] / r['windows_per_s']:.2f}x vs fixed-slot "
            f"this run); {format_percentiles(a)} over "
            f"{a['rounds']} rounds; {a['forward_calls']} forward calls, "
            f"{a['padded_slots']} padded slots (fixed-slot pads "
            f"{r['padded_slots']}); zcr features, small detector",
            windows_per_s=round(a["windows_per_s"], 2),
            n_streams=n,
            batch_slots=BATCH_SLOTS,
            adaptive_slots=True,
            padded_slots=a["padded_slots"],
            round_p50_ms=a["round_p50_ms"],
            round_p95_ms=a["round_p95_ms"],
            round_p99_ms=a["round_p99_ms"],
            drop_rate=a["drop_rate"],
            reject_rate=a["reject_rate"],
            host_devices=jax.device_count(),
        )
        row(
            f"serving/monitor_{n}streams_x{WINDOWS_PER_STREAM}win",
            f"{r['us_per_window']:.0f}",
            f"interpret-mode; {r['windows_per_s']:.1f} windows/s aggregate; "
            f"{format_percentiles(r)} over "
            f"{r['rounds']} rounds; drop {r['drop_rate']:.1%}, reject "
            f"{r['reject_rate']:.1%}; {r['forward_calls']} forward calls "
            f"({BATCH_SLOTS} slots, {r['padded_slots']} padded); zcr "
            f"features, small detector",
            windows_per_s=round(r["windows_per_s"], 2),
            n_streams=n,
            batch_slots=BATCH_SLOTS,
            round_p50_ms=r["round_p50_ms"],
            round_p95_ms=r["round_p95_ms"],
            round_p99_ms=r["round_p99_ms"],
            drop_rate=r["drop_rate"],
            reject_rate=r["reject_rate"],
            host_devices=jax.device_count(),
        )
    shard_counts = (2,) if _smoke() else SHARD_COUNTS
    # An outer XLA_FLAGS override wins over ours (force_host_device_count
    # never fights it) — only bench the shard counts that actually fit, and
    # say so instead of dying after the unsharded rows already ran.
    fitting = tuple(k for k in shard_counts if k <= jax.device_count())
    if fitting != shard_counts:
        print(
            f"bench_serving: only {jax.device_count()} device(s) available; "
            f"skipping shard counts {sorted(set(shard_counts) - set(fitting))}"
        )
    for k in fitting:
        r = bench_monitor(SHARDED_STREAMS, params, cfg, shards=k)
        row(
            f"serving/monitor_{SHARDED_STREAMS}streams_x{WINDOWS_PER_STREAM}win_shard{k}",
            f"{r['us_per_window']:.0f}",
            f"interpret-mode; sharded dispatch over {k} simulated CPU "
            f"device(s); {r['windows_per_s']:.1f} windows/s aggregate; "
            f"{r['forward_calls']} forward calls ({BATCH_SLOTS} slots, "
            f"{r['padded_slots']} padded); zcr features, small detector",
            windows_per_s=round(r["windows_per_s"], 2),
            n_streams=SHARDED_STREAMS,
            batch_slots=BATCH_SLOTS,
            shards=k,
            round_p50_ms=r["round_p50_ms"],
            round_p95_ms=r["round_p95_ms"],
            round_p99_ms=r["round_p99_ms"],
            drop_rate=r["drop_rate"],
            reject_rate=r["reject_rate"],
            host_devices=jax.device_count(),
        )
    # Concurrent-fleet rows (skipped under SMOKE): sequential supervisor vs
    # per-worker execution lanes, same artifact, same delivery schedule.
    if not _smoke():
        n_cpus = os.cpu_count() or 1
        seq = bench_fleet(params, cfg, lanes=None)
        lan = bench_fleet(params, cfg, lanes="threads")
        ratio = lan["windows_per_s"] / seq["windows_per_s"]
        for leg, r in (("seq", seq), ("lanes", lan)):
            vs = (
                f"; {ratio:.2f}x vs sequential fleet this run on a "
                f"{n_cpus}-cpu host (>=1.3x expected at 4 workers only with "
                f">=2 cores to overlap on; interpret-mode noise band "
                f"~+/-10%: track the ratio, not the absolute)"
                if leg == "lanes"
                else ""
            )
            row(
                f"serving/fleet_{leg}_{FLEET_WORKERS}workers_"
                f"{FLEET_STREAMS}streams_x{FLEET_WINDOWS}win",
                f"{r['us_per_window']:.0f}",
                f"interpret-mode; fleet supervisor, {FLEET_WORKERS} "
                f"worker(s), "
                f"{'thread execution lanes' if leg == 'lanes' else 'sequential step'}"
                f"; {r['windows_per_s']:.1f} windows/s aggregate{vs}; "
                f"{format_percentiles(r)} over {r['rounds']} rounds; "
                f"bitwise identical to the sequential fleet and the "
                f"monolithic engine (tests/test_lane_fleet.py); zcr "
                f"features, small detector",
                windows_per_s=round(r["windows_per_s"], 2),
                n_streams=FLEET_STREAMS,
                n_workers=FLEET_WORKERS,
                lanes=leg == "lanes",
                batch_slots=BATCH_SLOTS,
                round_p50_ms=r["round_p50_ms"],
                round_p95_ms=r["round_p95_ms"],
                round_p99_ms=r["round_p99_ms"],
                host_devices=jax.device_count(),
                host_cpus=n_cpus,
                **({"lanes_vs_seq": round(ratio, 3)} if leg == "lanes" else {}),
            )

    # Durability-overhead rows: the fleet leg re-run with state-dir
    # checkpoints + chunk WAL across the fsync x checkpoint-interval grid,
    # against an in-memory baseline benched in the same process (so the
    # ratio cancels the interpret-mode noise floor).  SMOKE runs one small
    # cell so the CI leg still exercises the durable path end to end.
    if _smoke():
        durable_grid = (("interval", 1),)  # the supervisor defaults
        durable_size = dict(
            n_streams=DURABLE_SMOKE_STREAMS,
            n_workers=DURABLE_SMOKE_WORKERS,
            n_windows=DURABLE_SMOKE_WINDOWS,
        )
    else:
        durable_grid = DURABLE_GRID
        durable_size = {}
    base = bench_fleet(params, cfg, lanes=None, **durable_size)
    for fsync, ck in durable_grid:
        state_dir = tempfile.mkdtemp(prefix="bench-durable-")
        try:
            r = bench_fleet(
                params, cfg, lanes=None, state_dir=state_dir,
                fsync=fsync, checkpoint_interval=ck, **durable_size,
            )
        finally:
            shutil.rmtree(state_dir, ignore_errors=True)
        ratio = r["us_per_window"] / base["us_per_window"]
        row(
            f"serving/fleet_durable_{fsync}_ck{ck}",
            f"{r['us_per_window']:.0f}",
            f"interpret-mode; durable fleet (state-dir checkpoints + chunk "
            f"WAL), fsync={fsync}, checkpoint every {ck} round(s); "
            f"{r['windows_per_s']:.1f} windows/s aggregate; {ratio:.2f}x "
            f"the in-memory fleet benched this run; {format_percentiles(r)} "
            f"over {r['rounds']} rounds; cold restart from these artifacts "
            f"is bitwise-conformant (tests/test_durability.py); zcr "
            f"features, small detector",
            windows_per_s=round(r["windows_per_s"], 2),
            n_streams=durable_size.get("n_streams", FLEET_STREAMS),
            n_workers=durable_size.get("n_workers", FLEET_WORKERS),
            fsync=fsync,
            checkpoint_interval=ck,
            durable_vs_plain=round(ratio, 3),
            round_p50_ms=r["round_p50_ms"],
            round_p95_ms=r["round_p95_ms"],
            round_p99_ms=r["round_p99_ms"],
            host_devices=jax.device_count(),
        )

    # Fleet-scale bursty-arrival rows (skipped under SMOKE: ~2k windows of
    # interpret-mode forward each).  Acceptance cares about the latency
    # percentiles of a budgeted scoring beat and a *live* drop-rate column
    # under genuine back-pressure.
    if not _smoke():
        for n in BURSTY_STREAMS:
            r = bench_bursty(n, params, cfg)
            hist = ", ".join(
                f"{c}x{s}" for s, c in sorted(r["slot_histogram"].items())
            )
            row(
                f"serving/monitor_bursty_{n}streams_x{BURSTY_WINDOWS}win",
                f"{r['us_per_window']:.0f}",
                f"interpret-mode; bursty arrival over {BURSTY_WAVES} waves "
                f"({BURSTY_WINDOWS}-window bursts into {BURSTY_CAPACITY}-"
                f"window rings, round budget {BURSTY_ROUND_BUDGET}); "
                f"{r['windows_per_s']:.1f} windows/s aggregate; "
                f"{format_percentiles(r)} over "
                f"{r['rounds']} rounds; drop {r['drop_rate']:.1%} (ring "
                f"overflow), {r['served']} served / {r['deferred']} "
                f"deferred window-rounds; {r['forward_calls']} forward "
                f"calls, {r['padded_slots']} padded slots, ladder use "
                f"{hist}; zcr features, small detector",
                windows_per_s=round(r["windows_per_s"], 2),
                n_streams=n,
                batch_slots=BATCH_SLOTS,
                adaptive_slots=True,
                round_budget=BURSTY_ROUND_BUDGET,
                capacity_windows=BURSTY_CAPACITY,
                round_p50_ms=r["round_p50_ms"],
                round_p95_ms=r["round_p95_ms"],
                round_p99_ms=r["round_p99_ms"],
                drop_rate=r["drop_rate"],
                host_devices=jax.device_count(),
            )

    bench_frontend_rows()

    # Deployment-cell rows: the artifact the paper actually ships — pruned
    # flatten (SIII-C) and per-layer mixed precision (SIII-B) — benched at
    # equal stream counts against the unpruned all-int8 baseline on the
    # dense-heavy shape.  Acceptance: pruned strictly above unpruned.
    deploy_cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS[DEPLOY_FEATURE],
        channels=DEPLOY_CHANNELS, hidden=8,
    )
    deploy_params = cnn1d.init_params(jax.random.PRNGKey(1), deploy_cfg)
    last = len(DEPLOY_CHANNELS) - 1
    spec = plan_prune(
        deploy_params[f"conv{last}"]["w"], deploy_cfg.n_frames,
        keep=DEPLOY_KEEP, trim_frames=1,
    )
    policy = PrecisionPolicy.parse(DEPLOY_POLICY, default="int8")
    deploy_streams = 2 if _smoke() else DEPLOY_STREAMS
    cells = [("unpruned", None, None), ("pruned", spec, None)]
    if not _smoke():
        cells += [("mixed", None, policy), ("pruned_mixed", spec, policy)]
    for name, prune, pol in cells:
        r = bench_monitor(
            deploy_streams, deploy_params, deploy_cfg,
            feature=DEPLOY_FEATURE, prune=prune, policy=pol,
        )
        flat = spec.flatten_after if prune is not None else spec.flatten_before
        row(
            f"serving/monitor_deploy_{name}_{deploy_streams}streams_x{WINDOWS_PER_STREAM}win",
            f"{r['us_per_window']:.0f}",
            f"interpret-mode; deployment cell '{name}' (flatten {flat}"
            f"{', policy ' + DEPLOY_POLICY if pol is not None else ''}); "
            f"{r['windows_per_s']:.1f} windows/s aggregate; "
            f"{r['forward_calls']} forward calls ({BATCH_SLOTS} slots, "
            f"{r['padded_slots']} padded); {DEPLOY_FEATURE} features, "
            f"channels {DEPLOY_CHANNELS}",
            windows_per_s=round(r["windows_per_s"], 2),
            n_streams=deploy_streams,
            batch_slots=BATCH_SLOTS,
            flatten=int(flat),
            pruned=prune is not None,
            mixed=pol is not None,
            host_devices=jax.device_count(),
        )

    if not _smoke():
        write_json("BENCH_serving.json", prefix="serving/")


if __name__ == "__main__":
    main()
