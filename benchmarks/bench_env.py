"""Pinned benchmark environment, applied *before the first jax import*.

Perf rows are only comparable across PRs if the process environment that
produced them is pinned — the olmax run.sh idiom (SNIPPETS.md): force the
host platform device count so XLA's thread pools are carved identically on
every run, place the step marker at the outer loop, silence the TF log spam
that skews short timings, and record whether tcmalloc is preloaded (the
single biggest allocator effect on numpy-heavy benches).

Usage, at the very top of a bench module (before anything imports jax)::

    from benchmarks import bench_env
    bench_env.apply()

``fingerprint()`` (callable any time after jax is importable) returns the
environment dict; ``fingerprint_id()`` is its short stable hash, attached to
every bench row via ``benchmarks.common.set_env_fingerprint`` so a JSON row
always names the environment that produced it.
"""
from __future__ import annotations

import glob
import hashlib
import json
import os
import platform
import sys

#: XLA flag pinned on TPU hosts only (merged into any caller-set flags).
#: 0 = program entry, 1 = outermost while loop — the olmax placement.  The
#: CPU build of XLA does not compile this flag in and hard-aborts on it at
#: import (measured), so it is applied exactly when TPU hardware is present;
#: the fingerprint records which way it went.
STEP_MARKER_FLAG = "--xla_step_marker_location=1"

#: Where TPU accelerators appear on a TPU VM.  Module-level so tests can
#: point it at a tmp path and exercise the TPU leg without hardware.
ACCEL_DEVICE_GLOB = "/dev/accel*"

_state: dict = {
    "applied": False,
    "late": False,
    "host_devices": None,
    "step_marker": False,
}


def _tpu_hardware_present() -> bool:
    """A TPU VM exposes its accelerators as /dev/accel* (libtpu merely being
    pip-installed — as in this CPU container — does not count)."""
    return bool(glob.glob(ACCEL_DEVICE_GLOB))


def apply(host_devices: int = 1) -> dict:
    """Pin the bench environment.  Must run before the first jax import —
    a late call is recorded in the fingerprint (the rows will say so)
    rather than silently measuring an unpinned process."""
    _state["late"] = "jax" in sys.modules
    _state["host_devices"] = host_devices
    flags = [f"--xla_force_host_platform_device_count={host_devices}"]
    _state["step_marker"] = _tpu_hardware_present()
    if _state["step_marker"]:
        flags.append(STEP_MARKER_FLAG)
    existing = os.environ.get("XLA_FLAGS", "")
    merged = existing.split() if existing else []
    for f in flags:
        key = f.split("=")[0]
        if not any(m.startswith(key) for m in merged):
            merged.append(f)
    os.environ["XLA_FLAGS"] = " ".join(merged)
    os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")  # no dataset warnings
    _state["applied"] = True
    return dict(_state)


def tcmalloc_loaded() -> bool:
    """The olmax runs LD_PRELOAD libtcmalloc; detect either the preload
    request or the library actually mapped into this process."""
    if "tcmalloc" in os.environ.get("LD_PRELOAD", ""):
        return True
    try:
        with open("/proc/self/maps") as f:
            return "tcmalloc" in f.read()
    except OSError:  # non-Linux host
        return False


def fingerprint() -> dict:
    """The machine-readable bench environment.  Imports jax (fine by now:
    ``apply()`` already ran, or ``late`` records that it did not)."""
    import jax

    return {
        "applied": _state["applied"],
        "late": _state["late"],
        "host_devices": _state["host_devices"],
        "step_marker": _state["step_marker"],
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "tcmalloc": tcmalloc_loaded(),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax": jax.__version__,
        "python": platform.python_version(),
        "machine": platform.machine(),
    }


def fingerprint_id() -> str:
    """Short stable digest of :func:`fingerprint` — the per-row field."""
    blob = json.dumps(fingerprint(), sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:10]
