"""Figs. 4-5 — accuracy / false-alarm / missed-detection vs SNR."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.data import acoustic, features
from repro.training import loop
from repro.training.detector_artifact import get_detector

SNRS = [-10, -5, 0, 5, 10, 15, 20]


def main():
    det = get_detector("mfcc20")
    sweep = acoustic.make_snr_sweep(300, SNRS, seed=11)
    for prec in (Precision.FP32, Precision.INT8):
        pol = PrecisionPolicy.uniform(prec)
        for snr in SNRS:
            audio, labels = sweep[snr]
            f = features.batch_features(audio, "mfcc20")
            m = loop.evaluate_logits(
                loop.predict(det["params"], f, det["cfg"], policy=pol), labels
            )
            row(
                f"fig45/{prec.value}/snr_{snr:+d}dB",
                "",
                f"acc={m.accuracy*100:.2f}% FA={m.false_alarm_rate*100:.2f}% "
                f"MD={m.missed_detection_rate*100:.2f}%",
            )


if __name__ == "__main__":
    main()
