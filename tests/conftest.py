import os

# Tests run on the single real CPU device.  The 512-device override belongs
# ONLY to the dry-run process (repro.launch.dryrun sets it before jax import);
# multi-device tests spawn subprocesses with their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
