"""Property-based tests for :class:`repro.serving.engine.StreamRing`.

The ring is the engine's only stateful ingest path, so its invariants are
stated as properties over *arbitrary* chunk-size delivery schedules rather
than hand-picked examples:

* a popped window is always exactly ``window`` samples — never partial;
* every popped window starts on a hop boundary of the original stream,
  including after drop-oldest overflow (drops are whole hops);
* sample conservation: delivered == dropped + consumed-by-pops + buffered.

Runs under real ``hypothesis`` when installed, else the deterministic
fallback shim (tests/_hypothesis_fallback.py).
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container: deterministic-example fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.serving.engine import StreamRing


def chunk_sizes(max_chunk=64, max_chunks=24):
    return st.lists(
        st.floats(0.0, float(max_chunk)).map(int), min_size=1, max_size=max_chunks
    )


def small_int(lo, hi):
    return st.floats(float(lo), float(hi)).map(int)


def _labelled(n, start):
    """Identifiable samples: the k-th delivered sample has value start + k."""
    return np.arange(start, start + n, dtype=np.float32)


class TestStreamRingProperties:
    @settings(max_examples=40, deadline=None)
    @given(chunk_sizes(), small_int(1, 12), small_int(1, 12))
    def test_never_yields_partial_window(self, chunks, window, hop):
        hop = min(hop, window)
        ring = StreamRing(window, hop, capacity_windows=3)
        delivered = 0
        for n in chunks:
            ring.push(_labelled(n, delivered))
            delivered += n
            while True:
                w = ring.pop_window()
                if w is None:
                    # None only when genuinely short of a full window
                    assert ring.buffered < window
                    break
                assert w.shape == (window,)

    @settings(max_examples=40, deadline=None)
    @given(chunk_sizes(max_chunk=96), small_int(2, 10), small_int(1, 10))
    def test_hop_alignment_survives_overflow(self, chunks, window, hop):
        """Every popped window is a contiguous hop-aligned slice of the
        delivered stream, even after drop-oldest overflow."""
        hop = min(hop, window)
        ring = StreamRing(window, hop, capacity_windows=2)  # tight: forces drops
        delivered = 0
        prev_start = None
        for n in chunks:
            ring.push(_labelled(n, delivered))
            delivered += n
            while (w := ring.pop_window()) is not None:
                start = int(w[0])
                # contiguous slice of the stream, starting on a hop boundary
                np.testing.assert_array_equal(w, _labelled(window, start))
                assert start % hop == 0
                if prev_start is not None:
                    # read head only moves forward, in whole hops
                    assert start > prev_start and (start - prev_start) % hop == 0
                prev_start = start

    @settings(max_examples=40, deadline=None)
    @given(chunk_sizes(max_chunk=96), small_int(1, 12), small_int(1, 12))
    def test_sample_conservation(self, chunks, window, hop):
        """delivered == dropped + hop-consumed + still-buffered, with the
        per-push return value summing to the ``dropped`` counter."""
        hop = min(hop, window)
        ring = StreamRing(window, hop, capacity_windows=2)
        delivered = 0
        pops = 0
        drop_returns = 0
        for n in chunks:
            drop_returns += ring.push(_labelled(n, delivered))
            delivered += n
            while ring.pop_window() is not None:
                pops += 1
        assert drop_returns == ring.dropped
        # each pop consumes exactly one hop off the front; the remainder is
        # still buffered (and too short to form another window)
        assert delivered == ring.dropped + pops * hop + ring.buffered
        assert ring.buffered < window
