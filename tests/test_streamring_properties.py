"""Property-based tests for :class:`repro.serving.engine.StreamRing`.

The ring is the engine's only stateful ingest path, so its invariants are
stated as properties over *arbitrary* chunk-size delivery schedules rather
than hand-picked examples:

* a popped window is always exactly ``window`` samples — never partial;
* every popped window starts on a hop boundary of the original stream,
  including after drop-oldest overflow (drops are whole hops);
* sample conservation: delivered == dropped + consumed-by-pops + buffered.

Runs under real ``hypothesis`` when installed, else the deterministic
fallback shim (tests/_hypothesis_fallback.py).
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container: deterministic-example fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.serving.engine import StreamRing


def chunk_sizes(max_chunk=64, max_chunks=24):
    return st.lists(
        st.floats(0.0, float(max_chunk)).map(int), min_size=1, max_size=max_chunks
    )


def pathological_chunk_sizes(window_hi=12, max_chunks=12):
    """Delivery schedules biased to the pathological edges: empty chunks and
    chunks far larger than the whole ring buffer (capacity is at most
    ``window_hi * capacity_windows`` below), interleaved with normal ones."""
    return st.lists(
        st.sampled_from([0, 0, 1, 7, 31, 5 * window_hi, 40 * window_hi]),
        min_size=1,
        max_size=max_chunks,
    )


def nonfinite_kinds():
    return st.lists(
        st.sampled_from(["nan", "+inf", "-inf", "finite"]), min_size=1, max_size=8
    )


def small_int(lo, hi):
    return st.floats(float(lo), float(hi)).map(int)


def _labelled(n, start):
    """Identifiable samples: the k-th delivered sample has value start + k."""
    return np.arange(start, start + n, dtype=np.float32)


class TestStreamRingProperties:
    @settings(max_examples=40, deadline=None)
    @given(chunk_sizes(), small_int(1, 12), small_int(1, 12))
    def test_never_yields_partial_window(self, chunks, window, hop):
        hop = min(hop, window)
        ring = StreamRing(window, hop, capacity_windows=3)
        delivered = 0
        for n in chunks:
            ring.push(_labelled(n, delivered))
            delivered += n
            while True:
                w = ring.pop_window()
                if w is None:
                    # None only when genuinely short of a full window
                    assert ring.buffered < window
                    break
                assert w.shape == (window,)

    @settings(max_examples=40, deadline=None)
    @given(chunk_sizes(max_chunk=96), small_int(2, 10), small_int(1, 10))
    def test_hop_alignment_survives_overflow(self, chunks, window, hop):
        """Every popped window is a contiguous hop-aligned slice of the
        delivered stream, even after drop-oldest overflow."""
        hop = min(hop, window)
        ring = StreamRing(window, hop, capacity_windows=2)  # tight: forces drops
        delivered = 0
        prev_start = None
        for n in chunks:
            ring.push(_labelled(n, delivered))
            delivered += n
            while (w := ring.pop_window()) is not None:
                start = int(w[0])
                # contiguous slice of the stream, starting on a hop boundary
                np.testing.assert_array_equal(w, _labelled(window, start))
                assert start % hop == 0
                if prev_start is not None:
                    # read head only moves forward, in whole hops
                    assert start > prev_start and (start - prev_start) % hop == 0
                prev_start = start

    @settings(max_examples=40, deadline=None)
    @given(chunk_sizes(max_chunk=96), small_int(1, 12), small_int(1, 12))
    def test_sample_conservation(self, chunks, window, hop):
        """delivered == dropped + hop-consumed + still-buffered, with the
        per-push return value summing to the ``dropped`` counter."""
        hop = min(hop, window)
        ring = StreamRing(window, hop, capacity_windows=2)
        delivered = 0
        pops = 0
        drop_returns = 0
        for n in chunks:
            drop_returns += ring.push(_labelled(n, delivered))
            delivered += n
            while ring.pop_window() is not None:
                pops += 1
        assert drop_returns == ring.dropped
        # each pop consumes exactly one hop off the front; the remainder is
        # still buffered (and too short to form another window)
        assert delivered == ring.dropped + pops * hop + ring.buffered
        assert ring.buffered < window

    @settings(max_examples=40, deadline=None)
    @given(pathological_chunk_sizes(), small_int(1, 12), small_int(1, 12))
    def test_pathological_chunks_keep_invariants(self, chunks, window, hop):
        """Empty chunks and chunks larger than the entire buffer: the ring
        must stay hop-aligned, never yield a partial window, and conserve
        samples — the giant chunk's surviving tail is a contiguous
        hop-aligned slice of the delivered stream."""
        hop = min(hop, window)
        ring = StreamRing(window, hop, capacity_windows=2)
        delivered = 0
        pops = 0
        for n in chunks:
            dropped = ring.push(_labelled(n, delivered))
            assert dropped % hop == 0  # drops are whole hops, empty push drops 0
            delivered += n
            while (w := ring.pop_window()) is not None:
                pops += 1
                start = int(w[0])
                np.testing.assert_array_equal(w, _labelled(window, start))
                assert start % hop == 0
        assert delivered == ring.dropped + pops * hop + ring.buffered
        assert ring.buffered < window

    @settings(max_examples=40, deadline=None)
    @given(
        st.lists(nonfinite_kinds(), min_size=1, max_size=10),
        small_int(2, 10),
        small_int(1, 10),
    )
    def test_nonfinite_samples_pass_through_aligned(self, chunk_kinds, window, hop):
        """The ring is a dumb byte mover: NaN/Inf samples ride through with
        position and count intact (sanitisation is the engine's job, see
        SanitizePolicy) — non-finite payloads must never corrupt alignment
        or the conservation accounting."""
        hop = min(hop, window)
        ring = StreamRing(window, hop, capacity_windows=3)
        delivered = []  # ground-truth stream, possibly non-finite
        pops = 0
        for kinds in chunk_kinds:
            chunk = np.empty(len(kinds), np.float32)
            for i, kind in enumerate(kinds):
                base = float(len(delivered) + i)
                chunk[i] = {
                    "nan": np.nan, "+inf": np.inf, "-inf": -np.inf,
                    "finite": base,
                }[kind]
            ring.push(chunk)
            delivered.extend(chunk.tolist())
            while True:
                # _r is the absolute stream index of the next window's first
                # sample, so it addresses the ground-truth stream directly.
                start = ring._r
                w = ring.pop_window()
                if w is None:
                    break
                pops += 1
                assert w.shape == (window,) and start % hop == 0
                expect = np.asarray(
                    delivered[start : start + window], np.float32
                )
                np.testing.assert_array_equal(w, expect)  # NaN-positional
        stream = np.asarray(delivered, np.float32)
        assert len(stream) == ring.dropped + pops * hop + ring.buffered
