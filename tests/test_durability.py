"""Durable crash-safe fleet state: checkpoints, the write-ahead chunk
journal, the exact state codec, and disk-fault injection.

The acceptance contract mirrors ``test_fault_tolerance.py`` but for process
*death* instead of worker faults, and it is *bitwise*, not approximate:

* kill a durable supervisor mid-round (pushes delivered, ``step()`` never
  ran) under a seeded fault plan, restore a brand-new supervisor from the
  ``--state-dir`` artifacts alone — the union of pre-crash and post-restore
  window scores, and the final ``TrackEvent`` lists, equal the uninterrupted
  run exactly;
* corrupt or tear the WAL tail (the routine end state of a crash
  mid-append) — replay truncates and counts, it never raises;
* inject disk faults (ENOSPC, torn writes, bit flips, slow fsyncs) through
  the filesystem seam — durability degrades and is counted
  (``wal_errors``/``ckpt_errors``), serving output stays bitwise identical.

The state-codec property tests run under real ``hypothesis`` when
installed, else the deterministic fallback shim
(tests/_hypothesis_fallback.py).
"""
import errno
import functools
import os

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container: deterministic-example fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.data import features
from repro.models import cnn1d
from repro.serving.durability import (
    FRAME_HEADER,
    WAL_DROPPED,
    WAL_FAULTED,
    CheckpointStore,
    ChunkWAL,
    CorruptRecord,
    LocalFilesystem,
    dumps_state,
    frame,
    loads_state,
    read_frames,
    write_atomic,
)
from repro.serving.engine import MonitorEngine, SanitizePolicy, StreamRing
from repro.serving.faults import (
    DISK_KINDS,
    KINDS,
    Fault,
    FaultClock,
    FaultPlan,
    FaultyFilesystem,
    InjectedFault,
)
from repro.serving.faults import main as faults_main
from repro.serving.quantized_params import quantize_params
from repro.serving.supervisor import FleetSupervisor
from repro.serving.tracker import TrackEvent, VectorTemporalTracker

TRACK_KW = dict(ema_alpha=0.7, enter_threshold=0.02, exit_threshold=0.01,
                min_duration=1)
SUP_KW = dict(feature_kind="zcr", batch_slots=2,
              sanitize=SanitizePolicy(nonfinite="reject"), **TRACK_KW)


@functools.lru_cache(maxsize=1)
def _detector():
    """Bake one frozen artifact per module (cached, not a fixture, so the
    property tests can reach it from inside ``@given`` bodies too)."""
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, cfg, mode="int8")
    return cfg, qp


@pytest.fixture(scope="module")
def detector():
    return _detector()


def _assert_state_equal(a, b, path="$"):
    """Recursive *exact* equality: dtypes, shapes, scalar types and values
    all match — the codec contract is lossless, not approximately so."""
    if isinstance(a, np.ndarray):
        assert isinstance(b, np.ndarray), f"{path}: {type(b)} is not ndarray"
        assert a.dtype == b.dtype, f"{path}: dtype {a.dtype} != {b.dtype}"
        assert a.shape == b.shape, f"{path}: shape {a.shape} != {b.shape}"
        np.testing.assert_array_equal(a, b, err_msg=path)
    elif isinstance(a, dict):
        assert isinstance(b, dict) and set(a) == set(b), f"{path}: keys differ"
        for k in a:
            _assert_state_equal(a[k], b[k], f"{path}.{k}")
    elif isinstance(a, (list, tuple)) and not isinstance(a, TrackEvent):
        assert type(a) is type(b) and len(a) == len(b), f"{path}: {a} != {b}"
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_state_equal(x, y, f"{path}[{i}]")
    elif isinstance(a, np.generic):
        assert type(a) is type(b), f"{path}: {type(a)} != {type(b)}"
        assert a == b, f"{path}: {a!r} != {b!r}"
    else:
        assert type(a) is type(b), f"{path}: {type(a)} != {type(b)}"
        assert a == b, f"{path}: {a!r} != {b!r}"


# ---------------------------------------------------------------------------
# CRC framing and the exact state codec
# ---------------------------------------------------------------------------


def test_frame_roundtrip_and_damage_detection():
    payloads = [b"alpha", b"bravo-bravo", b"charlie" * 9]
    blob = b"".join(frame(p) for p in payloads)
    out, clean = read_frames(blob)
    assert out == payloads and clean == len(blob)

    # torn tail: the final frame promises more bytes than exist
    out, clean = read_frames(blob[:-3])
    assert out == payloads[:2]
    assert clean == len(frame(payloads[0])) + len(frame(payloads[1]))

    # bit rot mid-stream: parsing stops at the damaged frame's offset
    rot = bytearray(blob)
    rot[len(frame(payloads[0])) + FRAME_HEADER.size + 2] ^= 0x10
    out, clean = read_frames(bytes(rot))
    assert out == payloads[:1] and clean == len(frame(payloads[0]))

    # empty payloads frame fine (WAL DROPPED markers have no chunk bytes)
    out, clean = read_frames(frame(b""))
    assert out == [b""] and clean == FRAME_HEADER.size


def test_state_codec_exact_roundtrip():
    payload = {
        "f32": np.linspace(-1.0, 1.0, 7, dtype=np.float32),
        "f64": np.array([1e-300, np.pi, -0.0]),
        "i64": np.arange(-3, 4, dtype=np.int64),
        "bools": np.array([True, False, True]),
        "mat": np.arange(6, dtype=np.float32).reshape(2, 3),
        "scalar_i": np.int64(-7),
        "scalar_f": np.float32(0.1),
        3: "int keys survive",
        "tuple": (1, 2.5, "x", None),
        "set": {4, 1, 2},
        "events": [TrackEvent(onset_idx=1, offset_idx=5, peak_score=0.9,
                              mean_score=0.5)],
        "nested": {"d": {0: np.float64(2.0)}, "l": [[1], [2, 3]]},
    }
    out = loads_state(dumps_state(payload))
    _assert_state_equal(payload, out)
    # the bytes themselves are a fixpoint of the round-trip
    assert dumps_state(out) == dumps_state(payload)
    # numpy bools deliberately collapse to python bool (json-native)
    assert loads_state(dumps_state(np.bool_(True))) is True
    with pytest.raises(TypeError):
        dumps_state(object())
    with pytest.raises(CorruptRecord):
        loads_state(b"\x01\x02\x03")


def chunk_sizes(max_chunk=96, max_chunks=24):
    return st.lists(
        st.floats(0.0, float(max_chunk)).map(int), min_size=1, max_size=max_chunks
    )


@given(chunk_sizes())
@settings(max_examples=25, deadline=None)
def test_streamring_state_survives_bytes_roundtrip(sizes):
    rng = np.random.default_rng(sum(sizes) + len(sizes))
    ring = StreamRing(window=64, hop=32, capacity_windows=3)
    for i, n in enumerate(sizes):
        ring.push(rng.standard_normal(n).astype(np.float32))
        if i % 2 and ring.ready:  # pop sometimes: exercise both heads
            ring.pop_window()
    sd = ring.state_dict()
    sd2 = loads_state(dumps_state(sd))
    _assert_state_equal(sd, sd2)
    ring2 = StreamRing(window=64, hop=32, capacity_windows=3)
    ring2.load_state_dict(sd2)
    assert dumps_state(ring2.state_dict()) == dumps_state(sd)


@given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=40))
@settings(max_examples=25, deadline=None)
def test_tracker_state_survives_bytes_roundtrip(probs):
    rng = np.random.default_rng(len(probs))
    tr = VectorTemporalTracker(3, ema_alpha=0.5, enter_threshold=0.6,
                               exit_threshold=0.4, min_duration=2)
    for p in probs:
        tr.update(np.full(3, p, np.float64), rng.random(3) < 0.8)
    sd = tr.state_dict()
    sd2 = loads_state(dumps_state(sd))
    _assert_state_equal(sd, sd2)
    tr2 = VectorTemporalTracker(3, ema_alpha=0.5, enter_threshold=0.6,
                                exit_threshold=0.4, min_duration=2)
    tr2.load_state_dict(sd2)
    assert dumps_state(tr2.state_dict()) == dumps_state(sd)


@given(st.lists(st.floats(0.1, 1.8), min_size=1, max_size=10))
@settings(max_examples=10, deadline=None)
def test_engine_snapshot_survives_bytes_roundtrip(sizes):
    """Push-only engine states (ingest mutates rings and counters but never
    calls the forward) round-trip through ``snapshot_bytes`` exactly."""
    cfg, qp = _detector()
    rng = np.random.default_rng(int(sum(sizes) * 100))

    def fresh():
        return MonitorEngine(qp, cfg, n_streams=2, feature_kind="zcr",
                             batch_slots=2, **TRACK_KW)

    eng = fresh()
    for i, f in enumerate(sizes):
        n = int(f * features.N_SAMPLES)
        eng.push(i % 2, rng.standard_normal(n).astype(np.float32))
    blob = eng.snapshot_bytes()
    _assert_state_equal(eng.snapshot(), loads_state(blob))
    eng2 = fresh()
    eng2.restore_bytes(blob)
    assert eng2.snapshot_bytes() == blob


# ---------------------------------------------------------------------------
# CheckpointStore: retention, corruption fallback, version pinning
# ---------------------------------------------------------------------------


def test_checkpoint_store_retention_and_corrupt_fallback(tmp_path):
    store = CheckpointStore(str(tmp_path / "ck"), retain=2)
    for v in range(1, 6):
        store.save(v, {"v": v, "arr": np.full(3, v, np.int64)})
    assert store.versions() == [4, 5]  # compacted down to `retain`

    # at_or_before pins the search below a known version: a newer orphan
    # (written pre-crash, never referenced by any meta) is not resurrected
    v, payload = store.load_latest(at_or_before=4)
    assert v == 4 and payload["v"] == 4

    # bit-rot the newest version: load() raises, load_latest() falls back
    blob = bytearray(store.fs.read_bytes(store._path(5)))
    blob[-1] ^= 0xFF
    with open(store._path(5), "wb") as fh:
        fh.write(bytes(blob))
    with pytest.raises(CorruptRecord):
        store.load(5)
    v, payload = store.load_latest()
    assert v == 4 and payload["v"] == 4 and store.corrupt_skipped == 1

    assert CheckpointStore(str(tmp_path / "empty")).load_latest() is None
    with pytest.raises(ValueError):
        CheckpointStore(str(tmp_path / "bad"), retain=0)


def test_write_atomic_publishes_all_or_nothing(tmp_path):
    plan = FaultPlan([Fault("torn_write", 0, magnitude=0.5)])
    fs = FaultyFilesystem(LocalFilesystem(), plan)
    target = str(tmp_path / "pub.bin")
    with pytest.raises(InjectedFault):
        write_atomic(fs, target, b"hello world")
    # the faulted write leaves neither the file nor its temp behind
    assert not os.path.exists(target) and not os.path.exists(target + ".tmp")
    write_atomic(fs, target, b"hello world")  # op 1: clean
    assert fs.read_bytes(target) == b"hello world"


# ---------------------------------------------------------------------------
# ChunkWAL: append/replay, torn-tail truncation, fsync policies
# ---------------------------------------------------------------------------


def test_chunk_wal_replay_and_tail_truncation(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = ChunkWAL(path, fsync="always")
    c0 = np.arange(4, dtype=np.float32)
    wal.append(stream=0, seq=0, round_=1, chunk=c0)
    wal.append(stream=1, seq=0, round_=1, chunk=c0 * 2.0, flags=WAL_FAULTED)
    wal.append(stream=0, seq=1, round_=2, flags=WAL_FAULTED | WAL_DROPPED)

    recs = wal.replay()
    assert [(r.stream, r.seq, r.round, r.flags) for r in recs] == [
        (0, 0, 1, 0), (1, 0, 1, WAL_FAULTED),
        (0, 1, 2, WAL_FAULTED | WAL_DROPPED),
    ]
    np.testing.assert_array_equal(recs[0].chunk, c0)
    assert recs[0].chunk.dtype == np.float32
    assert recs[2].chunk.size == 0  # DROPPED marker carries no payload
    assert wal.truncations == 0

    # tear the tail mid-frame (crash mid-append) — replay drops exactly the
    # torn record, truncates the file back to its last clean frame, counts
    blob = wal.fs.read_bytes(path)
    wal.fs.truncate(path, len(blob) - 3)
    recs2 = wal.replay()
    assert [(r.stream, r.seq) for r in recs2] == [(0, 0), (1, 0)]
    assert wal.truncations == 1
    assert len(wal.fs.read_bytes(path)) < len(blob) - 3
    # a second replay of the now-clean file is stable: no further damage
    assert len(wal.replay()) == 2 and wal.truncations == 1

    # appended garbage (bit rot past the end) is likewise truncated away
    with open(path, "ab") as fh:
        fh.write(b"\x00garbage-not-a-frame")
    assert len(wal.replay()) == 2 and wal.truncations == 2

    wal.reset()
    assert wal.replay() == [] and not wal.fs.exists(path)
    wal.close()

    with pytest.raises(ValueError):
        ChunkWAL(str(tmp_path / "w2.log"), fsync="sometimes")
    with pytest.raises(ValueError):
        ChunkWAL(str(tmp_path / "w3.log"), fsync_interval=0)


def test_chunk_wal_fsync_policies_count_flushes(tmp_path):
    class CountingFS(LocalFilesystem):
        synced = 0

        def fsync(self, fh):
            type(self).synced += 1
            super().fsync(fh)

    for policy, interval, expect in (("always", 1, 6), ("interval", 3, 2),
                                     ("never", 1, 0)):
        fs = CountingFS()
        CountingFS.synced = 0
        wal = ChunkWAL(str(tmp_path / f"{policy}.log"), fs=fs, fsync=policy,
                       fsync_interval=interval)
        for i in range(6):
            wal.append(stream=0, seq=i, round_=0,
                       chunk=np.zeros(2, np.float32))
        assert CountingFS.synced == expect, policy
        assert len(wal.replay()) == 6


# ---------------------------------------------------------------------------
# FaultyFilesystem: deterministic disk faults on the seam
# ---------------------------------------------------------------------------


def test_faulty_filesystem_injects_deterministically(tmp_path):
    plan = FaultPlan([
        Fault("enospc", 0),
        Fault("torn_write", 1, magnitude=0.25),
        Fault("bit_flip", 2, magnitude=3.0),
    ])
    fs = FaultyFilesystem(LocalFilesystem(), plan)
    path = str(tmp_path / "f.bin")
    fh = fs.open_write(path)
    with pytest.raises(OSError) as ei:  # op 0: disk full, nothing written
        fs.write(fh, b"doomed")
    assert ei.value.errno == errno.ENOSPC
    with pytest.raises(InjectedFault):  # op 1: only a prefix reaches disk
        fs.write(fh, b"xxxxxxxx")
    fs.write(fh, b"ABCD")  # op 2: silent single-bit corruption
    fs.close(fh)
    data = fs.read_bytes(path)
    assert data[:2] == b"xx" and len(data) == 6
    flipped = [bin(a ^ b).count("1") for a, b in zip(data[2:], b"ABCD")]
    assert sum(flipped) == 1  # exactly one bit differs
    assert fs.injected == [("enospc", 0), ("torn_write", 1), ("bit_flip", 2)]

    # the CRC framing is what catches the silent flip on read-back
    fs2 = FaultyFilesystem(LocalFilesystem(),
                           FaultPlan([Fault("bit_flip", 0, magnitude=40.0)]))
    p2 = str(tmp_path / "framed.bin")
    fh = fs2.open_write(p2)
    fs2.write(fh, frame(b"precious payload"))
    fs2.close(fh)
    payloads, clean = read_frames(fs2.read_bytes(p2))
    assert payloads == [] and clean == 0


def test_fault_plan_disk_kinds_generate_and_cli(tmp_path, capsys):
    gen_kw = dict(n_streams=4, n_workers=2, n_rounds=10, n_faults=12,
                  kinds=KINDS)
    p1 = FaultPlan.generate(9, **gen_kw)
    assert p1.faults == FaultPlan.generate(9, **gen_kw).faults  # seeded
    assert any(f.kind in DISK_KINDS for f in p1.faults)
    assert p1.has_disk_faults
    p2 = FaultPlan.from_json(p1.to_json())
    assert p2.faults == p1.faults and p2.seed == 9
    # the default mix still excludes disk kinds (existing seeded plans in
    # the chaos sweep must not change under them)
    default = FaultPlan.generate(9, n_streams=4, n_workers=2, n_rounds=10)
    assert not default.has_disk_faults
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan.generate(0, n_streams=2, n_workers=1, n_rounds=4,
                           kinds=("nope",))

    out = tmp_path / "plan.json"
    faults_main(["--seed", "3", "--rounds", "6", "--faults", "8",
                 "--kinds", "torn_write,enospc,drop_chunk",
                 "--out", str(out)])
    plan = FaultPlan.from_json(out.read_text())
    assert len(plan.faults) == 8
    assert {f.kind for f in plan.faults} <= {"torn_write", "enospc",
                                             "drop_chunk"}
    assert FaultPlan.from_json(plan.to_json()).faults == plan.faults
    capsys.readouterr()
    with pytest.raises(SystemExit):
        faults_main(["--kinds", "bogus", "--out", str(out)])
    assert "unknown fault kind" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# Cold-restart conformance: the acceptance-criteria tests
# ---------------------------------------------------------------------------

N_STREAMS, N_WORKERS, N_ROUNDS = 6, 2, 16


@pytest.fixture(scope="module")
def fleet_scene():
    """Precomputed delivery schedule + seeded fault plan, shared by every
    cold-restart test so interrupted and uninterrupted runs replay the
    identical scene."""
    rng = np.random.default_rng(7)
    schedule = [
        [(s, rng.normal(size=int(rng.uniform(0.4, 1.6) * features.N_SAMPLES)
                        ).astype(np.float32)) for s in range(N_STREAMS)]
        for _ in range(N_ROUNDS)
    ]
    plan = FaultPlan.generate(42, n_streams=N_STREAMS, n_workers=N_WORKERS,
                              n_rounds=N_ROUNDS, n_faults=6)
    return schedule, plan


def _fleet(detector, plan=None, **kw):
    cfg, qp = detector
    if plan is not None:
        # fresh copy per supervisor: worker-fault bookkeeping is stateful
        kw.update(faults=FaultPlan(list(plan.faults), seed=plan.seed),
                  clock=FaultClock(), dispatch_deadline_s=1.0)
    return FleetSupervisor(qp, cfg, n_streams=N_STREAMS, n_workers=N_WORKERS,
                           **SUP_KW, **kw)


def _drive(sup, schedule, *, start=0, cursor=None, upto=None):
    """Deliver the schedule, skipping pushes the restored cursor says were
    already delivered and steps the restored round says were committed.
    ``upto=k`` crashes mid-round k: its pushes are delivered, its ``step()``
    never runs."""
    out = []
    cursor = np.zeros(N_STREAMS, np.int64) if cursor is None else cursor
    ordinals = np.zeros(N_STREAMS, np.int64)
    for r, pushes in enumerate(schedule):
        for s, chunk in pushes:
            if ordinals[s] >= cursor[s]:
                sup.push(s, chunk)
            ordinals[s] += 1
        if r < start:
            continue
        if upto is not None and r >= upto:
            return out
        out.extend(sup.step())
    return out


def _score_map(scored):
    return {(w.stream, w.window_idx): (w.p_uav, w.smoothed, w.active)
            for w in scored}


@pytest.fixture(scope="module")
def fault_reference(detector, fleet_scene):
    schedule, plan = fleet_scene
    ref = _fleet(detector, plan)
    scores = _score_map(_drive(ref, schedule))
    events = ref.finalize()
    assert len(scores) > 0 and sum(len(e) for e in events) > 0
    return scores, events, ref.faulted_chunks.copy()


def test_cold_restart_bitwise_equal_clean_crash(detector, fleet_scene,
                                                tmp_path):
    """SIGKILL between rounds (no close, WAL empty at the crash instant):
    the restored fleet resumes at the checkpointed round and the combined
    run is bitwise identical to one that was never interrupted."""
    schedule, _ = fleet_scene
    ref = _fleet(detector)
    refd = _score_map(_drive(ref, schedule))
    ref_events = ref.finalize()

    d = str(tmp_path / "state")
    sup1 = _fleet(detector, state_dir=d)
    merged = _score_map(_drive(sup1, schedule[:7]))
    del sup1  # the crash: no close(), nothing flushed beyond the last step

    cfg, qp = detector
    sup2 = FleetSupervisor.restore_from_dir(qp, cfg, state_dir=d, **SUP_KW)
    assert sup2 is not None and sup2.round == 7
    assert sup2.replayed_chunks == 0  # between rounds: the WAL was empty
    s2 = _drive(sup2, schedule, start=sup2.round,
                cursor=sup2.pushed_chunks.copy())
    for k, v in _score_map(s2).items():
        assert merged.get(k, v) == v, f"overlap mismatch at {k}"
        merged[k] = v
    assert merged == refd
    assert sup2.finalize() == ref_events


def test_cold_restart_bitwise_equal_midround_crash_with_faults(
        detector, fleet_scene, fault_reference, tmp_path):
    """The acceptance-criteria test: crash *mid-round* (round-6 chunks
    pushed, step never ran) under a seeded fault plan.  The WAL replays the
    uncommitted pushes (``replayed_chunks > 0``), and scores, events and
    fault counters all match the uninterrupted faulted run bitwise."""
    schedule, plan = fleet_scene
    refd, ref_events, ref_faulted = fault_reference

    d = str(tmp_path / "state")
    sup1 = _fleet(detector, plan, state_dir=d)
    merged = _score_map(_drive(sup1, schedule, upto=6))
    del sup1

    cfg, qp = detector
    sup2 = FleetSupervisor.restore_from_dir(
        qp, cfg, state_dir=d,
        faults=FaultPlan(list(plan.faults), seed=plan.seed),
        clock=FaultClock(), dispatch_deadline_s=1.0, **SUP_KW)
    assert sup2 is not None
    assert sup2.replayed_chunks > 0  # the WAL actually did work
    s2 = _drive(sup2, schedule, start=sup2.round,
                cursor=sup2.pushed_chunks.copy())
    for k, v in _score_map(s2).items():
        assert merged.get(k, v) == v, f"overlap mismatch at {k}"
        merged[k] = v
    assert merged == refd
    assert sup2.finalize() == ref_events
    assert sup2.faulted_chunks.tolist() == ref_faulted.tolist()


def test_cold_restart_with_execution_lanes(detector, fleet_scene,
                                           fault_reference, tmp_path):
    """Same contract under threaded lanes: chunks queued but not yet
    drained at the crash never advanced the delivery cursor, so the driver
    re-delivers them after restore."""
    schedule, plan = fleet_scene
    refd, ref_events, _ = fault_reference

    d = str(tmp_path / "state")
    sup1 = _fleet(detector, plan, state_dir=d, lanes="threads")
    merged = _score_map(_drive(sup1, schedule, upto=9))
    del sup1

    cfg, qp = detector
    sup2 = FleetSupervisor.restore_from_dir(
        qp, cfg, state_dir=d, lanes="threads",
        faults=FaultPlan(list(plan.faults), seed=plan.seed),
        clock=FaultClock(), dispatch_deadline_s=1.0, **SUP_KW)
    assert sup2 is not None
    s2 = _drive(sup2, schedule, start=sup2.round,
                cursor=sup2.pushed_chunks.copy())
    sup2.close()
    merged.update(_score_map(s2))
    assert merged == refd
    assert sup2.finalize() == ref_events


def test_restore_from_empty_dir_returns_none(detector, tmp_path):
    cfg, qp = detector
    assert FleetSupervisor.restore_from_dir(
        qp, cfg, state_dir=str(tmp_path / "nothing"), **SUP_KW) is None


# ---------------------------------------------------------------------------
# Supervisor-level damage and disk faults
# ---------------------------------------------------------------------------


def test_supervisor_truncates_torn_wal_tail(detector, tmp_path):
    """A corrupted WAL tail — the routine end state of a crash mid-append —
    is truncated and counted on restore, never an unhandled exception."""
    cfg, qp = detector
    d = str(tmp_path / "state")
    rng = np.random.default_rng(3)
    chunks = [[rng.standard_normal(features.N_SAMPLES).astype(np.float32)
               for _ in range(2)] for _ in range(3)]

    sup = FleetSupervisor(qp, cfg, n_streams=2, n_workers=1, state_dir=d,
                          **SUP_KW)
    for r in range(2):
        for s in range(2):
            sup.push(s, chunks[r][s])
        sup.step()
    for s in range(2):  # crash mid-round 2: pushes WAL-logged, no step
        sup.push(s, chunks[2][s])
    del sup

    wal_path = os.path.join(d, "worker-000", "wal.log")
    assert os.path.exists(wal_path)
    with open(wal_path, "ab") as fh:
        fh.write(b"\x00half-written-frame")

    sup2 = FleetSupervisor.restore_from_dir(qp, cfg, state_dir=d,
                                            n_streams=2, n_workers=1,
                                            **SUP_KW)
    assert sup2 is not None
    assert sup2.wal_truncations == 1  # damage detected, cut, counted
    assert sup2.replayed_chunks == 2  # the clean prefix fully replayed
    assert sup2.round == 2
    assert len(sup2.step()) > 0  # and the fleet keeps serving


def test_disk_faults_degrade_durability_not_serving(detector, tmp_path):
    """ENOSPC / torn writes / bit flips / slow fsyncs on the durability
    seam are counted (``wal_errors``/``ckpt_errors``) while the serving
    output stays bitwise identical to a fault-free, non-durable run."""
    cfg, qp = detector
    plan = FaultPlan([
        Fault("slow_fsync", 1, magnitude=2.0),
        Fault("enospc", 2),
        Fault("torn_write", 5, magnitude=0.5),
        Fault("bit_flip", 7, magnitude=9.0),
    ])
    sup = FleetSupervisor(qp, cfg, n_streams=2, n_workers=1,
                          state_dir=str(tmp_path / "state"), faults=plan,
                          clock=FaultClock(), dispatch_deadline_s=30.0,
                          fsync="always", **SUP_KW)
    ref = FleetSupervisor(qp, cfg, n_streams=2, n_workers=1, **SUP_KW)

    rng = np.random.default_rng(5)
    scored, ref_scored = [], []
    for _ in range(4):
        for s in range(2):
            chunk = rng.standard_normal(features.N_SAMPLES).astype(np.float32)
            sup.push(s, chunk)
            ref.push(s, chunk)
        scored.extend(sup.step())
        ref_scored.extend(ref.step())
    sup.close()

    assert isinstance(sup._fs, FaultyFilesystem)  # auto-wrapped on the seam
    assert sup._fs.injected  # the plan actually fired
    assert sup.wal_errors + sup.ckpt_errors >= 1  # degradation was counted
    assert _score_map(scored) == _score_map(ref_scored)  # output untouched
    assert sup.finalize() == ref.finalize()
