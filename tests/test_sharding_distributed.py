"""Sharding rules, checkpoint/elastic restore, compression, pipeline."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.sharding import DEFAULT_RULES, ShardingRules
from repro.launch import hlo_analysis as H
from repro.training import compression as C
from repro.training.checkpoint import (
    CheckpointManager,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.training.optimizer import Adam, cosine_warmup_schedule, global_norm


class _FakeMesh:
    """Duck-typed mesh: .axis_names + .shape mapping (enough for rules)."""

    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return dict(self._shape)


class TestShardingRules:
    def test_divisibility_fallback(self):
        r = ShardingRules.__new__(ShardingRules)
        r.mesh = _FakeMesh({"data": 16, "model": 16})
        r.rules = dict(DEFAULT_RULES)
        r.fallbacks = []
        spec = r.spec(("embed", "kv_heads", "head_dim"), dims=(2048, 8, 256))
        assert spec == jax.sharding.PartitionSpec(None, None, None)
        assert any("kv_heads" in f[0] for f in r.fallbacks)

    def test_axis_dedup_first_come(self):
        r = ShardingRules.__new__(ShardingRules)
        r.mesh = _FakeMesh({"data": 16, "model": 16})
        r.rules = dict(DEFAULT_RULES)
        r.fallbacks = []
        spec = r.spec(("experts", "embed", "mlp"), dims=(16, 4096, 6400))
        assert spec == jax.sharding.PartitionSpec("model", None, None)

    def test_multi_axis_batch(self):
        r = ShardingRules.__new__(ShardingRules)
        r.mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
        r.rules = dict(DEFAULT_RULES)
        r.fallbacks = []
        spec = r.spec(("batch", "seq"), dims=(256, 4096))
        assert spec == jax.sharding.PartitionSpec(("pod", "data"), None)

    def test_non_divisible_second_axis_partial(self):
        r = ShardingRules.__new__(ShardingRules)
        r.mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
        r.rules = dict(DEFAULT_RULES)
        r.fallbacks = []
        # batch=8 divides pod(2) and data for 2*16=32? 8%32 != 0 -> keep pod only... 8%2==0, 8%(2*16)!=0
        spec = r.spec(("batch",), dims=(8,))
        assert spec == jax.sharding.PartitionSpec("pod")


class TestHLOAnalysis:
    HLO = textwrap.dedent(
        """\
        %body.1 (arg: (f32[8,128], f32[8,128])) -> (f32[8,128], f32[8,128]) {
          %ar = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %x), replica_groups={}
        }
        %cond.1 (arg: (f32[8,128], f32[8,128])) -> pred[] {
        }
        ENTRY %main (p: f32[8,128]) -> f32[8,128] {
          %ag = f32[16,128]{1,0} all-gather(f32[8,128]{1,0} %p), dimensions={0}
          %w = (f32[8,128], f32[8,128]) while(%t), condition=%cond.1, body=%body.1
        }
        """
    )

    def test_shape_bytes(self):
        assert H.shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
        assert H.shape_bytes("(bf16[2,4], s8[8])") == 2 * 4 * 2 + 8

    def test_collectives_with_loop_factors(self):
        out = H.collective_bytes(self.HLO, loop_factors=[10.0])
        # all-gather in entry: result 16*128*4 = 8192; all-reduce in body:
        # 8*128*4 * 2 (wire factor) * 10 (loop factor)
        assert out["per_op_bytes"]["all-gather"] == 8192.0
        assert out["per_op_bytes"]["all-reduce"] == 8 * 128 * 4 * 2 * 10
        assert out["counts"] == {"all-gather": 1, "all-reduce": 1}


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        save_checkpoint(tmp_path, 5, tree)
        step, restored = restore_checkpoint(latest_checkpoint(tmp_path), tree)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))

    def test_atomic_no_partial(self, tmp_path):
        tree = {"a": jnp.ones(3)}
        save_checkpoint(tmp_path, 1, tree)
        dirs = [p.name for p in tmp_path.iterdir()]
        assert dirs == ["step_0000000001"]  # no .tmp_ leftovers

    def test_retention_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, save_every=1)
        for s in range(1, 5):
            mgr.save(s, {"a": jnp.ones(2) * s})
        names = sorted(p.name for p in tmp_path.iterdir())
        assert names == ["step_0000000003", "step_0000000004"]

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"a": jnp.ones((2, 3))})
        with pytest.raises(ValueError):
            restore_checkpoint(latest_checkpoint(tmp_path), {"a": jnp.ones((3, 2))})

    def test_elastic_restore_new_sharding(self, tmp_path):
        """Checkpoint written unsharded restores onto an explicit sharding
        (the mesh-rescale path)."""
        tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
        save_checkpoint(tmp_path, 2, tree)
        sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        step, restored = restore_checkpoint(
            latest_checkpoint(tmp_path), tree, shardings={"w": sh}
        )
        assert restored["w"].sharding == sh
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))

    def test_maybe_restore_empty(self, tmp_path):
        mgr = CheckpointManager(tmp_path / "none")
        step, tree = mgr.maybe_restore({"a": jnp.zeros(1)})
        assert step == 0


class TestCompression:
    def test_int8_roundtrip_error_bounded(self):
        g = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
        q, s = C.int8_compress(g)
        err = float(jnp.max(jnp.abs(C.int8_decompress(q, s) - g)))
        assert err <= float(s) * 0.5 + 1e-7

    def test_error_feedback_removes_bias(self):
        """With error feedback, the *accumulated* compressed signal tracks
        the accumulated true signal (bias-free) — the convergence property."""
        rng = np.random.default_rng(1)
        err = jnp.zeros(64)
        total_true = np.zeros(64)
        total_sent = np.zeros(64)
        for _ in range(200):
            g = jnp.asarray(rng.standard_normal(64) * 0.01, jnp.float32)
            q, s, err = C.compress_with_feedback(g, err)
            total_true += np.asarray(g)
            total_sent += np.asarray(C.int8_decompress(q, s))
        # residual bounded by one quantisation step, not growing with T
        assert np.max(np.abs(total_true - total_sent)) <= float(np.abs(err).max()) + 1e-5

    def test_topk_roundtrip(self):
        g = jnp.asarray(np.random.default_rng(2).standard_normal((8, 8)), jnp.float32)
        vals, idx, shape = C.topk_compress(g, k_frac=0.25)
        r = C.topk_decompress(vals, idx, shape)
        assert r.shape == g.shape
        assert float(jnp.abs(r).max()) == float(jnp.abs(g).max())


class TestOptimizer:
    def test_adam_matches_reference_step(self):
        p = {"w": jnp.asarray([1.0, -2.0])}
        g = {"w": jnp.asarray([0.1, -0.2])}
        opt = Adam(lr=0.1, grad_clip_norm=None)
        st = opt.init(p)
        p2, st2 = opt.update(g, st, p)
        # first Adam step == -lr * sign-ish update
        m = 0.1 * np.array([0.1, -0.2])
        v = 0.001 * np.array([0.01, 0.04])
        expected = np.array([1.0, -2.0]) - 0.1 * (m / 0.1) / (np.sqrt(v / 0.001) + 1e-8)
        np.testing.assert_allclose(np.asarray(p2["w"]), expected, rtol=1e-5)

    def test_grad_clip(self):
        p = {"w": jnp.ones(4)}
        g = {"w": jnp.ones(4) * 100.0}
        opt = Adam(lr=0.0, grad_clip_norm=1.0)
        opt.update(g, opt.init(p), p)  # just exercises the path
        assert float(global_norm(g)) > 1.0

    def test_schedule_shape(self):
        lr = cosine_warmup_schedule(1.0, warmup=10, total=100)
        assert float(lr(jnp.asarray(0))) == 0.0
        assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
        assert float(lr(jnp.asarray(100))) < 0.2


def test_prefetch_loader():
    from repro.data.pipeline import PrefetchingLoader, synthetic_lm_batches

    make = synthetic_lm_batches(vocab=64, batch=2, seq=8, n_steps=3)
    loader = PrefetchingLoader(make, prefetch=2)
    batches = list(loader)
    assert len(batches) == 3
    assert batches[0]["tokens"].shape == (2, 8)
    # deterministic across loaders
    make2 = synthetic_lm_batches(vocab=64, batch=2, seq=8, n_steps=3)
    b2 = list(PrefetchingLoader(make2, prefetch=1))
    np.testing.assert_array_equal(np.asarray(batches[1]["tokens"]), np.asarray(b2[1]["tokens"]))
