"""End-to-end deployed-datapath inference (all Pallas kernels) vs fp32 JAX."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cnn1d
from repro.serving.accelerator import accelerator_forward, deviation_report


def _setup():
    cfg = cnn1d.CNNConfig(input_len=128, channels=(4, 8), hidden=8)
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    return cfg, params, x


def test_accelerator_probs_valid():
    cfg, params, x = _setup()
    probs = accelerator_forward(params, x, cfg)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-5)
    assert bool(jnp.all(probs >= 0))


def test_accelerator_close_to_fp32():
    cfg, params, x = _setup()
    rep = deviation_report(params, x, cfg)
    assert rep["max_prob_dev"] < 0.15, rep  # int8 end-to-end budget
    assert rep["decision_agreement"] >= 0.875, rep


def test_fxp8_mode_runs():
    cfg, params, x = _setup()
    probs = accelerator_forward(params, x, cfg, fxp=True)
    assert bool(jnp.all(jnp.isfinite(probs)))


def test_per_sample_scales_improve_mixed_loudness_batch():
    """One loud sample must not crush the quantisation resolution of quiet
    co-batched samples: per-sample activation scales (the default) keep the
    deviation of a mixed-loudness batch at the single-sample level, where a
    per-tensor scale degrades it by an order of magnitude."""
    cfg, params, x = _setup()
    x_mixed = np.asarray(x).copy()
    x_mixed[0] *= 100.0  # one loud stream in the micro-batch
    x_mixed = jnp.asarray(x_mixed)
    rep_per_sample = deviation_report(params, x_mixed, cfg, per_sample_acts=True)
    rep_per_tensor = deviation_report(params, x_mixed, cfg, per_sample_acts=False)
    assert rep_per_sample["max_prob_dev"] <= rep_per_tensor["max_prob_dev"]
    assert rep_per_sample["max_prob_dev"] < 0.05, rep_per_sample


def test_row_results_independent_of_cobatch():
    """Per-sample scales make each row's probabilities bitwise independent
    of whatever else shares its batch — the property the streaming engine's
    micro-batching relies on."""
    cfg, params, x = _setup()
    full = np.asarray(accelerator_forward(params, x, cfg))
    rng = np.random.default_rng(0)
    for i in range(x.shape[0]):
        block = rng.standard_normal((4, cfg.input_len)).astype(np.float32) * 10.0
        block[2] = np.asarray(x)[i]  # same row, different co-batch + position
        probs = np.asarray(accelerator_forward(params, jnp.asarray(block), cfg))
        np.testing.assert_array_equal(probs[2], full[i])
