"""End-to-end deployed-datapath inference (all Pallas kernels) vs fp32 JAX."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import cnn1d
from repro.serving.accelerator import accelerator_forward, deviation_report


def _setup():
    cfg = cnn1d.CNNConfig(input_len=128, channels=(4, 8), hidden=8)
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    return cfg, params, x


def test_accelerator_probs_valid():
    cfg, params, x = _setup()
    probs = accelerator_forward(params, x, cfg)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, atol=1e-5)
    assert bool(jnp.all(probs >= 0))


def test_accelerator_close_to_fp32():
    cfg, params, x = _setup()
    rep = deviation_report(params, x, cfg)
    assert rep["max_prob_dev"] < 0.15, rep  # int8 end-to-end budget
    assert rep["decision_agreement"] >= 0.875, rep


def test_fxp8_mode_runs():
    cfg, params, x = _setup()
    probs = accelerator_forward(params, x, cfg, fxp=True)
    assert bool(jnp.all(jnp.isfinite(probs)))
