"""Tests for ``scripts/perf_gate.py`` — the CI perf-regression gate.

The gate must actually bite: an injected synthetic regression in a copied
bench JSON exits nonzero; a drop inside the noise band passes; a required
row pattern that matches nothing fails (a bench silently dropping a row is
exactly the regression an eyeball diff misses); a brand-new row is allowed.
"""
import importlib.util
import json
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
GATE = ROOT / "scripts" / "perf_gate.py"

_spec = importlib.util.spec_from_file_location("perf_gate", GATE)
perf_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(perf_gate)


def _baseline() -> dict:
    return {
        "kernels/conv_layer_fused_8x1096x64": {
            "median_us": 100.0,
            "speedup_vs_im2col": 2.0,
            "env_fingerprint": "aaaaaaaaaa",
        },
        "kernels/frontend_jax_mfcc20_B8": {
            "median_us": 50.0,
            "speedup_vs_numpy": 4.0,
            "env_fingerprint": "aaaaaaaaaa",
        },
        "kernels/quant_matmul_256x1096x64": {"median_us": 10.0},  # no ratio: ungated
    }


def test_injected_regression_fails():
    fresh = _baseline()
    fresh["kernels/conv_layer_fused_8x1096x64"]["speedup_vs_im2col"] = 1.0  # 2.0 -> 1.0
    res = perf_gate.compare(fresh, _baseline(), band=0.30)
    assert len(res["failures"]) == 1
    assert "speedup_vs_im2col" in res["failures"][0]


def test_drop_within_noise_band_passes():
    fresh = _baseline()
    # 2.0 * (1 - 0.30) = 1.40 floor; 1.5 is a real drop but inside the band
    fresh["kernels/conv_layer_fused_8x1096x64"]["speedup_vs_im2col"] = 1.5
    res = perf_gate.compare(fresh, _baseline(), band=0.30)
    assert res["failures"] == []
    assert any("conv_layer_fused" in c for c in res["checked"])


def test_missing_required_row_fails():
    fresh = _baseline()
    del fresh["kernels/frontend_jax_mfcc20_B8"]  # bench silently dropped it
    res = perf_gate.compare(
        fresh, _baseline(), require=["kernels/frontend_jax_*"],
    )
    assert len(res["failures"]) == 1
    assert "frontend_jax" in res["failures"][0]
    # a row present without a ratio field must not satisfy the requirement
    fresh["kernels/frontend_jax_mfcc20_B8"] = {"median_us": 50.0}
    res = perf_gate.compare(fresh, _baseline(), require=["kernels/frontend_jax_*"])
    assert len(res["failures"]) == 1


def test_new_row_allowed():
    fresh = _baseline()
    fresh["kernels/conv_layer_fused_64x1096x256"] = {
        "median_us": 900.0, "speedup_vs_im2col": 0.5,  # terrible, but new
    }
    res = perf_gate.compare(fresh, _baseline())
    assert res["failures"] == []
    assert "kernels/conv_layer_fused_64x1096x256" in res["new"]


def test_env_fingerprint_mismatch_warns_not_fails():
    fresh = _baseline()
    fresh["kernels/conv_layer_fused_8x1096x64"]["env_fingerprint"] = "bbbbbbbbbb"
    res = perf_gate.compare(fresh, _baseline())
    assert res["failures"] == []
    assert any("fingerprint" in w for w in res["warnings"])


def test_cli_exit_codes_on_copied_json(tmp_path):
    """End-to-end: the script as CI runs it, on a copied bench JSON with a
    synthetic regression injected — exit 1; clean copy — exit 0; missing
    fresh file — exit 2."""
    base = tmp_path / "BENCH_kernels.json"
    base.write_text(json.dumps(_baseline()))
    ok = tmp_path / "fresh_ok.json"
    ok.write_text(json.dumps(_baseline()))
    bad_rows = _baseline()
    bad_rows["kernels/frontend_jax_mfcc20_B8"]["speedup_vs_numpy"] = 0.1
    bad = tmp_path / "fresh_bad.json"
    bad.write_text(json.dumps(bad_rows))

    cmd = [sys.executable, str(GATE), "--baseline", str(base)]
    req = ["--require", "kernels/conv_layer_fused_*"]
    p = subprocess.run(
        cmd + ["--fresh", str(ok)] + req, capture_output=True, text=True,
    )
    assert p.returncode == 0, p.stderr
    assert "perf_gate: OK" in p.stdout
    p = subprocess.run(
        cmd + ["--fresh", str(bad)] + req, capture_output=True, text=True,
    )
    assert p.returncode == 1
    assert "FAIL" in p.stderr
    p = subprocess.run(
        cmd + ["--fresh", str(tmp_path / "nope.json")], capture_output=True, text=True,
    )
    assert p.returncode == 2
