"""TemporalTracker bookkeeping regressions + scalar/vector agreement.

The seed tracker had two event-bookkeeping bugs this file pins down:

1. ``_close()`` always dropped the last smoothed score, even from
   ``finalize()`` where the final window is genuinely active — peak/mean
   excluded a valid window and the offset's score went missing.
2. The duration gate (``len(scores) - 1 >= min_duration``) disagreed with
   ``TrackEvent.duration = offset - onset + 1`` on the finalize path, so
   still-active events of exactly ``min_duration`` windows were dropped.
"""
import numpy as np
import pytest

from repro.serving.tracker import (
    TemporalTracker,
    TrackEvent,
    VectorTemporalTracker,
    track_stream,
)

KW = dict(ema_alpha=1.0, enter_threshold=0.65, exit_threshold=0.35, min_duration=2)


def test_finalize_keeps_final_active_window():
    """A stream that ends while tracking closes inclusively: the last window
    belongs to the event and contributes to peak/mean."""
    events = track_stream([0.1, 0.7, 0.8, 0.95], **KW)
    assert events == [
        TrackEvent(onset_idx=1, offset_idx=3, peak_score=0.95,
                   mean_score=(0.7 + 0.8 + 0.95) / 3)
    ]
    assert events[0].duration == 3


def test_finalize_event_of_exactly_min_duration_kept():
    """Regression: duration gate must agree with TrackEvent.duration."""
    events = track_stream([0.1, 0.7, 0.9], **KW)
    assert events == [
        TrackEvent(onset_idx=1, offset_idx=2, peak_score=0.9,
                   mean_score=(0.7 + 0.9) / 2)
    ]
    assert events[0].duration == 2


def test_update_close_event_of_exactly_min_duration_kept():
    events = track_stream([0.7, 0.9, 0.1, 0.1], **KW)
    assert events == [
        TrackEvent(onset_idx=0, offset_idx=1, peak_score=0.9,
                   mean_score=(0.7 + 0.9) / 2)
    ]


def test_exit_window_excluded_from_event_stats():
    """The window whose EMA breaks the track is not part of the event: the
    offset, peak and mean all stop at the previous window."""
    events = track_stream([0.9, 0.7, 0.8, 0.2, 0.1], **KW)
    assert events == [
        TrackEvent(onset_idx=0, offset_idx=2, peak_score=0.9,
                   mean_score=(0.9 + 0.7 + 0.8) / 3)
    ]


def test_sub_min_duration_blip_rejected_both_paths():
    assert track_stream([0.9, 0.1, 0.1], **KW) == []  # update-close path
    assert track_stream([0.1, 0.1, 0.9], **KW) == []  # finalize path


def test_ema_smoothing_hand_computed():
    """alpha=0.5 EMA sequence computed by hand, event stats pinned."""
    kw = dict(ema_alpha=0.5, enter_threshold=0.6, exit_threshold=0.3, min_duration=2)
    # p:    1.0   1.0    0.8   0.0    0.0
    # ema:  1.0   1.0    0.9   0.45   0.225 -> exits at idx 4
    events = track_stream([1.0, 1.0, 0.8, 0.0, 0.0], **kw)
    assert events == [
        TrackEvent(onset_idx=0, offset_idx=3, peak_score=1.0,
                   mean_score=(1.0 + 1.0 + 0.9 + 0.45) / 4)
    ]


def test_reset_clears_state():
    tr = TemporalTracker(**KW)
    for p in (0.9, 0.9, 0.9):
        tr.update(p)
    tr.reset()
    assert tr.finalize() == [] and tr.smoothed == 0.0


# ---------------------------------------------------------------------------
# Vectorised tracker
# ---------------------------------------------------------------------------


def test_vector_matches_scalar_dense_updates():
    rng = np.random.default_rng(11)
    n, steps = 6, 400
    p = rng.random((steps, n))
    kw = dict(ema_alpha=0.3, enter_threshold=0.6, exit_threshold=0.4, min_duration=2)
    vec = VectorTemporalTracker(n, **kw)
    scalars = [TemporalTracker(**kw) for _ in range(n)]
    for t in range(steps):
        st = vec.update(p[t])
        for s in range(n):
            ss = scalars[s].update(float(p[t, s]))
            assert st["idx"][s] == ss["idx"]
            assert st["smoothed"][s] == ss["smoothed"]
            assert st["active"][s] == ss["active"]
    vev = vec.finalize()
    total = 0
    for s in range(n):
        assert vev[s] == scalars[s].finalize()
        total += len(vev[s])
    assert total > 0  # the comparison is not vacuous


def test_vector_masked_updates_freeze_streams():
    """A masked-out stream keeps its EMA, activity and window index frozen —
    the uneven-arrival case the monitor engine produces every round."""
    rng = np.random.default_rng(12)
    n, steps = 4, 250
    p = rng.random((steps, n))
    masks = rng.random((steps, n)) < 0.6
    kw = dict(ema_alpha=0.5, enter_threshold=0.55, exit_threshold=0.45, min_duration=1)
    vec = VectorTemporalTracker(n, **kw)
    scalars = [TemporalTracker(**kw) for _ in range(n)]
    for t in range(steps):
        vec.update(p[t], masks[t])
        for s in range(n):
            if masks[t, s]:
                scalars[s].update(float(p[t, s]))
    vev = vec.finalize()
    assert sum(len(e) for e in vev) > 0
    for s in range(n):
        assert vev[s] == scalars[s].finalize()


def test_vector_initial_state():
    vec = VectorTemporalTracker(3)
    assert not vec.active.any()
    np.testing.assert_array_equal(vec.smoothed, np.zeros(3))
    assert vec.finalize() == [[], [], []]


def test_vector_events_invariant_to_dispatch_order():
    """Shard-reordered dispatch: sharded/double-buffered harvests change
    *when* each stream's window reaches the tracker relative to other
    streams, never the per-stream order.  Every interleaving schedule must
    produce TrackEvent lists identical to a scalar replay of each stream."""
    rng = np.random.default_rng(13)
    n, steps = 5, 120
    p = rng.random((steps, n))
    kw = dict(ema_alpha=0.5, enter_threshold=0.55, exit_threshold=0.45, min_duration=1)
    ref = [track_stream(p[:, s], **kw) for s in range(n)]
    assert sum(len(e) for e in ref) > 0

    def rounds_round_robin():
        for t in range(steps):
            yield p[t], np.ones(n, bool)

    def rounds_stream_major():
        # one whole stream drains before the next starts (extreme reorder)
        for s in range(n):
            for t in range(steps):
                mask = np.zeros(n, bool)
                mask[s] = True
                yield p[t], mask

    def rounds_random_shards():
        # each round advances a random subset, e.g. whichever shard's
        # harvest completed first; per-stream cursors keep stream order
        cursor = np.zeros(n, np.int64)
        while (cursor < steps).any():
            mask = (rng.random(n) < 0.5) & (cursor < steps)
            if not mask.any():
                continue
            probs = np.zeros(n)
            probs[mask] = p[cursor[mask], np.flatnonzero(mask)]
            yield probs, mask
            cursor[mask] += 1

    for schedule in (rounds_round_robin, rounds_stream_major, rounds_random_shards):
        vec = VectorTemporalTracker(n, **kw)
        for probs, mask in schedule():
            vec.update(np.asarray(probs, np.float64), mask)
        events = vec.finalize()
        for s in range(n):
            assert events[s] == ref[s], schedule.__name__


# ---------------------------------------------------------------------------
# Crash-recoverable state (state_dict / load_state_dict)
# ---------------------------------------------------------------------------


def test_state_dict_restore_replays_bitwise():
    """Snapshot mid-sequence, load into a FRESH tracker, replay the tail:
    EMA trajectory and events must be bitwise identical to the tracker that
    never died — the crash-recovery contract."""
    rng = np.random.default_rng(31)
    n, steps, cut = 4, 300, 117
    p = rng.random((steps, n))
    masks = rng.random((steps, n)) < 0.7
    kw = dict(ema_alpha=0.4, enter_threshold=0.55, exit_threshold=0.45, min_duration=2)

    ref = VectorTemporalTracker(n, **kw)
    for t in range(steps):
        ref.update(p[t], masks[t])
    ref_events = ref.finalize()
    assert sum(len(e) for e in ref_events) > 0

    first = VectorTemporalTracker(n, **kw)
    for t in range(cut):
        first.update(p[t], masks[t])
    snap = first.state_dict()

    revived = VectorTemporalTracker(n, **kw)
    revived.load_state_dict(snap)
    states = []
    for t in range(cut, steps):
        states.append(revived.update(p[t], masks[t]))
    assert revived.finalize() == ref_events

    # the revived trajectory is the uninterrupted one, bitwise
    ref2 = VectorTemporalTracker(n, **kw)
    for t in range(steps):
        st2 = ref2.update(p[t], masks[t])
        if t >= cut:
            got = states[t - cut]
            np.testing.assert_array_equal(got["smoothed"], st2["smoothed"])
            np.testing.assert_array_equal(got["idx"], st2["idx"])
            np.testing.assert_array_equal(got["active"], st2["active"])


def test_state_dict_is_deep_copied():
    """Mutating the tracker after snapshot must not leak into the snapshot,
    and vice versa — a supervisor keeps snapshots across later rounds."""
    vec = VectorTemporalTracker(2, ema_alpha=1.0, enter_threshold=0.5,
                                exit_threshold=0.2, min_duration=1)
    vec.update(np.array([0.9, 0.1]))
    snap = vec.state_dict()
    n_events_then = len(snap["events"][0])
    vec.update(np.array([0.1, 0.1]))  # closes stream 0's event
    vec.finalize()
    assert len(snap["events"][0]) == n_events_then  # snapshot unchanged
    snap["_ema"][0] = 123.0
    assert vec._ema[0] != 123.0


def test_load_state_dict_validates_stream_count():
    sd = VectorTemporalTracker(3).state_dict()
    with pytest.raises(ValueError, match="3 stream"):
        VectorTemporalTracker(2).load_state_dict(sd)
