"""1D-F-CNN behaviour: shapes, precision modes, train-ability, tracking."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.models import cnn1d
from repro.serving.tracker import TemporalTracker, track_stream
from repro.training import loop


def test_canonical_flatten():
    assert cnn1d.CANONICAL.flatten_size == 35_072
    assert cnn1d.CANONICAL.n_frames == 137


def test_forward_shapes_and_finite():
    cfg = cnn1d.CNNConfig(input_len=128, channels=(4, 8), hidden=8)
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 128))
    for prec in Precision:
        out = cnn1d.forward(params, x, cfg, policy=PrecisionPolicy.uniform(prec))
        assert out.shape == (3, 2)
        assert bool(jnp.all(jnp.isfinite(out)))


def test_bf16_close_int8_moderate():
    cfg = cnn1d.CNNConfig(input_len=128, channels=(4, 8), hidden=8)
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    base = cnn1d.forward(params, x, cfg)
    bf = cnn1d.forward(params, x, cfg, policy=PrecisionPolicy.uniform(Precision.BF16))
    i8 = cnn1d.forward(params, x, cfg, policy=PrecisionPolicy.uniform(Precision.INT8))
    d_bf = float(jnp.max(jnp.abs(bf - base)))
    d_i8 = float(jnp.max(jnp.abs(i8 - base)))
    assert d_bf < d_i8 + 1e-6
    assert d_bf < 0.1


def test_detector_learns_separable_task():
    rng = np.random.default_rng(0)
    n, m = 384, 128
    x = rng.standard_normal((n, m)).astype(np.float32)
    y = rng.integers(0, 2, n).astype(np.int32)
    x[y == 1, :16] += 4.0  # strong localized pattern
    cfg = cnn1d.CNNConfig(input_len=m, channels=(4, 8), hidden=8, dropout=0.1)
    res = loop.train_detector(x[:288], y[:288], x[288:], y[288:], cfg, epochs=25, batch=32, patience=25)
    assert res.best_val_acc > 0.85


def test_metrics_math():
    logits = np.array([[0.0, 1.0], [1.0, 0.0], [0.0, 1.0], [1.0, 0.0]])
    labels = np.array([1, 0, 0, 1])
    m = loop.evaluate_logits(logits, labels)
    assert m.accuracy == 0.5
    assert m.false_alarm_rate == 0.5 and m.missed_detection_rate == 0.5


def test_tracker_hysteresis_and_min_duration():
    probs = [0.1, 0.2, 0.9, 0.9, 0.9, 0.2, 0.1, 0.95, 0.1, 0.1]
    events = track_stream(probs, ema_alpha=1.0, min_duration=2)
    assert len(events) == 1  # the single-window blip at idx 7 is rejected
    assert events[0].onset_idx == 2
    assert events[0].peak_score > 0.8


def test_tracker_chatter_suppression():
    rng = np.random.default_rng(0)
    noisy = 0.5 + 0.3 * rng.standard_normal(200)
    tr = TemporalTracker(ema_alpha=0.2, enter_threshold=0.75, exit_threshold=0.3)
    for p in np.clip(noisy, 0, 1):
        tr.update(float(p))
    raw_crossings = int(np.sum(np.diff(noisy > 0.75)))
    assert len(tr.finalize()) <= max(1, raw_crossings // 4)
