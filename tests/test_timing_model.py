"""Cycle-accurate timing model (eqs. 9-10) and resource model checks."""
import math

from repro.core import timing_model as TM
from repro.models.cnn1d import CANONICAL, layer_macs


def test_eq10_closed_form():
    macs = {"l1": 40, "l2": 80, "l3": 120}
    cfg = TM.DatapathConfig(mac_bank_width=4, piso=False)
    r = TM.total_cycles_sequential(macs, flatten_size=0, cfg=cfg)
    L = 3
    assert r["total"] == (10 + 20 + 30) + 2 * L - 3


def test_piso_serialisation_term():
    macs = {"l1": 4}
    a = TM.total_cycles_sequential(macs, flatten_size=1000)
    b = TM.total_cycles_sequential(macs, flatten_size=0)
    assert a["total"] - b["total"] == 1000


def test_parallel_faster_than_sequential():
    macs = layer_macs(CANONICAL)
    p = TM.total_cycles_parallel(macs)
    s = TM.total_cycles_sequential(macs, flatten_size=35072)
    assert p["total"] < s["total"]


def test_116ms_calibration():
    ms = TM.shield8_latency(pruned=True)["seconds"] * 1e3
    assert abs(ms - 116.0) < 1.0, ms


def test_pruning_reduces_latency():
    p = TM.shield8_latency(pruned=True)["seconds"]
    u = TM.shield8_latency(pruned=False)["seconds"]
    assert p < u


def test_resource_row_matches_published():
    r = TM.resource_estimate()
    assert r["luts"] == 2268 and r["ffs"] == 3250 and r["bram_dsp"] == 8


def test_resource_scales_with_bank_width():
    r4 = TM.resource_estimate(TM.DatapathConfig(mac_bank_width=4))
    r8 = TM.resource_estimate(TM.DatapathConfig(mac_bank_width=8))
    assert r8["luts"] > r4["luts"]
    # still far below the published parallel designs at W=8
    assert r8["luts"] < TM.PUBLISHED_FPGA_RESOURCES["Layer-multiplexed [15]"]["luts"]


def test_mac_bank_width_halves_cycles():
    macs = {"l": 1000}
    c2 = TM.total_cycles_sequential(macs, 0, TM.DatapathConfig(mac_bank_width=2))
    c4 = TM.total_cycles_sequential(macs, 0, TM.DatapathConfig(mac_bank_width=4))
    assert c2["per_layer"]["l"] == 2 * c4["per_layer"]["l"]


def test_energy_model():
    assert math.isclose(TM.energy_joules(0.116, 0.94), 0.109, rel_tol=1e-2)
