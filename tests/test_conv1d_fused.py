"""Fused-vs-unfused parity: the fused conv kernel against the materialised
im2col reference, and the QuantizedParams serving cache against the legacy
quantise-per-call path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import fxp8_quantize, int8_symmetric
from repro.kernels import ops
from repro.kernels.conv1d_fused import conv1d_fused, conv1d_fused_q
from repro.models import cnn1d
from repro.serving import quantized_params as qpm
from repro.serving.accelerator import accelerator_forward

RNG = np.random.default_rng(7)

SHAPES = [
    (2, 64, 8, 16, 3),  # generic
    (1, 33, 3, 5, 5),  # odd everything, wider tap
    (2, 100, 1, 4, 3),  # Cin=1 (the detector's first layer)
    (1, 137, 64, 64, 3),  # canonical post-pool frame count
    (3, 16, 4, 4, 1),  # pointwise conv (no halo)
]


def _conv_case(b, l, cin, cout, k):
    x = jnp.asarray(RNG.standard_normal((b, l, cin)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, cin, cout)) * 0.2, jnp.float32)
    bias = jnp.asarray(RNG.standard_normal(cout), jnp.float32)
    return x, w, bias


@pytest.mark.slow  # full shape sweep; the epilogue/per-sample tests below keep fast-tier coverage
@pytest.mark.parametrize("b,l,cin,cout,k", SHAPES)
@pytest.mark.parametrize("fxp", [False, True])
def test_int32_accumulators_bitwise(b, l, cin, cout, k, fxp):
    """The in-kernel im2col reproduces the materialised im2col accumulators
    bit for bit — integer math, no tolerance."""
    x, w, _ = _conv_case(b, l, cin, cout, k)
    quant = fxp8_quantize if fxp else int8_symmetric
    xq, wq = quant(x, axis=None), quant(w, axis=2)
    acc = conv1d_fused_q(xq.q, wq.q, xq.scale, wq.scale, return_acc=True)
    patches = ops._im2col(xq.q.astype(jnp.float32), k).astype(jnp.int32)
    wmat = wq.q.reshape(k * cin, cout).astype(jnp.int32)
    expect = (patches @ wmat).reshape(b, l, cout)
    assert acc.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(expect))


@pytest.mark.slow
@pytest.mark.parametrize("b,l,cin,cout,k", SHAPES)
@pytest.mark.parametrize("fxp", [False, True])
def test_dequantised_matches_conv1d_q(b, l, cin, cout, k, fxp):
    """Same int8 payloads + same dequant ordering => <=1e-5 fp32 agreement
    with the im2col reference path."""
    x, w, bias = _conv_case(b, l, cin, cout, k)
    fused = conv1d_fused(x, w, bias, fxp=fxp)
    reference = ops.conv1d_q(x, w, bias, fxp=fxp)
    np.testing.assert_allclose(
        np.asarray(fused), np.asarray(reference), atol=1e-5, rtol=1e-5
    )


def test_per_sample_activation_scales_match_per_row_calls():
    """A (B,)-vector activation scale dequantises each batch row with its own
    scale: the batched call must equal B independent single-row calls."""
    x, w, bias = _conv_case(3, 32, 4, 8, 3)
    wq = int8_symmetric(w, axis=2)
    # quantise every row independently (what per-sample serving does)
    rows = [int8_symmetric(x[i], axis=None) for i in range(x.shape[0])]
    xq = jnp.stack([r.q for r in rows])
    xs = jnp.stack([r.scale for r in rows]).reshape(-1, 1)
    batched = conv1d_fused_q(xq, wq.q, xs, wq.scale, bias, act="relu")
    for i, r in enumerate(rows):
        single = conv1d_fused_q(
            r.q[None], wq.q, r.scale, wq.scale, bias, act="relu"
        )
        np.testing.assert_array_equal(np.asarray(batched[i]), np.asarray(single[0]))


def test_fused_epilogue_relu_clip():
    x, w, bias = _conv_case(2, 64, 8, 16, 3)
    alpha = jnp.asarray(0.5, jnp.float32)
    fused = conv1d_fused(x, w, bias, act="relu", clip=alpha)
    expect = jnp.minimum(jnp.maximum(ops.conv1d_q(x, w, bias), 0.0), alpha)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(expect), atol=1e-5)


def test_quant_matmul_fused_epilogue():
    x = jnp.asarray(RNG.standard_normal((32, 64)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((64, 16)) * 0.1, jnp.float32)
    bias = jnp.asarray(RNG.standard_normal(16), jnp.float32)
    fused = ops.quant_matmul_f32(x, w, bias, act="relu")
    expect = jnp.maximum(ops.quant_matmul_f32(x, w) + bias, 0.0)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(expect), atol=1e-5)


# ---------------------------------------------------------------------------
# QuantizedParams cache parity + quantise-once guarantees
# ---------------------------------------------------------------------------


def _detector():
    cfg = cnn1d.CNNConfig(input_len=128, channels=(4, 8), hidden=8)
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    return cfg, params, x


@pytest.mark.parametrize("mode", ["int8", "fxp8"])
def test_cached_params_match_per_call_quantisation(mode):
    cfg, params, x = _detector()
    fxp = mode == "fxp8"
    legacy = accelerator_forward(params, x, cfg, fxp=fxp)
    cached = accelerator_forward(cnn1d.export_quantized(params, cfg, mode=mode), x, cfg)
    np.testing.assert_allclose(np.asarray(cached), np.asarray(legacy), atol=1e-6)
    np.testing.assert_allclose(np.asarray(cached.sum(-1)), 1.0, atol=1e-5)


def test_serving_does_zero_weight_quantisation_per_call():
    """Weights are quantised exactly once per precision mode; serving calls
    afterwards perform no weight-quantisation work at all."""
    cfg, params, x = _detector()
    cache = qpm.QuantizedParamsCache(params, cfg)

    n_weights = len(cfg.channels) + 2
    before = qpm.quantize_calls
    qp_int8 = cache.get("int8")
    assert qpm.quantize_calls - before == n_weights  # once per weight tensor
    qp_fxp8 = cache.get("fxp8")
    assert qpm.quantize_calls - before == 2 * n_weights  # once per mode

    for _ in range(3):
        accelerator_forward(qp_int8, x, cfg)
        accelerator_forward(qp_fxp8, x, cfg)
    assert qpm.quantize_calls - before == 2 * n_weights  # zero per call
    assert cache.get("int8") is qp_int8  # memoised artifact, not re-built
