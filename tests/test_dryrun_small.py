"""Launcher plumbing on a small forced-device mesh (subprocess: the 512-device
override must never leak into the test process)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # heavyweight tier: scripts/ci.sh --all

SCRIPT = textwrap.dedent(
    """\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.distributed.sharding import ShardingRules, use_rules, tree_shardings
    from repro.models import transformer as T
    from repro.training.lm import make_train_step, TrainSettings, make_decode_step
    from repro.training.optimizer import Adam, AdamState
    from repro.launch.specs import batch_specs, cache_specs, ShapeSpec

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
    cfg = get_config("{arch}").smoke().replace(n_kv_heads=4, param_dtype="float32", act_dtype="float32")
    rules = ShardingRules(mesh)
    ap, lg = T.abstract_params(cfg), T.logical_axes(cfg)
    ps = tree_shardings(rules, ap, lg)
    params = jax.tree_util.tree_map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), ap, ps)
    shape = ShapeSpec("t", 64, 8, "{kind}")
    out = {{}}
    with mesh, use_rules(rules):
        if "{kind}" == "train":
            bs, bl = batch_specs(cfg, shape)
            batch = {{k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=rules.sharding(bl[k], dims=v.shape)) for k, v in bs.items()}}
            opt = Adam(lr=1e-3)
            mom = lambda: jax.tree_util.tree_map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32, sharding=p.sharding), params)
            ost = AdamState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=mom(), nu=mom())
            fn = make_train_step(cfg, opt, TrainSettings(n_micro=2))
            compiled = jax.jit(fn).lower(params, ost, batch).compile()
        else:
            ac, cl = cache_specs(cfg, shape, model_axis_size=2)
            cs = tree_shardings(rules, ac, cl)
            caches = jax.tree_util.tree_map(lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh), ac, cs)
            tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
            fn = make_decode_step(cfg, max_seq=64)
            compiled = jax.jit(fn, donate_argnums=(2,)).lower(params, tok, caches, jax.ShapeDtypeStruct((), jnp.int32)).compile()
    ma = compiled.memory_analysis()
    out["ok"] = True
    out["temp"] = ma.temp_size_in_bytes
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        # older jax returns [dict] (one entry per computation); newer
        # returns the dict directly — the 5 inherited tier-1 failures here
        # were this .get on a list, not a real lowering problem
        ca = ca[0] if ca else dict()
    out["flops"] = ca.get("flops")
    print("RESULT:" + json.dumps(out))
    """
)


@pytest.mark.parametrize(
    "arch,kind",
    [
        ("gemma-2b", "train"),
        ("olmoe-1b-7b", "train"),
        ("rwkv6-7b", "decode"),
        ("zamba2-7b", "decode"),
        ("gemma3-12b", "decode"),
    ],
)
def test_multidevice_lower_compile(arch, kind):
    """2x2x2 multi-pod mesh: lower + compile the real step functions."""
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(arch=arch, kind=kind)],
        capture_output=True,
        text=True,
        timeout=600,
        cwd=Path(__file__).resolve().parents[1],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    assert out["ok"] and out["flops"] > 0
