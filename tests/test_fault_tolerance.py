"""Fault-tolerance conformance: crash-recoverable engine state, ingest
hardening, and (from the supervisor sections onward) the chaos suite over
the fleet supervisor's deterministic fault-injection harness.

The central contract everywhere in this file is *bitwise*, not approximate:

* kill an engine mid-scene, restore its snapshot into a fresh engine built
  from the same baked artifact, finish the scene — the window scores and
  ``TrackEvent`` lists equal the uninterrupted run exactly;
* inject faults into some streams/workers — every stream not directly hit
  by a data-destroying fault produces event lists identical to the
  fault-free run (per-sample activation scales make rows co-batch
  independent; snapshot/restore and transactional rounds make worker death
  lossless).
"""
import jax
import numpy as np
import pytest

from repro.data import features
from repro.models import cnn1d
from repro.serving.engine import MonitorEngine, SanitizePolicy, StreamRing
from repro.serving.quantized_params import quantize_params

TRACK_KW = dict(ema_alpha=0.7, enter_threshold=0.02, exit_threshold=0.01,
                min_duration=1)


@pytest.fixture(scope="module")
def detector():
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    # Bake once: every engine/worker in this module serves the same frozen
    # artifact, exactly like a supervisor rebuilding dead workers.
    qp = quantize_params(params, cfg, mode="int8")
    return cfg, qp


def _scene_audio(rng, n_streams, n_win):
    return rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)


def _delivery_schedule(rng, audio):
    """Uneven real-world-ish chunk boundaries, precomputed so interrupted
    and uninterrupted runs replay the identical delivery."""
    n_streams, total = audio.shape
    schedule = []  # list of rounds; each round: [(stream, lo, hi), ...]
    cursors = [0] * n_streams
    while any(c < total for c in cursors):
        rnd = []
        for s in range(n_streams):
            if cursors[s] >= total:
                continue
            n = int(rng.uniform(0.3, 1.7) * features.N_SAMPLES)
            rnd.append((s, cursors[s], min(total, cursors[s] + n)))
            cursors[s] += n
        schedule.append(rnd)
    return schedule


def _drive(engine, audio, schedule, *, start_round=0, scores=None):
    """Deliver schedule[start_round:], stepping once per delivery round and
    draining at the end; collects per-stream p_uav lists."""
    scores = {s: [] for s in range(audio.shape[0])} if scores is None else scores
    for rnd in schedule[start_round:]:
        for s, lo, hi in rnd:
            engine.push(s, audio[s, lo:hi])
        for ws in engine.step():
            scores[ws.stream].append(ws.p_uav)
    while True:
        scored = engine.step()
        if not scored:
            break
        for ws in scored:
            scores[ws.stream].append(ws.p_uav)
    return scores


# ---------------------------------------------------------------------------
# Engine snapshot / restore (crash-recoverable state)
# ---------------------------------------------------------------------------


def test_engine_kill_restore_bitwise_equal_to_uninterrupted(detector):
    """The acceptance-criteria conformance test: kill an engine mid-scene,
    restore its snapshot into a fresh engine built from the same baked
    artifact, finish the scene — scores and events bitwise equal the run
    that was never interrupted."""
    cfg, qp = detector
    rng = np.random.default_rng(7)
    n_streams, n_win = 3, 6
    audio = _scene_audio(rng, n_streams, n_win)
    schedule = _delivery_schedule(rng, audio)
    kill_at = len(schedule) // 2
    assert 0 < kill_at < len(schedule)

    def fresh():
        return MonitorEngine(
            qp, cfg, n_streams=n_streams, feature_kind="zcr",
            batch_slots=2, **TRACK_KW,
        )

    ref_engine = fresh()
    ref_scores = _drive(ref_engine, audio, schedule)
    ref_events = ref_engine.finalize()
    assert sum(len(v) for v in ref_scores.values()) == n_streams * n_win
    assert sum(len(e) for e in ref_events) > 0

    # interrupted leg: run the first half, snapshot, "kill" the engine, and
    # revive the snapshot in a brand-new engine
    first = fresh()
    scores = _drive(first, audio, schedule[:kill_at])
    snap = first.snapshot()
    del first  # the crash

    revived = fresh()
    revived.restore(snap)
    scores = _drive(revived, audio, schedule, start_round=kill_at, scores=scores)
    events = revived.finalize()

    for s in range(n_streams):
        np.testing.assert_array_equal(
            np.asarray(scores[s], np.float64), np.asarray(ref_scores[s], np.float64)
        )
        assert events[s] == ref_events[s]
    # counters survive the crash too
    assert revived.windows_scored == ref_engine.windows_scored
    assert revived.dropped_samples == ref_engine.dropped_samples


def test_engine_snapshot_is_isolated_from_live_engine(detector):
    """Snapshot then keep running: later rounds must not mutate the snapshot
    (a supervisor holds last-good snapshots across many rounds)."""
    cfg, qp = detector
    rng = np.random.default_rng(8)
    engine = MonitorEngine(qp, cfg, n_streams=2, feature_kind="zcr", **TRACK_KW)
    audio = _scene_audio(rng, 2, 3)
    for s in range(2):
        engine.push(s, audio[s, : features.N_SAMPLES])
    engine.step()
    snap = engine.snapshot()
    ring_r = [sd["r"] for sd in snap["rings"]]
    ema = snap["tracker"]["_ema"].copy()
    for s in range(2):
        engine.push(s, audio[s, features.N_SAMPLES:])
    engine.drain()
    assert [sd["r"] for sd in snap["rings"]] == ring_r
    np.testing.assert_array_equal(snap["tracker"]["_ema"], ema)


def test_engine_restore_validates_stream_count(detector):
    cfg, qp = detector
    e3 = MonitorEngine(qp, cfg, n_streams=3, feature_kind="zcr")
    e2 = MonitorEngine(qp, cfg, n_streams=2, feature_kind="zcr")
    with pytest.raises(ValueError, match="3 stream"):
        e2.restore(e3.snapshot())


def test_ring_state_dict_validates_geometry():
    sd = StreamRing(window=10, hop=5, capacity_windows=2).state_dict()
    with pytest.raises(ValueError, match="hop"):
        StreamRing(window=10, hop=10, capacity_windows=2).load_state_dict(sd)


# ---------------------------------------------------------------------------
# Ingest hardening (SanitizePolicy)
# ---------------------------------------------------------------------------


def test_sanitize_reject_blocks_nan_poison_and_isolates_streams(detector):
    """A NaN-emitting microphone on stream 0: with the reject policy its
    chunks are refused (counted per stream), its tracker EMA stays finite,
    and stream 1 — fed the identical audio as a clean reference run — stays
    bitwise identical."""
    cfg, qp = detector
    rng = np.random.default_rng(9)
    n_win = 4
    clean = _scene_audio(rng, 2, n_win)

    ref = MonitorEngine(qp, cfg, n_streams=2, feature_kind="zcr", **TRACK_KW)
    for s in range(2):
        ref.push(s, clean[s])
    ref_scores = {s: [] for s in range(2)}
    for ws in ref.drain():
        ref_scores[ws.stream].append(ws.p_uav)
    ref_events = ref.finalize()

    engine = MonitorEngine(
        qp, cfg, n_streams=2, feature_kind="zcr",
        sanitize=SanitizePolicy(nonfinite="reject"), **TRACK_KW,
    )
    poisoned = clean[0].copy()
    poisoned[::50] = np.nan
    engine.push(0, poisoned)  # rejected whole
    engine.push(0, np.full(100, np.inf, np.float32))  # rejected too
    engine.push(1, clean[1])
    scores = {s: [] for s in range(2)}
    for ws in engine.drain():
        scores[ws.stream].append(ws.p_uav)
    events = engine.finalize()

    assert engine.rejected_chunks.tolist() == [2, 0]
    assert scores[0] == []  # nothing reached stream 0's ring
    assert np.isfinite(engine.tracker.smoothed).all()
    np.testing.assert_array_equal(
        np.asarray(scores[1], np.float64), np.asarray(ref_scores[1], np.float64)
    )
    assert events[1] == ref_events[1]


def test_sanitize_zero_mode_keeps_alignment(detector):
    """zero mode: poisoned samples are zeroed in place, chunk length (and so
    window alignment) is preserved, and the per-stream zeroed counter says
    exactly how many samples were scrubbed."""
    cfg, qp = detector
    engine = MonitorEngine(
        qp, cfg, n_streams=1, feature_kind="zcr",
        sanitize=SanitizePolicy(nonfinite="zero"),
    )
    chunk = np.ones(features.N_SAMPLES, np.float32)
    chunk[:7] = np.nan
    chunk[10:13] = -np.inf
    engine.push(0, chunk)
    assert engine.zeroed_samples.tolist() == [10]
    assert engine.rejected_chunks.tolist() == [0]
    ring = engine._rings[0]
    assert ring.buffered == features.N_SAMPLES  # full chunk kept
    w = ring.peek_window()
    assert np.isfinite(w).all()
    np.testing.assert_array_equal(w[:7], np.zeros(7))
    np.testing.assert_array_equal(w[13:], np.ones(features.N_SAMPLES - 13))


def test_sanitize_clip_detection_counts_and_rejects():
    policy_count = SanitizePolicy(clip_level=1.0, max_clip_fraction=0.1)
    loud = np.ones(100, np.float32)  # 100% at full scale
    soft = np.full(100, 0.5, np.float32)
    kept, rep = policy_count.apply(loud)
    assert rep.clipped and not rep.rejected and kept is not None
    kept, rep = policy_count.apply(soft)
    assert not rep.clipped and kept is not None

    policy_reject = SanitizePolicy(
        clip_level=1.0, max_clip_fraction=0.1, clipped_action="reject"
    )
    kept, rep = policy_reject.apply(loud)
    assert rep.rejected and rep.reason == "clipped" and kept is None


def test_sanitize_policy_validates_knobs():
    for bad in (
        dict(nonfinite="drop"),
        dict(clipped_action="zero"),
        dict(clip_level=0.0),
        dict(max_clip_fraction=1.5),
    ):
        with pytest.raises(ValueError):
            SanitizePolicy(**bad)


def test_without_sanitize_nan_poisons_only_its_own_stream():
    """The hazard the policy exists for, and the blast-radius guarantee that
    bounds it: with no sanitize policy a NaN chunk does poison its stream's
    EMA forever — but per-sample activation scales keep every co-batched
    clean stream bitwise intact.  (psd features: a NaN sample propagates
    through the FFT into the whole feature row — unlike zcr, whose
    sign-comparison math silently launders NaN into zeros.)"""
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["psd"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(3), cfg)
    qp = quantize_params(params, cfg, mode="int8")
    rng = np.random.default_rng(10)
    clean = _scene_audio(rng, 2, 2)

    ref = MonitorEngine(qp, cfg, n_streams=2, feature_kind="psd", **TRACK_KW)
    for s in range(2):
        ref.push(s, clean[s])
    ref_scores = {s: [] for s in range(2)}
    for ws in ref.drain():
        ref_scores[ws.stream].append(ws.p_uav)

    engine = MonitorEngine(qp, cfg, n_streams=2, feature_kind="psd", **TRACK_KW)
    poisoned = clean[0].copy()
    poisoned[1000] = np.nan
    engine.push(0, poisoned)
    engine.push(1, clean[1])
    scores = {s: [] for s in range(2)}
    for ws in engine.drain():
        scores[ws.stream].append(ws.p_uav)

    assert np.isnan(engine.tracker.smoothed[0])  # the un-hardened hazard
    np.testing.assert_array_equal(  # the blast radius: one row, one stream
        np.asarray(scores[1], np.float64), np.asarray(ref_scores[1], np.float64)
    )
