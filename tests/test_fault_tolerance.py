"""Fault-tolerance conformance: crash-recoverable engine state, ingest
hardening, and (from the supervisor sections onward) the chaos suite over
the fleet supervisor's deterministic fault-injection harness.

The central contract everywhere in this file is *bitwise*, not approximate:

* kill an engine mid-scene, restore its snapshot into a fresh engine built
  from the same baked artifact, finish the scene — the window scores and
  ``TrackEvent`` lists equal the uninterrupted run exactly;
* inject faults into some streams/workers — every stream not directly hit
  by a data-destroying fault produces event lists identical to the
  fault-free run (per-sample activation scales make rows co-batch
  independent; snapshot/restore and transactional rounds make worker death
  lossless).
"""
import jax
import numpy as np
import pytest

from repro.data import features
from repro.models import cnn1d
from repro.serving.engine import MonitorEngine, SanitizePolicy, StreamRing
from repro.serving.faults import Fault, FaultClock, FaultPlan
from repro.serving.quantized_params import quantize_params
from repro.serving.supervisor import FleetSupervisor

TRACK_KW = dict(ema_alpha=0.7, enter_threshold=0.02, exit_threshold=0.01,
                min_duration=1)


@pytest.fixture(scope="module")
def detector():
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    # Bake once: every engine/worker in this module serves the same frozen
    # artifact, exactly like a supervisor rebuilding dead workers.
    qp = quantize_params(params, cfg, mode="int8")
    return cfg, qp


def _scene_audio(rng, n_streams, n_win):
    return rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)


def _delivery_schedule(rng, audio):
    """Uneven real-world-ish chunk boundaries, precomputed so interrupted
    and uninterrupted runs replay the identical delivery."""
    n_streams, total = audio.shape
    schedule = []  # list of rounds; each round: [(stream, lo, hi), ...]
    cursors = [0] * n_streams
    while any(c < total for c in cursors):
        rnd = []
        for s in range(n_streams):
            if cursors[s] >= total:
                continue
            n = int(rng.uniform(0.3, 1.7) * features.N_SAMPLES)
            rnd.append((s, cursors[s], min(total, cursors[s] + n)))
            cursors[s] += n
        schedule.append(rnd)
    return schedule


def _drive(engine, audio, schedule, *, start_round=0, scores=None):
    """Deliver schedule[start_round:], stepping once per delivery round and
    draining at the end; collects per-stream p_uav lists."""
    scores = {s: [] for s in range(audio.shape[0])} if scores is None else scores
    for rnd in schedule[start_round:]:
        for s, lo, hi in rnd:
            engine.push(s, audio[s, lo:hi])
        for ws in engine.step():
            scores[ws.stream].append(ws.p_uav)
    while True:
        scored = engine.step()
        if not scored:
            break
        for ws in scored:
            scores[ws.stream].append(ws.p_uav)
    return scores


# ---------------------------------------------------------------------------
# Engine snapshot / restore (crash-recoverable state)
# ---------------------------------------------------------------------------


def test_engine_kill_restore_bitwise_equal_to_uninterrupted(detector):
    """The acceptance-criteria conformance test: kill an engine mid-scene,
    restore its snapshot into a fresh engine built from the same baked
    artifact, finish the scene — scores and events bitwise equal the run
    that was never interrupted."""
    cfg, qp = detector
    rng = np.random.default_rng(7)
    n_streams, n_win = 3, 6
    audio = _scene_audio(rng, n_streams, n_win)
    schedule = _delivery_schedule(rng, audio)
    kill_at = len(schedule) // 2
    assert 0 < kill_at < len(schedule)

    def fresh():
        return MonitorEngine(
            qp, cfg, n_streams=n_streams, feature_kind="zcr",
            batch_slots=2, **TRACK_KW,
        )

    ref_engine = fresh()
    ref_scores = _drive(ref_engine, audio, schedule)
    ref_events = ref_engine.finalize()
    assert sum(len(v) for v in ref_scores.values()) == n_streams * n_win
    assert sum(len(e) for e in ref_events) > 0

    # interrupted leg: run the first half, snapshot, "kill" the engine, and
    # revive the snapshot in a brand-new engine
    first = fresh()
    scores = _drive(first, audio, schedule[:kill_at])
    snap = first.snapshot()
    del first  # the crash

    revived = fresh()
    revived.restore(snap)
    scores = _drive(revived, audio, schedule, start_round=kill_at, scores=scores)
    events = revived.finalize()

    for s in range(n_streams):
        np.testing.assert_array_equal(
            np.asarray(scores[s], np.float64), np.asarray(ref_scores[s], np.float64)
        )
        assert events[s] == ref_events[s]
    # counters survive the crash too
    assert revived.windows_scored == ref_engine.windows_scored
    assert revived.dropped_samples == ref_engine.dropped_samples


def test_engine_snapshot_is_isolated_from_live_engine(detector):
    """Snapshot then keep running: later rounds must not mutate the snapshot
    (a supervisor holds last-good snapshots across many rounds)."""
    cfg, qp = detector
    rng = np.random.default_rng(8)
    engine = MonitorEngine(qp, cfg, n_streams=2, feature_kind="zcr", **TRACK_KW)
    audio = _scene_audio(rng, 2, 3)
    for s in range(2):
        engine.push(s, audio[s, : features.N_SAMPLES])
    engine.step()
    snap = engine.snapshot()
    ring_r = [sd["r"] for sd in snap["rings"]]
    ema = snap["tracker"]["_ema"].copy()
    for s in range(2):
        engine.push(s, audio[s, features.N_SAMPLES:])
    engine.drain()
    assert [sd["r"] for sd in snap["rings"]] == ring_r
    np.testing.assert_array_equal(snap["tracker"]["_ema"], ema)


def test_engine_restore_validates_stream_count(detector):
    cfg, qp = detector
    e3 = MonitorEngine(qp, cfg, n_streams=3, feature_kind="zcr")
    e2 = MonitorEngine(qp, cfg, n_streams=2, feature_kind="zcr")
    with pytest.raises(ValueError, match="3 stream"):
        e2.restore(e3.snapshot())


def _assert_snapshots_equal(a: dict, b: dict):
    """Deep bitwise equality over EVERY snapshot field — rings, tracker
    arrays and events, all counters, pending evictions."""
    assert a.keys() == b.keys()
    assert a["pending_evictions"] == b["pending_evictions"]
    assert len(a["rings"]) == len(b["rings"])
    for ra, rb in zip(a["rings"], b["rings"]):
        assert ra.keys() == rb.keys()
        for k in ra:
            np.testing.assert_array_equal(ra[k], rb[k], err_msg=f"rings.{k}")
    for part in ("tracker", "counters"):
        assert a[part].keys() == b[part].keys()
        for k in a[part]:
            if k == "events":
                assert a[part][k] == b[part][k]
            else:
                np.testing.assert_array_equal(
                    a[part][k], b[part][k], err_msg=f"{part}.{k}"
                )


def test_engine_snapshot_restore_roundtrips_every_field(detector):
    """Regression (the pending-evictions snapshot bug): ``snapshot()``
    omitted ``_pending_evictions`` and ``restore()`` reset it to ``[]``, so
    a revive from a snapshot taken between a stream's de-admission and the
    supervisor's ``take_evictions()`` left the stream de-admitted but never
    evicted.  The conformance is now total: a restored engine's snapshot
    deep-equals the original over every field, including a live pending
    eviction, which the revived engine still hands to its supervisor."""
    from repro.serving.batching import AdmissionPolicy

    cfg, qp = detector
    rng = np.random.default_rng(41)
    W = features.N_SAMPLES
    kw = dict(
        feature_kind="zcr", batch_slots=2, capacity_windows=1,
        sanitize=SanitizePolicy(nonfinite="reject"),
        admission=AdmissionPolicy(evict_overflow_rounds=1), **TRACK_KW,
    )
    engine = MonitorEngine(qp, cfg, n_streams=2, **kw)
    engine.push(0, rng.standard_normal(2 * W).astype(np.float32))  # overflow
    engine.push(1, rng.standard_normal(W).astype(np.float32))
    engine.step()  # stream 0 de-admitted, eviction pending, NOT collected

    snap = engine.snapshot()
    assert snap["pending_evictions"] == [0]  # the field the bug dropped

    revived = MonitorEngine(qp, cfg, n_streams=2, **kw)
    revived.restore(snap)
    _assert_snapshots_equal(revived.snapshot(), snap)
    # the revived engine still surfaces the eviction to its supervisor
    assert revived.take_evictions() == [0]
    assert engine.take_evictions() == [0]

    # drained state round-trips too (pending_evictions now empty)
    snap2 = engine.snapshot()
    assert snap2["pending_evictions"] == []
    again = MonitorEngine(qp, cfg, n_streams=2, **kw)
    again.restore(snap2)
    _assert_snapshots_equal(again.snapshot(), snap2)


def test_ring_state_dict_validates_geometry():
    sd = StreamRing(window=10, hop=5, capacity_windows=2).state_dict()
    with pytest.raises(ValueError, match="hop"):
        StreamRing(window=10, hop=10, capacity_windows=2).load_state_dict(sd)


# ---------------------------------------------------------------------------
# Ingest hardening (SanitizePolicy)
# ---------------------------------------------------------------------------


def test_sanitize_reject_blocks_nan_poison_and_isolates_streams(detector):
    """A NaN-emitting microphone on stream 0: with the reject policy its
    chunks are refused (counted per stream), its tracker EMA stays finite,
    and stream 1 — fed the identical audio as a clean reference run — stays
    bitwise identical."""
    cfg, qp = detector
    rng = np.random.default_rng(9)
    n_win = 4
    clean = _scene_audio(rng, 2, n_win)

    ref = MonitorEngine(qp, cfg, n_streams=2, feature_kind="zcr", **TRACK_KW)
    for s in range(2):
        ref.push(s, clean[s])
    ref_scores = {s: [] for s in range(2)}
    for ws in ref.drain():
        ref_scores[ws.stream].append(ws.p_uav)
    ref_events = ref.finalize()

    engine = MonitorEngine(
        qp, cfg, n_streams=2, feature_kind="zcr",
        sanitize=SanitizePolicy(nonfinite="reject"), **TRACK_KW,
    )
    poisoned = clean[0].copy()
    poisoned[::50] = np.nan
    engine.push(0, poisoned)  # rejected whole
    engine.push(0, np.full(100, np.inf, np.float32))  # rejected too
    engine.push(1, clean[1])
    scores = {s: [] for s in range(2)}
    for ws in engine.drain():
        scores[ws.stream].append(ws.p_uav)
    events = engine.finalize()

    assert engine.rejected_chunks.tolist() == [2, 0]
    assert scores[0] == []  # nothing reached stream 0's ring
    assert np.isfinite(engine.tracker.smoothed).all()
    np.testing.assert_array_equal(
        np.asarray(scores[1], np.float64), np.asarray(ref_scores[1], np.float64)
    )
    assert events[1] == ref_events[1]


def test_sanitize_zero_mode_keeps_alignment(detector):
    """zero mode: poisoned samples are zeroed in place, chunk length (and so
    window alignment) is preserved, and the per-stream zeroed counter says
    exactly how many samples were scrubbed."""
    cfg, qp = detector
    engine = MonitorEngine(
        qp, cfg, n_streams=1, feature_kind="zcr",
        sanitize=SanitizePolicy(nonfinite="zero"),
    )
    chunk = np.ones(features.N_SAMPLES, np.float32)
    chunk[:7] = np.nan
    chunk[10:13] = -np.inf
    engine.push(0, chunk)
    assert engine.zeroed_samples.tolist() == [10]
    assert engine.rejected_chunks.tolist() == [0]
    ring = engine._rings[0]
    assert ring.buffered == features.N_SAMPLES  # full chunk kept
    w = ring.peek_window()
    assert np.isfinite(w).all()
    np.testing.assert_array_equal(w[:7], np.zeros(7))
    np.testing.assert_array_equal(w[13:], np.ones(features.N_SAMPLES - 13))


def test_sanitize_clip_detection_counts_and_rejects():
    policy_count = SanitizePolicy(clip_level=1.0, max_clip_fraction=0.1)
    loud = np.ones(100, np.float32)  # 100% at full scale
    soft = np.full(100, 0.5, np.float32)
    kept, rep = policy_count.apply(loud)
    assert rep.clipped and not rep.rejected and kept is not None
    kept, rep = policy_count.apply(soft)
    assert not rep.clipped and kept is not None

    policy_reject = SanitizePolicy(
        clip_level=1.0, max_clip_fraction=0.1, clipped_action="reject"
    )
    kept, rep = policy_reject.apply(loud)
    assert rep.rejected and rep.reason == "clipped" and kept is None


def test_sanitize_policy_validates_knobs():
    for bad in (
        dict(nonfinite="drop"),
        dict(clipped_action="zero"),
        dict(clip_level=0.0),
        dict(max_clip_fraction=1.5),
    ):
        with pytest.raises(ValueError):
            SanitizePolicy(**bad)


def test_without_sanitize_nan_poisons_only_its_own_stream():
    """The hazard the policy exists for, and the blast-radius guarantee that
    bounds it: with no sanitize policy a NaN chunk does poison its stream's
    EMA forever — but per-sample activation scales keep every co-batched
    clean stream bitwise intact.  (psd features: a NaN sample propagates
    through the FFT into the whole feature row — unlike zcr, whose
    sign-comparison math silently launders NaN into zeros.)"""
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["psd"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(3), cfg)
    qp = quantize_params(params, cfg, mode="int8")
    rng = np.random.default_rng(10)
    clean = _scene_audio(rng, 2, 2)

    ref = MonitorEngine(qp, cfg, n_streams=2, feature_kind="psd", **TRACK_KW)
    for s in range(2):
        ref.push(s, clean[s])
    ref_scores = {s: [] for s in range(2)}
    for ws in ref.drain():
        ref_scores[ws.stream].append(ws.p_uav)

    engine = MonitorEngine(qp, cfg, n_streams=2, feature_kind="psd", **TRACK_KW)
    poisoned = clean[0].copy()
    poisoned[1000] = np.nan
    engine.push(0, poisoned)
    engine.push(1, clean[1])
    scores = {s: [] for s in range(2)}
    for ws in engine.drain():
        scores[ws.stream].append(ws.p_uav)

    assert np.isnan(engine.tracker.smoothed[0])  # the un-hardened hazard
    np.testing.assert_array_equal(  # the blast radius: one row, one stream
        np.asarray(scores[1], np.float64), np.asarray(ref_scores[1], np.float64)
    )


# ---------------------------------------------------------------------------
# Fleet supervisor + deterministic fault injection (the chaos suite)
# ---------------------------------------------------------------------------

SUP_KW = dict(feature_kind="zcr", batch_slots=2,
              sanitize=SanitizePolicy(nonfinite="reject"), **TRACK_KW)


def _fleet(detector, n_streams, n_workers, **kw):
    cfg, qp = detector
    return FleetSupervisor(
        qp, cfg, n_streams=n_streams, n_workers=n_workers,
        clock=FaultClock(), dispatch_deadline_s=1.0, **SUP_KW, **kw,
    )


@pytest.fixture(scope="module")
def fleet_scene(detector):
    """One shared scene + fault-free supervisor baseline for the whole chaos
    section: 4 streams, uneven delivery, scores and events to compare every
    faulted run against."""
    rng = np.random.default_rng(21)
    audio = _scene_audio(rng, 4, 5)
    schedule = _delivery_schedule(rng, audio)
    sup = _fleet(detector, 4, 2)
    scores = _drive(sup, audio, schedule)
    events = sup.finalize()
    assert sum(len(e) for e in events) > 0
    return audio, schedule, scores, events


def _assert_streams_bitwise(scores, events, ref_scores, ref_events, streams):
    for s in streams:
        np.testing.assert_array_equal(
            np.asarray(scores[s], np.float64),
            np.asarray(ref_scores[s], np.float64),
            err_msg=f"stream {s} scores diverged",
        )
        assert events[s] == ref_events[s], f"stream {s} events diverged"


def test_fleet_without_faults_matches_single_engine(detector, fleet_scene):
    """Conformance: partitioning streams over a worker pool is numerically
    invisible — the fleet's per-stream scores and events equal one monolithic
    engine serving all streams, bitwise, for every pool size."""
    cfg, qp = detector
    audio, schedule, sup_scores, sup_events = fleet_scene
    mono = MonitorEngine(qp, cfg, n_streams=4, **SUP_KW)
    ref_scores = _drive(mono, audio, schedule)
    ref_events = mono.finalize()
    _assert_streams_bitwise(sup_scores, sup_events, ref_scores, ref_events,
                            range(4))
    sup4 = _fleet(detector, 4, 4)  # one worker per stream
    scores4 = _drive(sup4, audio, schedule)
    _assert_streams_bitwise(scores4, sup4.finalize(), ref_scores, ref_events,
                            range(4))


def test_lossy_chunk_faults_isolate_target_streams(detector, fleet_scene):
    """Dropped and corrupted chunks hurt exactly their target stream: every
    other stream — including the target's co-batched neighbour on the same
    worker — stays bitwise identical to the fault-free run."""
    audio, schedule, ref_scores, ref_events = fleet_scene
    plan = FaultPlan([
        Fault("drop_chunk", round=1, stream=0),
        Fault("corrupt_chunk", round=2, stream=3),
    ])
    assert plan.affected_streams == {0, 3}
    sup = _fleet(detector, 4, 2, faults=plan)
    scores = _drive(sup, audio, schedule)
    events = sup.finalize()
    _assert_streams_bitwise(scores, events, ref_scores, ref_events, {1, 2})
    assert sup.faulted_chunks.tolist() == [1, 0, 0, 1]
    # the corrupt chunk was NaN-poisoned and the reject policy refused it
    assert sup.workers[sup._route[3][0]].engine.rejected_chunks[
        sup._route[3][1]] == 1
    # the damage is real: the target streams scored fewer windows
    assert len(scores[0]) < len(ref_scores[0])


def test_jitter_resegmentation_is_bitwise_invisible(detector, fleet_scene):
    """Jitter re-segments a chunk into two pushes with identical content —
    ALL streams, including the jittered one, must match the fault-free run
    bitwise (the ring's hop alignment doesn't care about chunk boundaries)."""
    audio, schedule, ref_scores, ref_events = fleet_scene
    plan = FaultPlan([
        Fault("jitter_chunk", round=0, stream=1, magnitude=0.4),
        Fault("jitter_chunk", round=3, stream=2, magnitude=0.7),
    ])
    assert plan.affected_streams == set()
    sup = _fleet(detector, 4, 2, faults=plan)
    scores = _drive(sup, audio, schedule)
    _assert_streams_bitwise(scores, sup.finalize(), ref_scores, ref_events,
                            range(4))
    assert sup.faulted_chunks.sum() == 2


def test_worker_crash_stall_kill_are_lossless(detector, fleet_scene):
    """The tentpole chaos contract: a crashing forward, a stalled forward
    (detected via the dispatch deadline on the injected clock) and a killed
    worker all recover losslessly — every stream of every worker bitwise
    matches the fault-free run, and the incident log classifies each fault
    correctly."""
    audio, schedule, ref_scores, ref_events = fleet_scene
    plan = FaultPlan([
        Fault("raise_forward", round=1, worker=0),
        Fault("stall_forward", round=2, worker=1, magnitude=5.0),
        Fault("kill_worker", round=3, worker=0),
    ])
    sup = _fleet(detector, 4, 2, faults=plan)
    scores = _drive(sup, audio, schedule)
    events = sup.finalize()
    _assert_streams_bitwise(scores, events, ref_scores, ref_events, range(4))
    assert [i["kind"] for i in sup.incidents] == ["crash", "stall", "kill"]
    assert [i["worker"] for i in sup.incidents] == [0, 1, 0]
    assert sup.workers[0].rebuilds == 2 and sup.workers[1].rebuilds == 1
    assert all(w.alive for w in sup.workers)


def test_back_to_back_worker_failures_never_escape_step(detector, fleet_scene):
    """Regression (the post-revive retry bug): a transient fault whose
    magnitude makes the recovery re-run fail *again* — back-to-back
    failures inside one round — must be absorbed by the same revive path,
    not escape ``step()``.  Before the fix the retry ran outside the
    try/except, so the second consecutive raise crashed the supervisor."""
    audio, schedule, ref_scores, ref_events = fleet_scene
    plan = FaultPlan([
        # first attempt AND the recovery re-run both raise; third succeeds
        Fault("raise_forward", round=1, worker=0, magnitude=2),
    ])
    sup = _fleet(detector, 4, 2, faults=plan)
    scores = _drive(sup, audio, schedule)  # the bug made this raise
    events = sup.finalize()
    _assert_streams_bitwise(scores, events, ref_scores, ref_events, range(4))
    assert [i["kind"] for i in sup.incidents] == ["crash", "crash"]
    assert [i["round"] for i in sup.incidents] == [1, 1]
    assert sup.workers[0].rebuilds == 2
    assert all(w.alive for w in sup.workers)


def test_transient_fault_outliving_rebuild_budget_retires_losslessly(
        detector, fleet_scene):
    """The bounded end of the retry loop: a fault that outlives
    ``max_rebuilds`` consecutive re-runs tips the worker into retirement —
    its streams migrate to the survivor mid-scene with zero loss and the
    fault still never escapes ``step()``."""
    audio, schedule, ref_scores, ref_events = fleet_scene
    plan = FaultPlan([
        Fault("raise_forward", round=1, worker=0, magnitude=5),
    ])
    sup = _fleet(detector, 4, 2, max_rebuilds=1, faults=plan)
    scores = _drive(sup, audio, schedule)
    events = sup.finalize()
    _assert_streams_bitwise(scores, events, ref_scores, ref_events, range(4))
    assert [i["kind"] for i in sup.incidents] == ["crash", "crash", "reassign"]
    assert not sup.workers[0].alive
    assert sup.workers[1].streams == [2, 3, 0, 1]


def test_reassignment_after_repeated_kills_is_lossless(detector, fleet_scene):
    """A worker that dies more than max_rebuilds times is retired and its
    streams migrate — with their full state — to the survivor.  The merged
    worker's output stays bitwise identical for ALL streams, routing follows
    the streams, and health reports the retirement."""
    audio, schedule, ref_scores, ref_events = fleet_scene
    plan = FaultPlan([
        Fault("kill_worker", round=1, worker=0),
        Fault("kill_worker", round=2, worker=0),
    ])
    sup = _fleet(detector, 4, 2, max_rebuilds=1, faults=plan)
    scores = _drive(sup, audio, schedule)
    events = sup.finalize()
    _assert_streams_bitwise(scores, events, ref_scores, ref_events, range(4))
    assert not sup.workers[0].alive
    assert sup.workers[1].streams == [2, 3, 0, 1]
    assert sup._route[0] == (1, 2) and sup._route[1] == (1, 3)
    kinds = [i["kind"] for i in sup.incidents]
    assert kinds == ["kill", "kill", "reassign"]
    health = sup.health()
    assert health[0]["alive"] is False and health[0]["streams"] == []
    assert health[1]["streams"] == [2, 3, 0, 1]


def test_generated_plans_complete_and_isolate(detector, fleet_scene):
    """Seeded random plans (the chaos sweep): whatever the mix of faults,
    the supervisor finishes the scene without raising and every stream not
    hit by a lossy fault is bitwise identical to the fault-free run."""
    audio, schedule, ref_scores, ref_events = fleet_scene
    for seed in (0, 1, 2):
        plan = FaultPlan.generate(
            seed, n_streams=4, n_workers=2, n_rounds=len(schedule), n_faults=5
        )
        sup = _fleet(detector, 4, 2, faults=plan)
        scores = _drive(sup, audio, schedule)
        events = sup.finalize()
        clean = set(range(4)) - plan.affected_streams
        _assert_streams_bitwise(scores, events, ref_scores, ref_events, clean)
        assert len(sup.health()) == 2


def test_fault_plan_determinism_and_json_roundtrip(tmp_path):
    p1 = FaultPlan.generate(42, n_streams=8, n_workers=2, n_rounds=30)
    p2 = FaultPlan.generate(42, n_streams=8, n_workers=2, n_rounds=30)
    assert p1.faults == p2.faults
    p3 = FaultPlan.from_json(p1.to_json())
    assert p3.faults == p1.faults and p3.seed == 42
    # the CLI writes a plan the supervisor can load
    from repro.serving import faults as faults_mod
    out = tmp_path / "plan.json"
    faults_mod.main(["--seed", "7", "--streams", "4", "--workers", "2",
                     "--rounds", "10", "--out", str(out)])
    plan = FaultPlan.from_json(out.read_text())
    assert plan.seed == 7 and len(plan.faults) > 0


def test_fault_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("explode", 0, stream=1)
    with pytest.raises(ValueError, match="target stream"):
        Fault("drop_chunk", 0)
    with pytest.raises(ValueError, match="target worker"):
        Fault("kill_worker", 0)


def test_supervisor_health_heartbeat_and_validation(detector):
    cfg, qp = detector
    clock = FaultClock(tick=0.25)
    sup = FleetSupervisor(
        qp, cfg, n_streams=2, n_workers=2, clock=clock, **SUP_KW
    )
    assert all(h["heartbeat_age_s"] is None for h in sup.health())
    sup.push(0, np.zeros(features.N_SAMPLES, np.float32))
    sup.step()
    h = sup.health()
    assert h[0]["rounds"] == 1 and h[1]["rounds"] == 0  # only stream 0 scored
    assert all(hh["heartbeat_age_s"] is not None and hh["heartbeat_age_s"] >= 0
               for hh in h)
    with pytest.raises(ValueError, match="out of range"):
        sup.push(5, np.zeros(4, np.float32))

    params = cnn1d.init_params(jax.random.PRNGKey(1), cfg)
    with pytest.raises(ValueError, match="pre-baked"):
        FleetSupervisor(params, cfg, n_streams=2, **SUP_KW)
    with pytest.raises(ValueError, match="n_workers"):
        FleetSupervisor(qp, cfg, n_streams=2, n_workers=3, **SUP_KW)
    with pytest.raises(ValueError, match="dispatch_deadline_s"):
        FleetSupervisor(qp, cfg, n_streams=2, dispatch_deadline_s=0, **SUP_KW)


# ---------------------------------------------------------------------------
# Stream admission / overflow eviction through the supervisor
# ---------------------------------------------------------------------------


def test_supervisor_evicts_persistently_overflowing_stream(detector):
    """A stream that overflows its ring in evict_overflow_rounds consecutive
    rounds is evicted through the supervisor: its worker is rebuilt without
    it (the reassignment machinery in reverse), further pushes are refused,
    its closed events survive finalize(), and every surviving stream stays
    bitwise identical to a monolithic engine that never evicted anyone."""
    from repro.serving.batching import AdmissionPolicy

    cfg, qp = detector
    rng = np.random.default_rng(33)
    n_win = 6
    audio = _scene_audio(rng, 4, n_win)
    W = features.N_SAMPLES

    def deliver(engine, r):
        # stream 0 firehoses 2 windows/round into a 1-window ring (overflows
        # every round); streams 1-3 are well-behaved
        engine.push(0, audio[0, : 2 * W])
        for s in (1, 2, 3):
            engine.push(s, audio[s, r * W : (r + 1) * W])

    def run(engine):
        scores = {s: [] for s in range(4)}
        for r in range(n_win):
            deliver(engine, r)
            for ws in engine.step():
                scores[ws.stream].append(ws.p_uav)
        return scores

    sup = _fleet(
        detector, 4, 2, capacity_windows=1,
        admission=AdmissionPolicy(evict_overflow_rounds=2),
    )
    scores = run(sup)
    events = sup.finalize()

    assert [i["kind"] for i in sup.incidents] == ["evict"]
    assert "[0]" in sup.incidents[0]["detail"]
    assert sup.evicted == {0}
    assert sup.workers[0].streams == [1]  # rebuilt without the firehose
    assert sup._route[1] == (0, 0) and 0 not in sup._route
    # pushes after eviction were refused, not raised, and counted
    assert sup.refused_chunks[0] == n_win - 2
    assert len(scores[0]) == 2  # only the pre-eviction rounds scored

    mono = MonitorEngine(qp, cfg, n_streams=4, capacity_windows=1, **SUP_KW)
    ref_scores = run(mono)
    ref_events = mono.finalize()
    _assert_streams_bitwise(scores, events, ref_scores, ref_events, (1, 2, 3))


def test_supervisor_eviction_can_retire_whole_worker(detector):
    """Evicting every stream of a worker retires the worker cleanly."""
    from repro.serving.batching import AdmissionPolicy

    cfg, qp = detector
    rng = np.random.default_rng(35)
    W = features.N_SAMPLES
    sup = _fleet(
        detector, 2, 2, capacity_windows=1,
        admission=AdmissionPolicy(evict_overflow_rounds=1),
    )
    for _ in range(2):
        sup.push(0, rng.standard_normal(2 * W).astype(np.float32))
        sup.push(1, rng.standard_normal(W).astype(np.float32))
        sup.step()
    assert sup.evicted == {0}
    assert not sup.workers[0].alive and sup.workers[0].streams == []
    # the surviving worker keeps serving
    sup.push(1, rng.standard_normal(W).astype(np.float32))
    assert [ws.stream for ws in sup.step()] == [1]


def test_evicted_streams_keep_final_counter_totals(detector):
    """Regression (the per-stream gather bug): ``served_windows`` /
    ``deferred_windows`` promised that evicted streams keep their final
    totals, but the gather only read live workers' current streams — an
    evicted stream silently reported 0.  The totals are now stashed at
    eviction (like the event lists) and folded into the gather, matching a
    monolithic engine that de-admitted the same stream."""
    from repro.serving.batching import AdmissionPolicy

    cfg, qp = detector
    rng = np.random.default_rng(34)
    n_win = 6
    audio = _scene_audio(rng, 4, n_win)
    W = features.N_SAMPLES

    def run(engine):
        for r in range(n_win):
            engine.push(0, audio[0, : 2 * W])  # overflows every round
            for s in (1, 2, 3):
                engine.push(s, audio[s, r * W : (r + 1) * W])
            engine.step()

    kw = dict(capacity_windows=1,
              admission=AdmissionPolicy(evict_overflow_rounds=2))
    sup = _fleet(detector, 4, 2, **kw)
    run(sup)
    assert sup.evicted == {0}
    mono = MonitorEngine(qp, cfg, n_streams=4, **kw, **SUP_KW)
    run(mono)
    # the evicted stream's pre-eviction totals survive (the bug zeroed them)
    assert sup.served_windows[0] == mono.served_windows[0] > 0
    np.testing.assert_array_equal(sup.served_windows, mono.served_windows)
    np.testing.assert_array_equal(sup.deferred_windows, mono.deferred_windows)


def test_whole_worker_retirement_keeps_stream_totals(detector):
    """The same gather contract across whole-worker death: evicting a
    worker's last stream retires the worker, and the dead worker's streams
    still report their final served totals."""
    from repro.serving.batching import AdmissionPolicy

    rng = np.random.default_rng(36)
    W = features.N_SAMPLES
    sup = _fleet(
        detector, 2, 2, capacity_windows=1,
        admission=AdmissionPolicy(evict_overflow_rounds=1),
    )
    for _ in range(2):
        sup.push(0, rng.standard_normal(2 * W).astype(np.float32))
        sup.push(1, rng.standard_normal(W).astype(np.float32))
        sup.step()
    assert not sup.workers[0].alive  # stream 0 was its only stream
    assert sup.served_windows[0] == 1  # the pre-eviction round still counts
    assert sup.served_windows[1] == 2


def test_fleet_admission_cap_refuses_late_streams(detector):
    """max_streams is a fleet-level first-come cap: late streams' chunks are
    refused and counted at the supervisor, never delivered to a worker."""
    from repro.serving.batching import AdmissionPolicy

    rng = np.random.default_rng(37)
    W = features.N_SAMPLES
    sup = _fleet(detector, 4, 2, admission=AdmissionPolicy(max_streams=2))
    win = lambda: rng.standard_normal(W).astype(np.float32)
    assert sup.push(0, win()) == 0 and sup.push(3, win()) == 0  # admitted
    assert sup.push(1, win()) == 0 and sup.push(2, win()) == 0  # refused
    assert sorted(ws.stream for ws in sup.step()) == [0, 3]
    np.testing.assert_array_equal(sup.refused_chunks, [0, 1, 1, 0])
    # refusal is sticky; an unknown stream still raises
    sup.push(1, win())
    assert sup.refused_chunks[1] == 2
    with pytest.raises(ValueError, match="out of range"):
        sup.push(7, win())
