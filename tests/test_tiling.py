"""VMEM-budget tile selection: legality properties of the selector itself,
and the load-bearing numerical contract — block-shape choice NEVER changes
the int32 accumulator bits of any kernel.

The invariance legs run every kernel at >= 3 distinct tile selections
(driven both by explicit non-128-multiple overrides and by shrinking the
declared VMEM budget until the selector picks different geometry) and
assert bitwise-equal accumulators / outputs.  This is what makes the
budget-driven defaults safe to ship under the serving stack: retuning the
budget for a different part is a pure perf knob, not a numerics change.

Runs under real ``hypothesis`` when installed, else the deterministic
fallback shim (tests/_hypothesis_fallback.py).
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - bare container
    from _hypothesis_fallback import given, settings, st

from repro.core.quantization import int8_symmetric
from repro.kernels import ops, tiling
from repro.kernels.conv1d_fused import conv1d_fused_q
from repro.kernels.cordic_act import cordic_activation
from repro.kernels.quant_matmul import quant_matmul

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# Selector legality
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    st.integers(1, 3000),
    st.integers(1, 3000),
    st.integers(1, 3000),
    st.sampled_from([256 << 10, 1 << 20, 4 << 20, tiling.DEFAULT_VMEM_BUDGET]),
)
def test_matmul_selector_fits_budget_and_granules(m, k, n, budget):
    t = tiling.select_matmul_tiles(m, k, n, budget=budget, has_bias=True)
    assert t.bm % tiling.SUBLANE_INT8 == 0
    assert t.bn % tiling.LANE == 0 and t.bk % tiling.LANE == 0
    assert t.bm <= tiling.MAX_TILE and t.bn <= tiling.MAX_TILE and t.bk <= tiling.MAX_TILE
    used = tiling.matmul_vmem_bytes(t.bm, t.bn, t.bk, has_bias=True)
    # Either inside the budget, or already at the smallest legal tiling.
    smallest = (t.bm, t.bn, t.bk) == (tiling.SUBLANE_INT8, tiling.LANE, tiling.LANE)
    assert used <= budget or smallest
    assert used <= tiling.VMEM_BYTES_PER_CORE  # never exceeds physical VMEM


@settings(max_examples=40, deadline=None)
@given(
    st.integers(1, 4000),
    st.integers(1, 512),
    st.integers(1, 512),
    st.sampled_from([1, 3, 5, 7]),
    st.sampled_from([512 << 10, 2 << 20, tiling.DEFAULT_VMEM_BUDGET]),
)
def test_conv_selector_fits_budget_and_granules(l, cin, cout, k, budget):
    t = tiling.select_conv_tiles(4, l, cin, cout, k, budget=budget, has_bias=True)
    assert t.bn % tiling.LANE == 0
    if k > 1:
        assert t.bl % tiling.conv_halo_rows(k) == 0  # exact halo block index
    assert t.bl % tiling.SUBLANE_INT8 == 0
    cin_p = (cin + tiling.LANE - 1) // tiling.LANE * tiling.LANE
    used = tiling.conv_vmem_bytes(t.bl, t.bn, k=k, cin_p=cin_p, has_bias=True)
    smallest_bl = max(tiling.SUBLANE_INT8, tiling.conv_halo_rows(k) if k > 1 else 0)
    assert used <= budget or (t.bl, t.bn) == (smallest_bl, tiling.LANE)


def test_selector_is_deterministic_and_budget_sensitive():
    a = tiling.select_matmul_tiles(1024, 1024, 1024, budget=8 << 20)
    b = tiling.select_matmul_tiles(1024, 1024, 1024, budget=8 << 20)
    assert a == b  # pure function of its inputs
    tight = tiling.select_matmul_tiles(1024, 1024, 1024, budget=256 << 10)
    assert (tight.bm, tight.bn, tight.bk) != (a.bm, a.bn, a.bk)
    assert tiling.matmul_vmem_bytes(tight.bm, tight.bn, tight.bk) <= 256 << 10


def test_elementwise_selector_granules():
    for n in (1, 100, 4096, 524288):
        t = tiling.select_elementwise_tiles(n)
        assert t.bn == tiling.LANE
        assert t.bm % tiling.SUBLANE_FP32 == 0
        assert 2 * (2 * t.bm * t.bn * 4) <= tiling.DEFAULT_VMEM_BUDGET


# ---------------------------------------------------------------------------
# Tile-choice invariance: int32 accumulators are bitwise identical across
# >= 3 distinct selections per kernel (incl. non-128 multiples).
# ---------------------------------------------------------------------------

# Explicit geometries: small sublane-granule tiles, mixed, and the legacy
# 128-cube — none of which may move a single accumulator bit.
MATMUL_TILES = [(32, 128, 128), (96, 256, 384), (128, 128, 128), (64, 512, 256)]
CONV_TILES = [(32, 128), (96, 256), (128, 128), (64, 384)]
CORDIC_BLOCKS = [(8, 128), (32, 128), (256, 128), (512, 128)]


def _matmul_case(m=70, k=300, n=200):
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, n)) * 0.2, jnp.float32)
    xq, wq = int8_symmetric(x, axis=None), int8_symmetric(w, axis=1)
    return xq, wq


def test_matmul_accumulators_invariant_across_tiles():
    xq, wq = _matmul_case()
    accs = [
        quant_matmul(
            xq.q, wq.q, xq.scale.reshape(1, 1), wq.scale.reshape(1, -1),
            bm=bm, bn=bn, bk=bk, return_acc=True,
        )
        for bm, bn, bk in MATMUL_TILES
    ]
    ref = xq.q.astype(jnp.int32) @ wq.q.astype(jnp.int32)  # integer oracle
    for acc, tiles in zip(accs, MATMUL_TILES):
        assert acc.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(ref), err_msg=str(tiles))


def test_matmul_accumulators_invariant_across_budgets():
    xq, wq = _matmul_case(m=256, k=1096, n=160)
    budgets = [256 << 10, 1 << 20, tiling.DEFAULT_VMEM_BUDGET]
    picked = [tiling.select_matmul_tiles(256, 1096, 160, budget=bdg) for bdg in budgets]
    assert len({(t.bm, t.bn, t.bk) for t in picked}) >= 2  # budgets actually differ
    accs = [
        quant_matmul(
            xq.q, wq.q, xq.scale.reshape(1, 1), wq.scale.reshape(1, -1),
            bm=t.bm, bn=t.bn, bk=t.bk, return_acc=True,
        )
        for t in picked
    ]
    for acc in accs[1:]:
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(accs[0]))


@pytest.mark.parametrize("k", [1, 3, 5])
def test_conv_accumulators_invariant_across_tiles(k):
    x = jnp.asarray(RNG.standard_normal((2, 210, 70)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, 70, 150)) * 0.2, jnp.float32)
    xq, wq = int8_symmetric(x, axis=None), int8_symmetric(w, axis=2)
    accs = [
        conv1d_fused_q(
            xq.q, wq.q, xq.scale, wq.scale, bl=bl, bn=bn, return_acc=True
        )
        for bl, bn in CONV_TILES
    ]
    patches = ops._im2col(xq.q.astype(jnp.float32), k).astype(jnp.int32)
    wmat = wq.q.reshape(k * 70, 150).astype(jnp.int32)
    ref = (patches @ wmat).reshape(2, 210, 150)
    for acc, tiles in zip(accs, CONV_TILES):
        assert acc.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(ref), err_msg=str(tiles))


def test_conv_accumulators_invariant_across_budgets():
    x = jnp.asarray(RNG.standard_normal((2, 300, 100)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((3, 100, 200)) * 0.2, jnp.float32)
    xq, wq = int8_symmetric(x, axis=None), int8_symmetric(w, axis=2)
    budgets = [512 << 10, 2 << 20, tiling.DEFAULT_VMEM_BUDGET]
    picked = [
        tiling.select_conv_tiles(2, 300, 100, 200, 3, budget=bdg) for bdg in budgets
    ]
    assert len({(t.bl, t.bn) for t in picked}) >= 2
    accs = [
        conv1d_fused_q(
            xq.q, wq.q, xq.scale, wq.scale, bl=t.bl, bn=t.bn, return_acc=True
        )
        for t in picked
    ]
    for acc in accs[1:]:
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(accs[0]))


def test_cordic_bits_invariant_across_blocks():
    x = jnp.asarray(RNG.uniform(-4, 4, (1000, 37)), jnp.float32)
    for mode in ("tanh", "exp", "sigmoid"):
        outs = [
            cordic_activation(x, mode, block=blk) for blk in CORDIC_BLOCKS
        ]
        outs.append(cordic_activation(x, mode))  # budget-driven default
        for o in outs[1:]:
            np.testing.assert_array_equal(np.asarray(o), np.asarray(outs[0]))


def test_default_tiles_match_legacy_128_bitwise():
    """The selector-driven defaults reproduce the legacy hardcoded-128 path
    bit for bit on the serving dequant output, not just the accumulators."""
    xq, wq = _matmul_case(m=48, k=200, n=96)
    bias = jnp.asarray(RNG.standard_normal(96), jnp.float32)
    legacy = quant_matmul(
        xq.q, wq.q, xq.scale.reshape(1, 1), wq.scale.reshape(1, -1), bias,
        act="relu", bm=128, bn=128, bk=128,
    )
    picked = quant_matmul(
        xq.q, wq.q, xq.scale.reshape(1, 1), wq.scale.reshape(1, -1), bias,
        act="relu",
    )
    np.testing.assert_array_equal(np.asarray(picked), np.asarray(legacy))
