"""Golden-artifact regression: the deployed numerics are pinned to disk.

``artifacts/golden/`` holds a seeded tiny detector baked into serving
artifacts (plain int8 and the full deployment cell — pruned + mixed
per-layer precision) plus the expected probabilities on a committed input
batch.  Any change anywhere in the serving stack (quantisers, kernels,
dispatch, prune plumbing, artifact IO) that moves the deployed numbers
fails here *bitwise* and loudly — with the regeneration command in the
failure message, so an intentional numerics change is a conscious,
reviewable diff of the golden files.
"""
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.features import FEATURE_DIMS
from repro.models import cnn1d
from repro.serving.accelerator import accelerator_forward
from repro.serving.quantized_params import load_artifact

GOLDEN = Path(__file__).resolve().parents[1] / "artifacts" / "golden"
REGEN = "PYTHONPATH=src python scripts/make_golden_artifact.py"


def _cfg(input_len: int) -> cnn1d.CNNConfig:
    # accelerator_forward takes its shapes from the artifact; the config is
    # only the wrapper-level contract (input length matches the stored batch).
    return cnn1d.CNNConfig(input_len=input_len, channels=(4, 8), hidden=8)


@pytest.mark.parametrize("name", ["int8", "pruned_mixed", "int8_ondevice"])
def test_golden_artifact_numerics_pinned(name):
    # the on-device cell replays raw 0.8 s windows through the fused
    # front-end + datapath program; the others replay extracted features
    raw = name.endswith("_ondevice")
    x = np.load(GOLDEN / ("input_windows.npy" if raw else "input.npy"))
    qp = load_artifact(GOLDEN / f"detector_{name}.npz")
    cfg = _cfg(FEATURE_DIMS[qp.feature_kind] if raw else x.shape[1])
    got = np.asarray(
        accelerator_forward(
            qp, jnp.asarray(x), cfg, interpret=True, raw_windows=raw
        )
    )
    want = np.load(GOLDEN / f"expected_{name}.npy")
    if not np.array_equal(got, want):
        pytest.fail(
            f"Golden artifact {name!r} deployed numerics drifted "
            f"(max |dp| = {np.abs(got - want).max():.3e}, "
            f"{int((got != want).sum())}/{want.size} values changed).\n"
            f"If this change is intentional, regenerate and commit the "
            f"golden files:\n    {REGEN}"
        )


def test_golden_artifact_metadata():
    """The committed artifacts carry the deployment decisions they claim."""
    plain = load_artifact(GOLDEN / "detector_int8.npz")
    assert plain.mode == "int8" and not plain.mixed and not plain.pruned

    deploy = load_artifact(GOLDEN / "detector_pruned_mixed.npz")
    assert deploy.pruned and deploy.mixed
    assert deploy.layer_modes == (("bf16", "int8"), ("int8", "fp32"))
    # keep=3 channels, one boundary frame trimmed from the 32-frame map
    assert deploy.keep_frames == 31
    assert deploy.convs[-1]["b"].shape == (3,)
    assert deploy.denses[0]["w"].shape == (31 * 3, 8)
    # pre-front-end artifacts carry no baked feature kind...
    assert plain.feature_kind is None and deploy.feature_kind is None
    # ...the on-device cell does (it is what makes raw-window serving legal)
    ondev = load_artifact(GOLDEN / "detector_int8_ondevice.npz")
    assert ondev.feature_kind == "zcr" and not ondev.mixed and not ondev.pruned
