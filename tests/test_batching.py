"""The shared continuous-batching core (serving/batching.py) in isolation:
slot-ladder selection, the rotating block pool's aliasing-safety contract,
the dispatch loop's ordering/padding/in-flight behaviour and its
all-or-nothing commit/rollback semantics, and the admission/fairness
primitives the fleet-scale monitor builds on.  The engine- and server-level
suites (test_streaming_engine.py, test_serve.py, test_fault_tolerance.py)
cover the same core through its two production callers.
"""
import numpy as np
import pytest

from repro.serving.batching import (
    AdmissionPolicy,
    BlockPool,
    DispatchCore,
    SlotPolicy,
    fair_allocation,
)

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st


# ---------------------------------------------------------------------------
# SlotPolicy
# ---------------------------------------------------------------------------


def test_fixed_policy_always_max():
    p = SlotPolicy.fixed(8)
    assert p.ladder == (8,)
    for backlog in (1, 3, 8, 100):
        assert p.pick(backlog) == 8


def test_adaptive_ladder_powers_of_two():
    p = SlotPolicy(8, adaptive=True)
    assert p.ladder == (1, 2, 4, 8)
    assert p.pick(1) == 1
    assert p.pick(2) == 2
    assert p.pick(3) == 2  # largest that fits: 2, then a 1-block follows
    assert p.pick(7) == 4
    assert p.pick(8) == 8
    assert p.pick(1000) == 8


def test_adaptive_ladder_respects_min_slots():
    p = SlotPolicy(16, adaptive=True, min_slots=4)
    assert p.ladder == (4, 8, 16)
    # sub-min backlog dispatches the smallest ladder block (bounded padding)
    assert p.pick(1) == 4
    assert p.pick(5) == 4
    assert p.pick(16) == 16


def test_adaptive_ladder_multiple_for_shards():
    p = SlotPolicy(8, adaptive=True, multiple=2)
    assert p.ladder == (2, 4, 8)
    assert all(s % 2 == 0 for s in p.ladder)
    assert p.pick(1) == 2  # never dispatches a shape the mesh can't split


def test_slot_policy_validation():
    with pytest.raises(ValueError, match="max_slots"):
        SlotPolicy(0)
    with pytest.raises(ValueError, match="min_slots"):
        SlotPolicy(4, min_slots=5)
    with pytest.raises(ValueError, match="multiple"):
        SlotPolicy(6, multiple=4)
    with pytest.raises(ValueError, match="backlog"):
        SlotPolicy(4).pick(0)


def test_adaptive_total_padding_bounded_by_ladder():
    # whatever the backlog, padding only ever occurs on the final sub-min
    # block, so it is < the smallest ladder value
    p = SlotPolicy(8, adaptive=True)
    for backlog in range(1, 40):
        remaining, padded = backlog, 0
        while remaining > 0:
            s = p.pick(remaining)
            live = min(s, remaining)
            padded += s - live
            remaining -= live
        assert padded == 0  # ladder reaches down to 1: never pads


# ---------------------------------------------------------------------------
# BlockPool
# ---------------------------------------------------------------------------


def test_block_pool_rotation_depth():
    pool = BlockPool(width=3, inflight=2)
    rows = [np.full(3, i, np.float32) for i in range(10)]
    b0 = pool.pack(rows[:2], 4)
    b1 = pool.pack(rows[2:4], 4)
    b2 = pool.pack(rows[4:6], 4)
    # three distinct buffers (inflight + 1), then the rotation reuses b0
    assert b0 is not b1 and b1 is not b2 and b0 is not b2
    assert pool.pack(rows[6:8], 4) is b0


def test_block_pool_zeroes_dead_tail():
    pool = BlockPool(width=2, inflight=1)
    full = pool.pack([np.ones(2, np.float32)] * 3, 3)
    np.testing.assert_array_equal(full, np.ones((3, 2), np.float32))
    partial = pool.pack([np.full(2, 7.0, np.float32)], 3)
    np.testing.assert_array_equal(partial[0], np.full(2, 7.0, np.float32))
    np.testing.assert_array_equal(partial[1:], np.zeros((2, 2), np.float32))


def test_block_pool_shapes_rotate_independently():
    pool = BlockPool(width=1, inflight=1)
    a = pool.pack([np.zeros(1, np.float32)], 2)
    b = pool.pack([np.zeros(1, np.float32)], 4)  # other shape: fresh pool
    c = pool.pack([np.ones(1, np.float32)], 2)
    assert a.shape == (2, 1) and b.shape == (4, 1)
    assert a is not c  # shape-2 rotation advanced, untouched by shape-4
    with pytest.raises(ValueError, match="do not fit"):
        pool.pack([np.zeros(1, np.float32)] * 3, 2)


# ---------------------------------------------------------------------------
# DispatchCore
# ---------------------------------------------------------------------------


def _sync_core(slots=4, adaptive=False, **kw):
    """Core over a synchronous 'program' that records each block."""
    calls = []

    def submit(live, n_slots):
        calls.append((list(live), n_slots))
        return [x * 10 for x in live]

    core = DispatchCore(
        submit=submit,
        harvest=None,
        slot_policy=SlotPolicy(slots, adaptive=adaptive),
        **kw,
    )
    return core, calls


def test_dispatch_preserves_input_order_and_chunks():
    core, calls = _sync_core(slots=4)
    out = core.dispatch(list(range(10)))
    assert out == [x * 10 for x in range(10)]
    assert [n for _, n in calls] == [4, 4, 4]
    assert core.blocks_dispatched == 3
    assert core.padded_slots == 2  # final block: 2 live in 4 slots
    assert core.slot_histogram == {4: 3}


def test_dispatch_adaptive_shrinks_tail():
    core, calls = _sync_core(slots=4, adaptive=True)
    out = core.dispatch(list(range(7)))
    assert out == [x * 10 for x in range(7)]
    assert [n for _, n in calls] == [4, 2, 1]
    assert core.padded_slots == 0
    assert core.slot_histogram == {4: 1, 2: 1, 1: 1}


def test_async_harvest_bounded_inflight():
    in_flight = []
    max_depth = []

    def submit(live, slots):
        handle = [x + 100 for x in live]
        in_flight.append(handle)
        max_depth.append(len(in_flight))
        return handle

    def harvest(handle):
        in_flight.remove(handle)
        return handle

    core = DispatchCore(
        submit=submit, harvest=harvest,
        slot_policy=SlotPolicy(2), inflight=2,
    )
    out = core.dispatch(list(range(9)))
    assert out == [x + 100 for x in range(9)]
    # the pipeline never holds more than `inflight` unharvested blocks
    assert max(max_depth) == 2
    assert not in_flight  # everything harvested by the end


def test_enqueue_drain_fifo_and_requeue_on_failure():
    boom = {"armed": True}

    def submit(live, slots):
        if boom["armed"]:
            raise RuntimeError("injected")
        return list(live)

    core = DispatchCore(
        submit=submit, harvest=None, slot_policy=SlotPolicy(3)
    )
    core.enqueue([1, 2, 3, 4])
    with pytest.raises(RuntimeError, match="injected"):
        core.drain()
    # rollback: the items went back to the front of the queue, in order
    core.enqueue([5])
    boom["armed"] = False
    assert core.drain() == [1, 2, 3, 4, 5]
    assert core.drain() == []  # empty queue: no dispatch


def test_pre_dispatch_seam_fires_before_submit_and_rolls_back():
    events = []

    def pre(items):
        events.append(("pre", list(items)))
        raise RuntimeError("injected crash")

    core = DispatchCore(
        submit=lambda live, n: events.append(("submit", list(live))) or list(live),
        harvest=None,
        slot_policy=SlotPolicy(2),
        pre_dispatch=pre,
        on_rollback=lambda items: events.append(("rollback", list(items))),
    )
    with pytest.raises(RuntimeError, match="injected crash"):
        core.dispatch([1, 2, 3])
    assert events == [("pre", [1, 2, 3]), ("rollback", [1, 2, 3])]
    core.pre_dispatch = None
    assert core.dispatch([1, 2]) is not None  # seam cleared: dispatch works


def test_on_commit_sees_items_and_results():
    committed = []
    core = DispatchCore(
        submit=lambda live, n: [x * 2 for x in live],
        harvest=None,
        slot_policy=SlotPolicy(2),
        on_commit=lambda items, results: committed.append((items, results)),
    )
    core.dispatch([1, 2, 3])
    assert committed == [([1, 2, 3], [2, 4, 6])]


def test_mid_stream_failure_rolls_back_without_partial_commit():
    # a failure on block 2 must not fire on_commit even though block 1
    # already returned results — all-or-nothing from the caller's view
    committed, rolled = [], []

    def submit(live, slots):
        if live[0] >= 2:
            raise RuntimeError("late failure")
        return list(live)

    core = DispatchCore(
        submit=submit, harvest=None, slot_policy=SlotPolicy(2),
        on_commit=lambda *a: committed.append(a),
        on_rollback=lambda items: rolled.append(list(items)),
    )
    with pytest.raises(RuntimeError, match="late failure"):
        core.dispatch([0, 1, 2, 3])
    assert committed == []
    assert rolled == [[0, 1, 2, 3]]


# ---------------------------------------------------------------------------
# AdmissionPolicy / fair_allocation
# ---------------------------------------------------------------------------


def test_admission_policy_validation():
    AdmissionPolicy()  # defaults valid
    with pytest.raises(ValueError, match="max_streams"):
        AdmissionPolicy(max_streams=0)
    with pytest.raises(ValueError, match="max_per_stream_per_round"):
        AdmissionPolicy(max_per_stream_per_round=0)
    with pytest.raises(ValueError, match="round_budget"):
        AdmissionPolicy(round_budget=0)
    with pytest.raises(ValueError, match="evict_overflow_rounds"):
        AdmissionPolicy(evict_overflow_rounds=0)


def test_fair_allocation_passthrough_when_budget_covers():
    want = np.array([3, 0, 2, 1])
    np.testing.assert_array_equal(fair_allocation(want, None), want)
    np.testing.assert_array_equal(fair_allocation(want, 6), want)
    np.testing.assert_array_equal(fair_allocation(want, 100), want)


def test_fair_allocation_depth_fair_under_pressure():
    # firehose stream 0 wants 10, trickles want 1 each; budget 4 must give
    # every wanting stream its first window before stream 0's second
    want = np.array([10, 1, 1, 1])
    np.testing.assert_array_equal(fair_allocation(want, 4), [1, 1, 1, 1])
    # one more unit of budget goes to the deepest demand, stream 0
    np.testing.assert_array_equal(fair_allocation(want, 5), [2, 1, 1, 1])


def test_fair_allocation_ties_break_by_index():
    want = np.array([2, 2, 2])
    np.testing.assert_array_equal(fair_allocation(want, 4), [2, 1, 1])
    np.testing.assert_array_equal(fair_allocation(want, 2), [1, 1, 0])


def test_fair_allocation_rejects_negative():
    with pytest.raises(ValueError, match="non-negative"):
        fair_allocation(np.array([1, -1]), 2)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=9), min_size=1, max_size=12),
    st.integers(min_value=1, max_value=40),
)
def test_fair_allocation_properties(want, budget):
    want = np.asarray(want, np.int64)
    alloc = fair_allocation(want, budget)
    # never over-serves a stream, never exceeds the budget
    assert (alloc <= want).all() and (alloc >= 0).all()
    assert alloc.sum() <= budget
    # work-conserving: either demand is fully met or the budget is spent
    assert alloc.sum() == min(int(want.sum()), budget)
    # depth-fairness: a stream only reaches depth d+1 once every stream
    # wanting depth d got it (up to the index tie-break at the boundary)
    if (want > 0).any():
        served = alloc[want > 0]
        assert served.max() - served.min() <= 1 or served.min() >= 1
