"""PrecisionPolicy: lossless serialisation and order-stable glob resolution.

A policy rides along in configs, checkpoints and serving artifacts, so its
round-trip must be lossless and its pattern resolution must be a function of
the rule *set* — never of dict insertion order (two artifacts baked from the
same rules written in different orders must dispatch identically).
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision_policy import (
    PrecisionPolicy,
    fake_quant_params,
    policy_einsum,
)
from repro.core.quantization import Precision

RULES = {
    "conv*/w": Precision.INT8,
    "conv0/w": Precision.BF16,
    "dense1/w": Precision.FP32,
    "dense*/w": Precision.FXP8,
}


def test_dict_round_trip_lossless():
    pol = PrecisionPolicy(rules=dict(RULES), default=Precision.FXP8)
    back = PrecisionPolicy.from_dict(pol.to_dict())
    assert back == pol
    assert back.rules == RULES and back.default == Precision.FXP8


def test_json_round_trip_lossless():
    pol = PrecisionPolicy(rules=dict(RULES), default=Precision.BF16)
    s = pol.to_json()
    json.loads(s)  # valid JSON
    assert PrecisionPolicy.from_json(s) == pol
    # serialisation is canonical: same rule set, any insertion order -> same bytes
    reordered = PrecisionPolicy(
        rules=dict(reversed(list(RULES.items()))), default=Precision.BF16
    )
    assert reordered.to_json() == s


PATHS = ["conv0/w", "conv1/w", "conv2/w", "dense0/w", "dense1/w", "emb/w"]


def test_resolution_is_insertion_order_stable():
    fwd = PrecisionPolicy(rules=dict(RULES), default=Precision.FP32)
    rev = PrecisionPolicy(
        rules=dict(reversed(list(RULES.items()))), default=Precision.FP32
    )
    for path in PATHS:
        assert fwd.precision_for(path) == rev.precision_for(path), path


def test_longest_match_wins():
    pol = PrecisionPolicy(rules=dict(RULES), default=Precision.FP32)
    assert pol.precision_for("conv0/w") == Precision.BF16  # exact beats glob
    assert pol.precision_for("conv1/w") == Precision.INT8
    assert pol.precision_for("dense1/w") == Precision.FP32  # exact beats dense*
    assert pol.precision_for("dense0/w") == Precision.FXP8
    assert pol.precision_for("emb/w") == Precision.FP32  # default


def test_equal_length_overlap_breaks_ties_deterministically():
    """Two same-length overlapping patterns: the lexicographically smallest
    wins, regardless of which was inserted first."""
    a = {"conv?/w": Precision.BF16, "conv0/*": Precision.INT8}
    assert len("conv?/w") == len("conv0/*")
    p1 = PrecisionPolicy(rules=dict(a), default=Precision.FP32)
    p2 = PrecisionPolicy(rules=dict(reversed(list(a.items()))), default=Precision.FP32)
    assert (
        p1.precision_for("conv0/w")
        == p2.precision_for("conv0/w")
        == Precision.INT8  # "conv0/*" < "conv?/w" lexicographically
    )


def test_parse_inline_rules_json_and_file(tmp_path):
    inline = PrecisionPolicy.parse("conv0/w=bf16, dense1/w=fp32", default="int8")
    assert inline.rules == {"conv0/w": Precision.BF16, "dense1/w": Precision.FP32}
    assert inline.default == Precision.INT8

    as_json = PrecisionPolicy.parse(inline.to_json())
    assert as_json == inline

    f = tmp_path / "policy.json"
    f.write_text(inline.to_json())
    from_file = PrecisionPolicy.parse(str(f))
    assert from_file == inline

    with pytest.raises(ValueError, match="pattern=mode"):
        PrecisionPolicy.parse("conv0/w")
    with pytest.raises(ValueError):
        PrecisionPolicy.parse("conv0/w=int9")


def test_fake_quant_params_walks_tree_per_policy():
    rng = np.random.default_rng(5)
    params = {
        "conv0": {"w": jnp.ones((3, 2, 4)), "b": jnp.zeros((4,))},
        "dense0": {
            "w": jnp.asarray(rng.standard_normal((4, 2)), jnp.float32),
            "b": jnp.zeros((2,)),
        },
    }
    pol = PrecisionPolicy(rules={"conv0/w": Precision.FP32}, default=Precision.INT8)
    out = fake_quant_params(params, pol)
    np.testing.assert_array_equal(  # fp32 layer untouched
        np.asarray(out["conv0"]["w"]), np.asarray(params["conv0"]["w"])
    )
    assert out["dense0"]["b"].shape == (2,)  # biases ride through unquantised
    assert not np.array_equal(  # int8 fake-quant moved the dense weights
        np.asarray(out["dense0"]["w"]), np.asarray(params["dense0"]["w"])
    )


@pytest.mark.parametrize(
    "prec", [Precision.FP32, Precision.BF16, Precision.INT8, Precision.FXP8]
)
def test_policy_einsum_dispatches_every_mode(prec):
    rng = np.random.default_rng(0)
    # post-ReLU-like activations: the 8-bit modes run PACT, which clips to
    # [0, alpha] — negative inputs would be zeroed by design, not by error.
    x = jnp.asarray(rng.uniform(0.0, 4.0, (4, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((8, 6)), jnp.float32)
    out = policy_einsum("bk,kn->bn", x, w, prec)
    assert out.shape == (4, 6) and out.dtype == jnp.float32
    ref = np.asarray(x) @ np.asarray(w)
    atol = {Precision.FP32: 1e-5, Precision.BF16: 0.3}.get(prec, 1.0)
    np.testing.assert_allclose(np.asarray(out), ref, atol=atol)
