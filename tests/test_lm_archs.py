"""Per-architecture smoke tests (reduced configs) + serving consistency.

Every assigned arch: one forward + one train step on CPU asserting shapes
and finiteness; decoder archs additionally check prefill+decode against the
full forward (with capacity_factor raised so MoE token-dropping cannot
perturb the comparison)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # heavyweight tier: scripts/ci.sh --all

from repro.configs import get_config, lm_arch_names
from repro.models import transformer as T
from repro.training.lm import TrainSettings, make_train_step
from repro.training.optimizer import Adam

ARCHS = lm_arch_names()


def _batch(cfg, rng, B=2, S=32, train=False):
    if cfg.frontend == "audio_frames":
        b = {"frames": jax.random.normal(rng, (B, S, cfg.frontend_dim))}
        lbl_len = S
    elif cfg.frontend == "vision_patches":
        b = {
            "tokens": jax.random.randint(rng, (B, S - cfg.n_patches), 0, cfg.vocab),
            "patches": jax.random.normal(rng, (B, cfg.n_patches, cfg.frontend_dim)),
        }
        lbl_len = S - cfg.n_patches
    else:
        b = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
        lbl_len = S
    if train:
        b["labels"] = jax.random.randint(rng, (B, lbl_len), 0, cfg.vocab)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch).smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits = T.forward(params, batch, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    opt = Adam(lr=1e-3)
    opt_state = opt.init(params)
    step = make_train_step(cfg, opt, TrainSettings(n_micro=2))
    batch = _batch(cfg, jax.random.PRNGKey(1), train=True)
    p2, o2, metrics = jax.jit(step)(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(p2), jax.tree_util.tree_leaves(params))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).smoke().replace(capacity_factor=16.0)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S, MAX = 2, 24, 40
    rng = jax.random.PRNGKey(2)
    tok = jax.random.randint(rng, (B, S + 2), 0, cfg.vocab)
    batch = {"tokens": tok[:, :S]}
    off = 0
    if cfg.frontend == "vision_patches":
        batch["patches"] = jax.random.normal(rng, (B, cfg.n_patches, cfg.frontend_dim))
        off = cfg.n_patches
    full = T.forward(params, {**batch, "tokens": tok}, cfg)
    last, caches = T.forward_with_cache(params, batch, cfg, MAX)
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full[:, S - 1 + off]), rtol=2e-4, atol=2e-4
    )
    for i in range(2):  # two consecutive decode steps exercise cache updates
        pos = jnp.asarray(S + i + off, jnp.int32)
        lg, caches = T.decode_step(params, tok[:, S + i : S + i + 1], caches, pos, cfg, MAX)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, S + i + off]), rtol=2e-3, atol=2e-3
        )


def test_ring_cache_matches_full_window():
    """Sliding-window decode with a ring cache == full forward, beyond the
    window horizon (the long_500k mechanism)."""
    cfg = get_config("h2o-danube-3-4b").smoke().replace(window=8)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 20
    tok = jax.random.randint(jax.random.PRNGKey(3), (B, S + 4), 0, cfg.vocab)
    full = T.forward(params, {"tokens": tok}, cfg)
    _, caches = T.forward_with_cache(params, {"tokens": tok[:, :S]}, cfg, max_seq=S + 4)
    # ring cache buffer length == window
    k0 = jax.tree_util.tree_leaves(caches)[0]
    assert k0.shape[2] == 8
    for i in range(4):
        lg, caches = T.decode_step(
            params, tok[:, S + i : S + i + 1], caches, jnp.asarray(S + i, jnp.int32), cfg, S + 4
        )
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, S + i]), rtol=2e-3, atol=2e-3
        )


def test_scan_equals_unroll():
    """The sequential shared-datapath execution (scan) is numerically the
    unrolled program."""
    cfg = get_config("gemma-2b").smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    a = T.forward(params, batch, cfg.replace(stack_mode="scan"))
    b = T.forward(params, batch, cfg.replace(stack_mode="unroll"))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_unroll_attn_equals_scan_attn():
    cfg = get_config("gemma-2b").smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    S = 2 * 1024 + 128  # force the chunked path (> ATTN_CHUNK)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab)}
    a = T.forward(params, batch, cfg)
    b = T.forward(params, batch, cfg.replace(unroll_attn=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_zamba2_shared_block_is_shared():
    cfg = get_config("zamba2-7b").smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    assert "shared" in params
    # zero the shared attention weights -> every shared_attn block changes
    z = jax.tree_util.tree_map(jnp.zeros_like, params["shared"])
    batch = _batch(cfg, jax.random.PRNGKey(1))
    base = T.forward(params, batch, cfg)
    changed = T.forward({**params, "shared": z}, batch, cfg)
    assert float(jnp.max(jnp.abs(base - changed))) > 1e-3


def test_param_counts_match_published_scale():
    """Full configs land near the published parameter counts."""
    expected = {
        "phi3.5-moe-42b-a6.6b": (40e9, 45e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "phi4-mini-3.8b": (3.3e9, 4.3e9),
        "gemma3-12b": (10e9, 13.5e9),
        "h2o-danube-3-4b": (3.3e9, 4.2e9),
        "gemma-2b": (2.2e9, 3.0e9),
        "rwkv6-7b": (6.5e9, 8e9),
        "zamba2-7b": (6.3e9, 8.3e9),
        "hubert-xlarge": (0.85e9, 1.1e9),
        "internvl2-1b": (0.4e9, 0.8e9),
    }
    for arch, (lo, hi) in expected.items():
        n = T.param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]B"
