"""Unit tests for the shared benchmark row-builder helpers.

The serving benches derive their percentile columns through
:func:`benchmarks.common.percentile_fields`, which must degrade to null
fields on zero recorded rounds (SMOKE runs score everything in
warmup/drain) instead of letting ``np.percentile`` raise on an empty
list.  ``benchmarks.bench_serving`` itself is deliberately NOT imported
here — it forces a simulated host-device count before jax import, which
must not leak into the unit-test process.
"""
import csv
import io
import json

import pytest

from benchmarks import common
from benchmarks.common import (
    PERCENTILE_KEYS,
    format_percentiles,
    median_us,
    percentile_fields,
    row,
    write_json,
)


def test_percentile_fields_empty_rounds_degrade_to_null():
    fields = percentile_fields([])
    assert fields == {k: None for k in PERCENTILE_KEYS}
    assert format_percentiles(fields) == "round latency n/a (0 rounds)"


def test_percentile_fields_scale_and_ordering():
    fields = percentile_fields([0.001, 0.002, 0.010, 0.004])
    assert set(fields) == set(PERCENTILE_KEYS)
    p50, p95, p99 = (fields[k] for k in PERCENTILE_KEYS)
    assert p50 <= p95 <= p99  # percentiles are monotone in q
    assert p50 == pytest.approx(3.0)  # seconds -> milliseconds
    assert p99 <= 10.0
    text = format_percentiles(fields)
    assert text.startswith("round latency p50/p95/p99 ")
    assert text.endswith(" ms")


def test_percentile_fields_single_round_collapses():
    fields = percentile_fields([0.005])
    assert all(fields[k] == 5.0 for k in PERCENTILE_KEYS)


def test_format_percentiles_null_safe_on_partial_fields():
    fields = percentile_fields([0.001])
    fields["round_p99_ms"] = None
    assert format_percentiles(fields) == "round latency n/a (0 rounds)"


def test_row_records_non_numeric_median_as_null(capsys):
    before = len(common._RECORDS)
    row("kernels/unit_test_na", "n/a", "derived text", extra_key=7)
    rec = common._RECORDS[-1]
    try:
        assert rec["median_us"] is None
        assert rec["extra_key"] == 7
        assert capsys.readouterr().out.strip() == (
            "kernels/unit_test_na,n/a,derived text"
        )
    finally:
        del common._RECORDS[before:]  # keep the module-global sink clean


def test_row_csv_quotes_commas_and_parses_back(capsys):
    """``derived`` strings routinely contain commas ("drop 0.0%, reject
    0.0%") — the emitted CSV must round-trip through ``csv.reader`` as
    exactly three fields, not shear into five."""
    before = len(common._RECORDS)
    derived = "drop 0.0%, reject 0.0%, p50 1.2 ms"
    row("kernels/unit_test_csv", 42.0, derived)
    try:
        out = capsys.readouterr().out
        parsed = list(csv.reader(io.StringIO(out)))
        assert len(parsed) == 1
        assert parsed[0] == ["kernels/unit_test_csv", "42.0", derived]
    finally:
        del common._RECORDS[before:]


def test_median_us_true_median_for_even_iters():
    # 4 samples: true median is the mean of the middle two (2.5s -> 2.5e6us);
    # the old sorted-index pick returned the upper-mid element (3.0s).
    assert median_us([4.0, 1.0, 3.0, 2.0]) == pytest.approx(2.5e6)
    assert median_us([5.0, 1.0, 3.0]) == pytest.approx(3.0e6)


def test_row_attaches_env_fingerprint_when_registered():
    before = len(common._RECORDS)
    try:
        common.set_env_fingerprint("deadbeef00")
        row("kernels/unit_test_env", 1.0, "a")
        assert common._RECORDS[-1]["env_fingerprint"] == "deadbeef00"
        common.set_env_fingerprint(None)
        row("kernels/unit_test_noenv", 1.0, "b")
        assert "env_fingerprint" not in common._RECORDS[-1]
    finally:
        common.set_env_fingerprint(None)
        del common._RECORDS[before:]


def test_write_json_merge_preserves_unmeasured_rows(tmp_path):
    path = tmp_path / "BENCH_unit.json"
    path.write_text(json.dumps({"kernels/old_row": {"median_us": 1.0, "derived": "x"}}))
    before = len(common._RECORDS)
    row("kernels/new_row", 2.0, "y")
    try:
        write_json(str(path), prefix="kernels/", merge=True)
        data = json.loads(path.read_text())
        assert "kernels/old_row" in data  # survived the merge
        assert data["kernels/new_row"]["median_us"] == 2.0
        write_json(str(path), prefix="kernels/", merge=False)
        assert "kernels/old_row" not in json.loads(path.read_text())
    finally:
        del common._RECORDS[before:]


def test_write_json_filters_by_prefix(tmp_path):
    before = len(common._RECORDS)
    row("serving/unit_a", 12.3456, "a")
    row("kernels/unit_b", 1.0, "b")
    try:
        path = tmp_path / "BENCH_unit.json"
        write_json(str(path), prefix="serving/")
        data = json.loads(path.read_text())
        assert "serving/unit_a" in data
        assert "kernels/unit_b" not in data
        assert data["serving/unit_a"]["median_us"] == 12.346  # rounded
        assert data["serving/unit_a"]["derived"] == "a"
    finally:
        del common._RECORDS[before:]


_BENCH_ENV_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_cpu_enable_fast_math=false"
from benchmarks import bench_env

state = bench_env.apply(host_devices=2)
assert state["late"] is False  # ran before the first jax import
flags = os.environ["XLA_FLAGS"].split()
assert "--xla_cpu_enable_fast_math=false" in flags  # caller flag survives
assert "--xla_force_host_platform_device_count=2" in flags
bench_env.apply(host_devices=4)  # key already present: no duplicate/override
assert os.environ["XLA_FLAGS"].split().count(
    "--xla_force_host_platform_device_count=2"
) == 1

fp = bench_env.fingerprint()
assert fp["applied"] and not fp["late"]
assert fp["device_count"] == 2  # the pinned count actually took effect
assert isinstance(fp["tcmalloc"], bool)
fid = bench_env.fingerprint_id()
assert len(fid) == 10 and fid == bench_env.fingerprint_id()  # stable
print("BENCH_ENV_OK")
"""


def test_bench_env_pins_before_jax_import_subprocess():
    """``apply()`` merges the pinned flags into caller-set XLA_FLAGS without
    clobbering them, never duplicates a key, and the forced host device
    count actually takes effect — in a subprocess, because the whole point
    is mutating the pre-jax-import environment."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = f"{root}:{root / 'src'}"
    proc = subprocess.run(
        [sys.executable, "-c", _BENCH_ENV_SCRIPT],
        cwd=root, env=env, capture_output=True, text=True, timeout=120,
    )
    assert proc.returncode == 0, proc.stderr
    assert "BENCH_ENV_OK" in proc.stdout


def test_bench_env_step_marker_leg_with_mocked_accel(tmp_path, monkeypatch):
    """The TPU leg of ``bench_env.apply()`` — exercised without hardware by
    pointing ``ACCEL_DEVICE_GLOB`` at a tmp path: the step-marker flag is
    pinned exactly once (idempotent on re-apply), recorded in the state,
    and absent again when the glob matches nothing."""
    import os

    from benchmarks import bench_env

    (tmp_path / "accel0").touch()
    monkeypatch.setattr(bench_env, "ACCEL_DEVICE_GLOB",
                        str(tmp_path / "accel*"))
    monkeypatch.setenv("XLA_FLAGS", "")
    saved = dict(bench_env._state)
    try:
        state = bench_env.apply(host_devices=1)
        assert state["step_marker"] is True
        flags = os.environ["XLA_FLAGS"].split()
        assert bench_env.STEP_MARKER_FLAG in flags
        bench_env.apply(host_devices=1)  # re-apply: no duplicate flag
        assert os.environ["XLA_FLAGS"].split().count(
            bench_env.STEP_MARKER_FLAG
        ) == 1

        # no-hardware leg: empty glob means no marker and no flag
        monkeypatch.setattr(bench_env, "ACCEL_DEVICE_GLOB",
                            str(tmp_path / "nothing*"))
        monkeypatch.setenv("XLA_FLAGS", "")
        state = bench_env.apply(host_devices=1)
        assert state["step_marker"] is False
        assert bench_env.STEP_MARKER_FLAG not in os.environ["XLA_FLAGS"]
    finally:
        bench_env._state.clear()
        bench_env._state.update(saved)
