"""Unit tests for the shared benchmark row-builder helpers.

The serving benches derive their percentile columns through
:func:`benchmarks.common.percentile_fields`, which must degrade to null
fields on zero recorded rounds (SMOKE runs score everything in
warmup/drain) instead of letting ``np.percentile`` raise on an empty
list.  ``benchmarks.bench_serving`` itself is deliberately NOT imported
here — it forces a simulated host-device count before jax import, which
must not leak into the unit-test process.
"""
import json

import pytest

from benchmarks import common
from benchmarks.common import (
    PERCENTILE_KEYS,
    format_percentiles,
    percentile_fields,
    row,
    write_json,
)


def test_percentile_fields_empty_rounds_degrade_to_null():
    fields = percentile_fields([])
    assert fields == {k: None for k in PERCENTILE_KEYS}
    assert format_percentiles(fields) == "round latency n/a (0 rounds)"


def test_percentile_fields_scale_and_ordering():
    fields = percentile_fields([0.001, 0.002, 0.010, 0.004])
    assert set(fields) == set(PERCENTILE_KEYS)
    p50, p95, p99 = (fields[k] for k in PERCENTILE_KEYS)
    assert p50 <= p95 <= p99  # percentiles are monotone in q
    assert p50 == pytest.approx(3.0)  # seconds -> milliseconds
    assert p99 <= 10.0
    text = format_percentiles(fields)
    assert text.startswith("round latency p50/p95/p99 ")
    assert text.endswith(" ms")


def test_percentile_fields_single_round_collapses():
    fields = percentile_fields([0.005])
    assert all(fields[k] == 5.0 for k in PERCENTILE_KEYS)


def test_format_percentiles_null_safe_on_partial_fields():
    fields = percentile_fields([0.001])
    fields["round_p99_ms"] = None
    assert format_percentiles(fields) == "round latency n/a (0 rounds)"


def test_row_records_non_numeric_median_as_null(capsys):
    before = len(common._RECORDS)
    row("kernels/unit_test_na", "n/a", "derived text", extra_key=7)
    rec = common._RECORDS[-1]
    try:
        assert rec["median_us"] is None
        assert rec["extra_key"] == 7
        assert capsys.readouterr().out.strip() == (
            "kernels/unit_test_na,n/a,derived text"
        )
    finally:
        del common._RECORDS[before:]  # keep the module-global sink clean


def test_write_json_filters_by_prefix(tmp_path):
    before = len(common._RECORDS)
    row("serving/unit_a", 12.3456, "a")
    row("kernels/unit_b", 1.0, "b")
    try:
        path = tmp_path / "BENCH_unit.json"
        write_json(str(path), prefix="serving/")
        data = json.loads(path.read_text())
        assert "serving/unit_a" in data
        assert "kernels/unit_b" not in data
        assert data["serving/unit_a"]["median_us"] == 12.346  # rounded
        assert data["serving/unit_a"]["derived"] == "a"
    finally:
        del common._RECORDS[before:]
