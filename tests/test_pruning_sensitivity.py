"""Structured pruning (Table I) and sensitivity scoring (eqs. 2-3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning as P
from repro.core import sensitivity as S
from repro.core.quantization import Precision
from repro.models import cnn1d


def test_table1_exact_reproduction():
    params = cnn1d.init_params(jax.random.PRNGKey(0), cnn1d.CANONICAL)
    _, _, spec = cnn1d.prune_model(params, cnn1d.CANONICAL, keep=64, trim_frames=1)
    assert spec.flatten_before == 35_072
    assert spec.flatten_after == 8_704
    assert abs(spec.reduction - 0.7518) < 1e-3


def test_prune_keeps_top_channels():
    w = jnp.zeros((3, 4, 8)).at[:, :, 2].set(5.0).at[:, :, 6].set(3.0)
    spec = P.plan_prune(w, n_frames=10, keep=2)
    assert list(spec.keep_channels) == [2, 6]


def test_pruned_forward_equals_masked_full():
    """Pruning == zeroing pruned channels when the dense rows match."""
    rng = jax.random.PRNGKey(1)
    cfg = cnn1d.CNNConfig(input_len=64, channels=(4, 8), hidden=8)
    params = cnn1d.init_params(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64))
    pruned, pcfg, spec = cnn1d.prune_model(params, cfg, keep=4, trim_frames=0)
    out_p = cnn1d.forward_pruned(pruned, x, pcfg, spec)
    # manual masked reference: zero dropped channels before flatten
    masked = {k: dict(v) for k, v in params.items()}
    keep = np.asarray(spec.keep_channels)
    mask = np.zeros(cfg.channels[-1]); mask[keep] = 1
    masked["conv1"]["w"] = params["conv1"]["w"] * mask[None, None, :]
    masked["conv1"]["b"] = params["conv1"]["b"] * mask
    out_m = cnn1d.forward(masked, x, cfg)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_m), rtol=1e-4, atol=1e-4)


def test_ffn_prune():
    rng = np.random.default_rng(0)
    wi = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    wo = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    wi2, wo2, idx = P.prune_ffn(wi, wo, keep=8)
    assert wi2.shape == (16, 8) and wo2.shape == (8, 16) and len(idx) == 8


def test_sensitivity_scores_and_assignment():
    rng = np.random.default_rng(0)
    params = {
        "big_spread": jnp.asarray(rng.standard_normal((32, 32)) * np.exp(rng.standard_normal((32, 32))), jnp.float32),
        "uniform": jnp.asarray(rng.uniform(-1, 1, (32, 32)), jnp.float32),
        "bias": jnp.ones((32,)),
    }
    grads = {k: jnp.ones_like(v) for k, v in params.items()}
    scores = S.sensitivity_scores(params, grads)
    assert set(scores) == {"big_spread", "uniform"}  # 1-D bias not scored
    assert all(s >= 0 for s in scores.values())
    policy = S.assign_precisions(scores, high_fraction=0.5)
    assert sorted(policy.values(), key=lambda p: p.value) == [Precision.BF16, Precision.INT8]
    # the heavy-tailed tensor benefits more from extra bits -> more sensitive
    assert policy["big_spread"] == Precision.BF16


def test_pinned_overrides():
    policy = S.assign_precisions({"a": 1.0, "b": 0.1}, high_fraction=0.0,
                                 pinned={"b": Precision.FP32})
    assert policy["b"] == Precision.FP32


def test_s8_term_is_identically_zero_and_never_computed(monkeypatch):
    """Eq. (3)'s s_{l,sc,8} term compares the 8-bit quantiser with itself —
    zero by construction.  The score must clamp at 0 exactly as if the term
    were computed, while paying only two quantiser calls per layer (the
    8-bit base + the 16-bit scale-corrected variant), not three."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
    g = jnp.ones_like(w)

    calls = []
    real = S.pwq_error
    monkeypatch.setattr(S, "pwq_error", lambda t, n: calls.append(n) or real(t, n))
    s = S.layer_sensitivity(w, g)
    assert sorted(calls) == [8, 16]  # no third (dead) 8-bit call

    # the clamp reproduces max(s_16, s_8) with s_8 == 0 exactly
    base = real(w, 8)
    s_16 = (base - real(w, 16)) * jnp.linalg.norm(g) / w.size
    s_8 = (base - real(w, 8)) * jnp.linalg.norm(g) / w.size
    assert float(s_8) == 0.0
    np.testing.assert_array_equal(np.asarray(s), np.asarray(jnp.maximum(s_16, s_8)))
