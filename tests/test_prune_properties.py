"""Property-based tests for :class:`repro.core.pruning.PruneSpec`.

The prune planner's contract is stated as properties over arbitrary keep-K
and trim choices rather than the single paper configuration:

* the paper configuration is reproduced exactly (35,072 -> 8,704);
* keep-mask propagation into the consumer dense layer is equivalence-
  preserving: the pruned forward equals the masked full-size forward for
  *any* keep-K, not just the paper's 64;
* the flatten reduction is monotone in keep-K (more channels kept can never
  shrink the flatten), and the planned sizes are internally consistent;
* ``to_dict``/``from_dict`` round-trips losslessly.

Runs under real ``hypothesis`` when installed, else the deterministic
fallback shim (tests/_hypothesis_fallback.py).
"""
import jax
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container: deterministic-example fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.core.pruning import PruneSpec, plan_prune
from repro.models import cnn1d

CFG = cnn1d.CNNConfig(input_len=64, channels=(4, 8), hidden=8)
PARAMS = cnn1d.init_params(jax.random.PRNGKey(1), CFG)
X = jax.random.normal(jax.random.PRNGKey(2), (2, CFG.input_len))
N_CH = CFG.channels[-1]


def test_paper_config_exact():
    """keep=64, trim=1 on the canonical feature map is Table I, exactly."""
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 128, 256))
    spec = plan_prune(w, cnn1d.CANONICAL.n_frames, keep=64, trim_frames=1)
    assert spec.flatten_before == 35_072
    assert spec.flatten_after == 8_704
    assert len(spec.keep_channels) == 64 and len(spec.keep_frames) == 136


@settings(deadline=None)
@given(st.integers(1, N_CH), st.integers(0, 1))
def test_keep_mask_propagation_is_equivalence_preserving(keep, trim):
    """For any keep-K and boundary trim, pruning physically == zeroing the
    dropped channels (and trimming the same frames) in the full model."""
    pruned, pcfg, spec = cnn1d.prune_model(PARAMS, CFG, keep=keep, trim_frames=trim)
    assert sorted(set(int(c) for c in spec.keep_channels)) == sorted(
        int(c) for c in spec.keep_channels
    )
    out_p = cnn1d.forward_pruned(pruned, X, pcfg, spec)

    mask = np.zeros(N_CH, np.float32)
    mask[np.asarray(spec.keep_channels)] = 1.0
    masked = {k: dict(v) for k, v in PARAMS.items()}
    masked["conv1"]["w"] = PARAMS["conv1"]["w"] * mask[None, None, :]
    masked["conv1"]["b"] = PARAMS["conv1"]["b"] * mask
    if trim:  # zero the dense rows of the trimmed boundary frames too
        wd = np.asarray(PARAMS["dense0"]["w"]).reshape(CFG.n_frames, N_CH, -1).copy()
        wd[len(spec.keep_frames):] = 0.0
        masked["dense0"]["w"] = np.reshape(wd, (CFG.flatten_size, -1))
    out_m = cnn1d.forward(masked, X, CFG)
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_m), rtol=1e-4, atol=1e-4
    )


@settings(deadline=None)
@given(st.integers(1, N_CH - 1), st.integers(0, 2))
def test_reduction_monotone_in_keep(keep, trim):
    """Keeping one more channel grows the flatten by exactly the kept frame
    count — the reduction is strictly monotone in keep-K."""
    w = PARAMS["conv1"]["w"]
    lo = plan_prune(w, CFG.n_frames, keep=keep, trim_frames=trim)
    hi = plan_prune(w, CFG.n_frames, keep=keep + 1, trim_frames=trim)
    n_frames_kept = CFG.n_frames - trim
    assert lo.flatten_after == n_frames_kept * keep
    assert hi.flatten_after - lo.flatten_after == n_frames_kept
    assert hi.reduction < lo.reduction
    assert 0.0 <= hi.reduction < 1.0
    # the kept set is nested: the top-K channels are a subset of the top-K+1
    assert set(int(c) for c in lo.keep_channels) <= set(
        int(c) for c in hi.keep_channels
    )


@settings(deadline=None)
@given(st.integers(1, N_CH), st.integers(0, 2))
def test_prunespec_dict_round_trip(keep, trim):
    spec = plan_prune(PARAMS["conv1"]["w"], CFG.n_frames, keep=keep, trim_frames=trim)
    back = PruneSpec.from_dict(spec.to_dict())
    np.testing.assert_array_equal(back.keep_channels, spec.keep_channels)
    np.testing.assert_array_equal(back.keep_frames, spec.keep_frames)
    assert back.flatten_before == spec.flatten_before
    assert back.flatten_after == spec.flatten_after
    assert back.cache_key == spec.cache_key
