"""Metamorphic conformance suite: streaming == batched == sharded, bitwise.

The serving layer's scaling story rests on one invariant: a window's
probability depends only on its own row (per-sample activation scales), so
*how* the batch is executed — streamed window-at-a-time, micro-batched,
permuted across slots, or split over a device mesh — can never change the
numbers.  This file pins that invariant:

* slot-permutation metamorphism: permuting the batch rows and unpermuting
  the outputs is the identity, for random loudness mixes;
* the sharded leg runs in a subprocess with
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (simulated devices
  must be configured before jax import, and must never leak into this test
  process), asserting ``streaming == batched == sharded`` bitwise for random
  stream counts/loudness mixes, plus the permutation identity *across shard
  boundaries*.

Fast tier: the subprocess uses the small zcr detector in interpret mode.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import features
from repro.models import cnn1d
from repro.serving.accelerator import accelerator_forward


def _small_detector():
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_permute_unpermute_is_identity():
    """Rows are independent of their co-batch: shuffling slot assignment and
    unshuffling the outputs reproduces the unpermuted run bitwise, even with
    a 10^4 loudness spread across the batch."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(7)
    bsz = 6  # fixed so all trials share one jit trace
    for trial in range(3):
        x = rng.standard_normal((bsz, cfg.input_len)).astype(np.float32)
        x *= (10.0 ** rng.uniform(-2, 2, size=(bsz, 1))).astype(np.float32)
        ref = np.asarray(accelerator_forward(params, jnp.asarray(x), cfg))
        perm = rng.permutation(bsz)
        inv = np.argsort(perm)
        got = np.asarray(accelerator_forward(params, jnp.asarray(x[perm]), cfg))[inv]
        np.testing.assert_array_equal(ref, got)


def test_sharded_forward_single_device_in_process():
    """A 1-way "streams" mesh needs no simulated devices, so the whole
    sharded datapath (mesh helper, replicated placement, shard_map forward)
    runs in-process in the fast tier — and must still be bitwise identical
    to the unsharded forward."""
    from repro.distributed.sharding import stream_mesh
    from repro.serving.accelerator import accelerator_forward_sharded
    from repro.serving.engine import MonitorEngine

    cfg, params = _small_detector()
    rng = np.random.default_rng(3)
    x = rng.standard_normal((4, cfg.input_len)).astype(np.float32)
    mesh = stream_mesh(1)
    ref = np.asarray(accelerator_forward(params, jnp.asarray(x), cfg))
    got = np.asarray(accelerator_forward_sharded(params, jnp.asarray(x), cfg, mesh=mesh))
    np.testing.assert_array_equal(ref, got)

    # the engine's shards=1 route goes through the sharded dispatch too
    # (batch_slots=4 reuses the (4, M) sharded trace from above)
    engine = MonitorEngine(
        params, cfg, n_streams=2, feature_kind="zcr", batch_slots=4, shards=1
    )
    assert engine.shards == 1
    audio = rng.standard_normal((2, 2 * features.N_SAMPLES)).astype(np.float32)
    for s in range(2):
        engine.push(s, audio[s])
    scored = engine.drain()
    assert len(scored) == 4
    for ws in scored:
        s, i = ws.stream, ws.window_idx
        feats = features.batch_features(
            audio[s].reshape(2, features.N_SAMPLES), "zcr"
        )
        p = np.asarray(accelerator_forward(params, jnp.asarray(feats), cfg))[i, 1]
        assert ws.p_uav == np.float64(p)


def test_stream_mesh_rejects_bad_shard_counts():
    import pytest

    from repro.distributed.sharding import stream_mesh

    with pytest.raises(ValueError, match="local devices"):
        stream_mesh(0)
    with pytest.raises(ValueError, match="local devices"):
        stream_mesh(len(jax.devices()) + 1)


SHARDED_SCRIPT = textwrap.dedent(
    """\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.data import features
    from repro.distributed.sharding import stream_mesh
    from repro.models import cnn1d
    from repro.serving.accelerator import accelerator_forward, accelerator_forward_sharded
    from repro.serving.engine import MonitorEngine

    cfg = cnn1d.CNNConfig(input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8)
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    checks = 0

    n_win = 3  # fixed so the per-stream reference forwards share one trace
    for trial in range(1):
        n_streams = int(rng.integers(3, 6))
        audio = rng.standard_normal((n_streams, n_win * features.N_SAMPLES)).astype(np.float32)
        # loudness mix: each stream at its own gain over 4 orders of magnitude
        audio *= (10.0 ** rng.uniform(-2, 2, size=(n_streams, 1))).astype(np.float32)

        # (a) one batched unsharded forward per stream = the reference
        ref = []
        for s in range(n_streams):
            feats = features.batch_features(audio[s].reshape(n_win, features.N_SAMPLES), "zcr")
            ref.append(np.asarray(accelerator_forward(params, jnp.asarray(feats), cfg))[:, 1])

        # (b) streaming through the engine, unsharded vs sharded x{2,4}
        for shards in (None, 2, 4):
            engine = MonitorEngine(
                params, cfg, n_streams=n_streams, feature_kind="zcr",
                batch_slots=4, shards=shards,
            )
            cursors = [0] * n_streams
            scores = {s: [] for s in range(n_streams)}
            while any(c < audio.shape[1] for c in cursors):
                for s in range(n_streams):
                    n = int(rng.uniform(0.3, 1.8) * features.N_SAMPLES)
                    engine.push(s, audio[s, cursors[s] : cursors[s] + n])
                    cursors[s] += n
                for ws in engine.step():
                    scores[ws.stream].append(ws.p_uav)
            for ws in engine.drain():
                scores[ws.stream].append(ws.p_uav)
            assert engine.dropped_samples == 0
            for s in range(n_streams):
                got = np.asarray(scores[s], np.float64)
                assert got.shape == (n_win,)
                np.testing.assert_array_equal(got, ref[s].astype(np.float64))
                checks += 1

    # (c) permutation identity ACROSS shard boundaries: rows change device
    # under the permutation, outputs must still unpermute to the reference.
    mesh = stream_mesh(4)
    x = rng.standard_normal((8, cfg.input_len)).astype(np.float32)
    x *= (10.0 ** rng.uniform(-2, 2, size=(8, 1))).astype(np.float32)
    base = np.asarray(accelerator_forward(params, jnp.asarray(x), cfg))
    sharded = np.asarray(accelerator_forward_sharded(params, jnp.asarray(x), cfg, mesh=mesh))
    np.testing.assert_array_equal(base, sharded)
    perm = rng.permutation(8)  # moves rows between the 4 shards
    inv = np.argsort(perm)
    permuted = np.asarray(
        accelerator_forward_sharded(params, jnp.asarray(x[perm]), cfg, mesh=mesh)
    )[inv]
    np.testing.assert_array_equal(base, permuted)
    checks += 2

    # a batch that does not divide over the shards is rejected loudly
    try:
        accelerator_forward_sharded(params, jnp.asarray(x[:3]), cfg, mesh=mesh)
    except ValueError as e:
        assert "not divisible" in str(e)
        checks += 1
    else:
        raise AssertionError("expected ValueError for 3 rows over 4 shards")
    print("RESULT:" + json.dumps({"ok": True, "checks": checks}))
    """
)


def test_streaming_batched_sharded_bitwise_equal():
    """streaming == batched == sharded (2 and 4 shards), bitwise, for random
    stream counts and loudness mixes — on 4 simulated devices."""
    proc = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT],
        capture_output=True,
        text=True,
        timeout=300,
        cwd=Path(__file__).resolve().parents[1],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    # 3 dispatch modes x >= 3 streams, + the 2 permutation legs + the
    # divisibility rejection
    assert out["ok"] and out["checks"] >= 12
