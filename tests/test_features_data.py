"""DSP feature stack identities + synthetic dataset sanity."""
import numpy as np
import pytest

from repro.data import acoustic, features


def test_feature_dims():
    x = np.random.default_rng(0).standard_normal(features.N_SAMPLES).astype(np.float32)
    for kind, dim in features.FEATURE_DIMS.items():
        v = features.feature_vector(x, kind)
        assert v.shape == (dim,)
        assert np.isfinite(v).all()


def test_mel_filterbank_partition():
    fb = features.mel_filterbank(64)
    assert fb.shape == (64, features.N_FFT // 2 + 1)
    # each filter normalised to unit area; coverage inside the band is dense
    sums = fb.sum(axis=1)
    np.testing.assert_allclose(sums, 1.0, atol=1e-6)


def test_dct_orthonormal():
    m = features.dct_ii(20, 64)
    np.testing.assert_allclose(m @ m.T, np.eye(20), atol=1e-10)


def test_stft_parseval():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(features.N_SAMPLES)
    p = features.stft_power(x)
    assert p.shape[0] == 1 + features.N_SAMPLES // features.HOP
    assert (p >= 0).all()


def test_zcr_pure_tone_vs_noise():
    t = np.arange(features.N_SAMPLES) / features.SR
    tone = np.sin(2 * np.pi * 100 * t)  # 100 Hz -> low ZCR
    noise = np.random.default_rng(2).standard_normal(features.N_SAMPLES)
    assert features.zcr(tone).mean() < features.zcr(noise).mean()


def test_uav_has_harmonic_structure():
    """UAV windows concentrate energy at BPF harmonics vs broadband noise."""
    rng = np.random.default_rng(3)
    uav = acoustic.synth_uav(rng)
    spec = np.abs(np.fft.rfft(uav)) ** 2
    freqs = np.fft.rfftfreq(len(uav), 1 / features.SR)
    band = spec[(freqs > 80) & (freqs < 2000)].sum() / spec.sum()
    assert band > 0.5  # rotor harmonics live in 80-2000 Hz


def test_snr_control():
    rng = np.random.default_rng(4)
    x = acoustic.synth_uav(rng)
    noisy = acoustic.add_noise_snr(x, 10.0, rng)
    n = noisy - x
    snr = 10 * np.log10(np.mean(x**2) / np.mean(n**2))
    assert abs(snr - 10.0) < 1.0


def test_dataset_balance_and_shapes():
    ds = acoustic.make_dataset(64, seed=5)
    assert ds.audio.shape == (64, features.N_SAMPLES)
    frac = ds.labels.mean()
    assert 0.25 < frac < 0.75


def test_snr_sweep_labels_fixed():
    sweep = acoustic.make_snr_sweep(16, [0.0, 10.0], seed=6)
    (_, l0), (_, l1) = sweep[0.0], sweep[10.0]
    np.testing.assert_array_equal(l0, l1)
