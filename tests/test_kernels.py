"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize(
    "m,k,n",
    [(8, 16, 8), (128, 128, 128), (64, 200, 96), (300, 512, 130), (1, 8704, 64), (17, 33, 65)],
)
def test_quant_matmul_exact(m, k, n):
    """int8 x int8 -> int32 path is exact vs the oracle (no fp error)."""
    xq = jnp.asarray(RNG.integers(-128, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(RNG.integers(-128, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(RNG.uniform(0.001, 0.1, (m, 1)), jnp.float32)
    ws = jnp.asarray(RNG.uniform(0.001, 0.1, (1, n)), jnp.float32)
    out = ops.quant_matmul(xq, wq, xs, ws)
    exp = ref.quant_matmul_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (64, 128, 256)])
def test_quant_matmul_block_shapes(bm, bn, bk):
    xq = jnp.asarray(RNG.integers(-128, 128, (200, 300)), jnp.int8)
    wq = jnp.asarray(RNG.integers(-128, 128, (300, 100)), jnp.int8)
    xs = jnp.ones((200, 1), jnp.float32)
    ws = jnp.ones((1, 100), jnp.float32)
    out = ops.quant_matmul(xq, wq, xs, ws, bm=bm, bn=bn, bk=bk)
    exp = ref.quant_matmul_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-6, atol=1e-5)


def test_quant_matmul_f32_wrapper():
    x = jnp.asarray(RNG.standard_normal((32, 64)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((64, 16)) * 0.1, jnp.float32)
    out = ops.quant_matmul_f32(x, w)
    rel = float(jnp.linalg.norm(out - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.02  # W8A8 quantisation error budget
    out_fxp = ops.quant_matmul_f32(x, w, fxp=True)
    rel_fxp = float(jnp.linalg.norm(out_fxp - x @ w) / jnp.linalg.norm(x @ w))
    assert rel_fxp < 0.04 and rel_fxp >= rel * 0.5  # fxp slightly worse


@pytest.mark.parametrize("mode", ["tanh", "sigmoid", "exp", "swish", "gelu", "selu", "relu"])
@pytest.mark.parametrize("shape", [(1000,), (7, 129), (4, 37, 33)])
def test_cordic_modes_shapes(mode, shape):
    x = jnp.asarray(RNG.uniform(-6, 6, shape), jnp.float32)
    y = ops.cordic_activation(x, mode)
    expect = ref.ACT_REFS[mode](x)
    assert y.shape == x.shape
    if mode == "exp":
        np.testing.assert_allclose(np.asarray(y), np.asarray(expect), rtol=3e-4, atol=1e-4)
    else:
        np.testing.assert_allclose(np.asarray(y), np.asarray(expect), atol=2e-3)


def test_cordic_softmax():
    x = jnp.asarray(RNG.uniform(-5, 5, (8, 64)), jnp.float32)
    sm = ops.cordic_softmax(x)
    np.testing.assert_allclose(np.asarray(sm.sum(-1)), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sm), np.asarray(ref.softmax_ref(x)), atol=1e-4)


def test_cordic_fixed_point_domain():
    """Inputs beyond Q15.16 range still behave (clipping, saturation)."""
    x = jnp.asarray([-100.0, -4.5, 4.5, 100.0])
    y = ops.cordic_activation(x, "tanh")
    np.testing.assert_allclose(np.asarray(y), [-1, -1, 1, 1], atol=1e-3)


@pytest.mark.parametrize("b,l,cin,cout,k", [(2, 64, 8, 16, 3), (1, 33, 3, 5, 5)])
def test_conv1d_q_shared_datapath(b, l, cin, cout, k):
    x = jnp.asarray(RNG.standard_normal((b, l, cin)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, cin, cout)) * 0.2, jnp.float32)
    bias = jnp.asarray(RNG.standard_normal(cout), jnp.float32)
    out = ops.conv1d_q(x, w, bias)
    expect = ref.conv1d_q_ref(x, w, bias)
    rel = float(jnp.linalg.norm(out - expect) / jnp.linalg.norm(expect))
    assert out.shape == expect.shape and rel < 0.03
