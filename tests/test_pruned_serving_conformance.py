"""Conformance matrix for pruned + mixed-precision serving artifacts.

Two guarantees pin the deploy-time transforms:

* **pruned-physical == masked-unpruned, bitwise on int32 accumulators** —
  physically removing the pruned conv-out channels / dense rows from the
  artifact produces the same numbers as serving the full-size artifact with
  those channels and rows zeroed.  Because weights are quantised *after*
  pruning in both constructions (zeroed rows do not move a per-column amax),
  the int8 payloads, scales and therefore the kernel's int32 accumulators
  agree exactly — an indexing bug anywhere in the slice/flatten plumbing
  breaks this loudly.

* **streaming == batched == sharded for every artifact cell** — the
  row-independence invariant (per-sample activation scales for the 8-bit
  layer modes, per-row conv/matmul for the float modes) holds for all of
  {pruned, unpruned} x {int8, fxp8, mixed}, so window-at-a-time streaming,
  micro-batching, and 4-way sharded dispatch produce bitwise-identical
  probabilities on every cell.  The sharded leg runs in a subprocess with 4
  simulated devices (the device-count flag must land before jax import).

Fast tier: small zcr detector, interpret mode.
"""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision_policy import Precision, PrecisionPolicy
from repro.core.pruning import PruneSpec, plan_prune
from repro.core.quantization import fxp8_quantize, int8_symmetric
from repro.data import features
from repro.kernels import ops
from repro.models import cnn1d
from repro.serving.accelerator import accelerator_forward
from repro.serving.quantized_params import quantize_params


def _small_detector():
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mixed_policy(default: Precision = Precision.INT8) -> PrecisionPolicy:
    return PrecisionPolicy(
        rules={"conv0/w": Precision.BF16, "dense1/w": Precision.FP32},
        default=default,
    )


#: the precision axis of the matrix: (cell name, default mode, policy)
PRECISION_CELLS = [
    ("int8", "int8", None),
    ("fxp8", "fxp8", None),
    ("mixed", "int8", _mixed_policy()),
]


def _masked_setup(params, cfg, spec: PruneSpec):
    """Full-size params with pruned channels/rows zeroed, plus the frame-only
    spec that applies the same boundary trim without touching channels."""
    n_ch = cfg.channels[-1]
    last = f"conv{len(cfg.channels) - 1}"
    mask = np.zeros(n_ch, np.float32)
    mask[np.asarray(spec.keep_channels)] = 1.0
    masked = {k: dict(v) for k, v in params.items()}
    masked[last]["w"] = params[last]["w"] * mask[None, None, :]
    masked[last]["b"] = params[last]["b"] * mask
    wd = np.asarray(params["dense0"]["w"]).reshape(cfg.n_frames, n_ch, -1).copy()
    dropped = np.setdiff1d(np.arange(n_ch), np.asarray(spec.keep_channels))
    wd[:, dropped, :] = 0.0
    masked["dense0"]["w"] = jnp.asarray(wd.reshape(cfg.flatten_size, -1))
    frame_spec = PruneSpec(
        keep_channels=np.arange(n_ch),
        keep_frames=np.asarray(spec.keep_frames),
        flatten_before=cfg.flatten_size,
        flatten_after=len(spec.keep_frames) * n_ch,
    )
    return masked, frame_spec


@pytest.mark.parametrize("name,mode,policy", PRECISION_CELLS)
def test_pruned_physical_equals_masked_unpruned_bitwise(name, mode, policy):
    """The headline conformance cell: the physically-pruned artifact and the
    masked full-size artifact produce bitwise-identical probabilities on the
    whole deployed datapath, for every precision cell."""
    cfg, params = _small_detector()
    spec = plan_prune(params["conv1"]["w"], cfg.n_frames, keep=3, trim_frames=1)
    masked, frame_spec = _masked_setup(params, cfg, spec)

    qp_pruned = quantize_params(params, cfg, mode=mode, prune=spec, policy=policy)
    qp_masked = quantize_params(masked, cfg, mode=mode, prune=frame_spec, policy=policy)
    assert qp_pruned.pruned and qp_pruned.keep_frames == cfg.n_frames - 1

    rng = np.random.default_rng(3)
    x = rng.standard_normal((6, cfg.input_len)).astype(np.float32)
    x *= (10.0 ** rng.uniform(-2, 2, size=(6, 1))).astype(np.float32)
    p_pruned = np.asarray(accelerator_forward(qp_pruned, jnp.asarray(x), cfg))
    p_masked = np.asarray(accelerator_forward(qp_masked, jnp.asarray(x), cfg))
    np.testing.assert_array_equal(p_pruned, p_masked)


@pytest.mark.parametrize("quant", [int8_symmetric, fxp8_quantize])
def test_dense_prune_int32_accumulator_parity(quant):
    """Accumulator-level form of the guarantee: slicing dense rows physically
    vs zeroing them yields identical int32 accumulators on the W8A8 kernel
    (unit scales make the fp32 output an exact image of the accumulator)."""
    rng = np.random.default_rng(0)
    flatten, keep_n, out = 96, 24, 16
    keep = np.sort(rng.choice(flatten, size=keep_n, replace=False))
    w = rng.standard_normal((flatten, out)).astype(np.float32)
    h_kept = rng.standard_normal((4, keep_n)).astype(np.float32)
    h_masked = np.zeros((4, flatten), np.float32)
    h_masked[:, keep] = h_kept

    # quantise-after-prune on both sides: per-column amax over the surviving
    # rows only (zeroed rows cannot move it), per-sample act scales.
    w_masked = np.zeros_like(w)
    w_masked[keep] = w[keep]
    wq_pruned = quant(jnp.asarray(w[keep]), axis=1)
    wq_masked = quant(jnp.asarray(w_masked), axis=1)
    np.testing.assert_array_equal(
        np.asarray(wq_pruned.scale), np.asarray(wq_masked.scale)
    )
    hq_pruned = quant(jnp.asarray(h_kept), axis=0)
    hq_masked = quant(jnp.asarray(h_masked), axis=0)
    np.testing.assert_array_equal(
        np.asarray(hq_pruned.scale), np.asarray(hq_masked.scale)
    )

    ones_m = jnp.ones((4, 1), jnp.float32)
    ones_n = jnp.ones((1, out), jnp.float32)
    acc_pruned = np.asarray(
        ops.quant_matmul(hq_pruned.q, wq_pruned.q, ones_m, ones_n)
    )
    acc_masked = np.asarray(
        ops.quant_matmul(hq_masked.q, wq_masked.q, ones_m, ones_n)
    )
    np.testing.assert_array_equal(acc_pruned, acc_masked)
    assert np.abs(acc_pruned).max() < 2.0**24  # fp32 carries the int32 exactly


def test_quantize_rejects_non_prefix_frame_subsets():
    """The accelerator serves the frame trim as a prefix slice; a spec whose
    kept frames are not a contiguous prefix would silently disagree with the
    dense rows that were actually kept — it must be rejected at bake time."""
    cfg, params = _small_detector()
    bad = PruneSpec(
        keep_channels=np.arange(cfg.channels[-1]),
        keep_frames=np.arange(1, cfg.n_frames),  # trims the FIRST frame
        flatten_before=cfg.flatten_size,
        flatten_after=(cfg.n_frames - 1) * cfg.channels[-1],
    )
    with pytest.raises(ValueError, match="contiguous prefix"):
        quantize_params(params, cfg, prune=bad)


def test_engine_rejects_prune_policy_on_prebaked_artifact():
    """prune/policy are quantise-once decisions: silently ignoring them on a
    pre-baked artifact would serve the wrong deployment cell."""
    from repro.serving.engine import MonitorEngine

    cfg, params = _small_detector()
    spec = plan_prune(params["conv1"]["w"], cfg.n_frames, keep=3, trim_frames=1)
    qp = quantize_params(params, cfg, mode="int8")
    with pytest.raises(ValueError, match="already-baked"):
        MonitorEngine(qp, cfg, n_streams=1, feature_kind="zcr", prune=spec)
    with pytest.raises(ValueError, match="already-baked"):
        MonitorEngine(
            qp, cfg, n_streams=1, feature_kind="zcr", policy=_mixed_policy()
        )


def test_mixed_artifact_tags_drive_dispatch():
    """The artifact's static tags are the dispatch surface: a mixed artifact
    stores bf16/fp32 layers as plain arrays (no QTensor payload) and 8-bit
    layers as int8 payloads + scales."""
    from repro.core.quantization import QTensor

    cfg, params = _small_detector()
    qp = quantize_params(params, cfg, mode="int8", policy=_mixed_policy())
    assert qp.layer_modes == (("bf16", "int8"), ("int8", "fp32"))
    assert qp.mixed and not qp.pruned
    assert qp.convs[0]["w"].dtype == jnp.bfloat16
    assert isinstance(qp.convs[1]["w"], QTensor)
    assert isinstance(qp.denses[0]["w"], QTensor)
    assert qp.denses[1]["w"].dtype == jnp.float32


MATRIX_SCRIPT = textwrap.dedent(
    """\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.precision_policy import Precision, PrecisionPolicy
    from repro.core.pruning import plan_prune
    from repro.data import features
    from repro.distributed.sharding import stream_mesh
    from repro.models import cnn1d
    from repro.serving.accelerator import accelerator_forward, accelerator_forward_sharded
    from repro.serving.engine import MonitorEngine
    from repro.serving.quantized_params import quantize_params

    cfg = cnn1d.CNNConfig(input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8)
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    spec = plan_prune(params["conv1"]["w"], cfg.n_frames, keep=3, trim_frames=1)
    mixed = PrecisionPolicy(
        rules={"conv0/w": Precision.BF16, "dense1/w": Precision.FP32},
        default=Precision.INT8,
    )
    cells = [
        (prune_name, mode_name, mode, policy)
        for prune_name in ("unpruned", "pruned")
        for mode_name, mode, policy in (
            ("int8", "int8", None), ("fxp8", "fxp8", None), ("mixed", "int8", mixed),
        )
    ]
    mesh = stream_mesh(4)
    rng = np.random.default_rng(17)
    x = rng.standard_normal((4, cfg.input_len)).astype(np.float32)
    x *= (10.0 ** rng.uniform(-2, 2, size=(4, 1))).astype(np.float32)
    # raw 0.8 s windows for the on-device-features leg, same loudness spread
    wr = rng.standard_normal((4, features.N_SAMPLES)).astype(np.float32)
    wr *= (10.0 ** rng.uniform(-2, 2, size=(4, 1))).astype(np.float32)
    checks = 0

    for prune_name, mode_name, mode, policy in cells:
        prune = spec if prune_name == "pruned" else None
        qp = quantize_params(params, cfg, mode=mode, prune=prune, policy=policy)
        batched = np.asarray(accelerator_forward(qp, jnp.asarray(x), cfg))
        # sharded: 4 rows over 4 devices, bitwise
        sharded = np.asarray(
            accelerator_forward_sharded(qp, jnp.asarray(x), cfg, mesh=mesh)
        )
        np.testing.assert_array_equal(batched, sharded, err_msg=f"{prune_name}/{mode_name} sharded")
        # streamed: one row at a time, bitwise
        for i in range(x.shape[0]):
            row = np.asarray(accelerator_forward(qp, jnp.asarray(x[i : i + 1]), cfg))
            np.testing.assert_array_equal(batched[i : i + 1], row, err_msg=f"{prune_name}/{mode_name} row {i}")
        checks += 1 + x.shape[0]

        # on-device-features leg: same cell with the DSP front-end fused
        # into the jitted program — raw windows in, still bitwise across
        # streaming/batched/sharded (features recomputed shard-local).
        qp_dev = quantize_params(
            params, cfg, mode=mode, prune=prune, policy=policy, feature_kind="zcr"
        )
        b_dev = np.asarray(
            accelerator_forward(qp_dev, jnp.asarray(wr), cfg, raw_windows=True)
        )
        s_dev = np.asarray(accelerator_forward_sharded(
            qp_dev, jnp.asarray(wr), cfg, mesh=mesh, raw_windows=True
        ))
        np.testing.assert_array_equal(b_dev, s_dev, err_msg=f"{prune_name}/{mode_name} sharded raw")
        for i in range(wr.shape[0]):
            row = np.asarray(accelerator_forward(
                qp_dev, jnp.asarray(wr[i : i + 1]), cfg, raw_windows=True
            ))
            np.testing.assert_array_equal(b_dev[i : i + 1], row, err_msg=f"{prune_name}/{mode_name} raw row {i}")
        checks += 1 + wr.shape[0]

    # End-to-end engine leg on the deployed configuration (pruned + mixed):
    # uneven chunked delivery, unsharded vs 2-way sharded dispatch, host vs
    # fused front-end, must all reproduce the batched per-stream reference
    # bitwise (host features vs one host-features batched forward; on-device
    # features vs one raw-window batched forward).
    qp_deploy = quantize_params(params, cfg, mode="int8", prune=spec, policy=mixed)
    qp_deploy_dev = quantize_params(
        params, cfg, mode="int8", prune=spec, policy=mixed, feature_kind="zcr"
    )
    n_streams, n_win = 2, 2
    audio = rng.standard_normal((n_streams, n_win * features.N_SAMPLES)).astype(np.float32)
    audio *= (10.0 ** rng.uniform(-2, 2, size=(n_streams, 1))).astype(np.float32)
    ref, ref_dev = [], []
    for s in range(n_streams):
        wins = audio[s].reshape(n_win, features.N_SAMPLES)
        feats = features.batch_features(wins, "zcr")
        ref.append(np.asarray(accelerator_forward(qp_deploy, jnp.asarray(feats), cfg))[:, 1])
        ref_dev.append(np.asarray(accelerator_forward(
            qp_deploy_dev, jnp.asarray(wins), cfg, raw_windows=True
        ))[:, 1])
    for on_device in (False, True):
        for shards in (None, 2):
            engine = MonitorEngine(
                params, cfg, n_streams=n_streams, feature_kind="zcr",
                on_device_features=on_device,
                batch_slots=2, prune=spec, policy=mixed, shards=shards,
            )
            cursors = [0] * n_streams
            scores = {s: [] for s in range(n_streams)}
            while any(c < audio.shape[1] for c in cursors):
                for s in range(n_streams):
                    n = int(rng.uniform(0.4, 1.6) * features.N_SAMPLES)
                    engine.push(s, audio[s, cursors[s] : cursors[s] + n])
                    cursors[s] += n
                for ws in engine.step():
                    scores[ws.stream].append(ws.p_uav)
            for ws in engine.drain():
                scores[ws.stream].append(ws.p_uav)
            assert engine.dropped_samples == 0
            want = ref_dev if on_device else ref
            for s in range(n_streams):
                got = np.asarray(scores[s], np.float64)
                assert got.shape == (n_win,)
                np.testing.assert_array_equal(got, want[s].astype(np.float64))
                checks += 1
    print("RESULT:" + json.dumps({"ok": True, "checks": checks}))
    """
)


def test_matrix_streaming_batched_sharded_bitwise_equal():
    """streaming == batched == sharded (4 simulated devices), bitwise, for
    every {pruned, unpruned} x {int8, fxp8, mixed} artifact cell — each cell
    run twice, on host-extracted features and with the DSP front-end fused
    into the jitted program (raw windows) — plus the engine's pruned+mixed
    deployment end to end in both front-end modes."""
    proc = subprocess.run(
        [sys.executable, "-c", MATRIX_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        cwd=Path(__file__).resolve().parents[1],
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    # 6 cells x 2 front-ends x (1 sharded + 4 streamed rows)
    # + 2 front-ends x 2 engine dispatch modes x 2 streams
    assert out["ok"] and out["checks"] == 68
