"""Concurrent-fleet conformance and the SLO autoscaler loop.

The headline contract of the execution-lane work: a fleet whose workers
run in named lane threads produces per-stream scores and events **bitwise
equal** to the sequential fleet and to one monolithic engine — with and
without a seeded fault plan.  Per-sample activation scales make every
window's score independent of its co-batch, worker engines are isolated
per stream group, and the supervisor defers all fleet-level mutations to
the join point, so thread interleaving has nowhere to leak into the
numbers.

The second half exercises the elasticity actuators the
:class:`~repro.serving.controller.FleetController` drives — spawn, retire,
retune — and the closed SLO loop itself under a bursty arrival schedule:
the controller must scale the fleet up against deferral pressure and back
down when the backlog drains, all bitwise losslessly.
"""
import dataclasses
import threading

import jax
import numpy as np
import pytest

from repro.data import features
from repro.models import cnn1d
from repro.serving.batching import AdmissionPolicy, IngestQueue
from repro.serving.controller import FleetController, SLOTarget
from repro.serving.engine import MonitorEngine, SanitizePolicy
from repro.serving.faults import Fault, FaultClock, FaultPlan
from repro.serving.quantized_params import quantize_params
from repro.serving.supervisor import FleetSupervisor

TRACK_KW = dict(ema_alpha=0.7, enter_threshold=0.02, exit_threshold=0.01,
                min_duration=1)
SUP_KW = dict(feature_kind="zcr", batch_slots=2,
              sanitize=SanitizePolicy(nonfinite="reject"), **TRACK_KW)


@pytest.fixture(scope="module")
def detector():
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, cfg, mode="int8")
    return cfg, qp


def _fleet(detector, n_streams, n_workers, **kw):
    cfg, qp = detector
    return FleetSupervisor(
        qp, cfg, n_streams=n_streams, n_workers=n_workers,
        clock=FaultClock(), dispatch_deadline_s=1.0, **SUP_KW, **kw,
    )


def _scene(rng, n_streams, n_win):
    audio = rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)
    schedule = []
    cursors = [0] * n_streams
    total = audio.shape[1]
    while any(c < total for c in cursors):
        rnd = []
        for s in range(n_streams):
            if cursors[s] >= total:
                continue
            n = int(rng.uniform(0.3, 1.7) * features.N_SAMPLES)
            rnd.append((s, cursors[s], min(total, cursors[s] + n)))
            cursors[s] += n
        schedule.append(rnd)
    return audio, schedule


def _drive(engine, audio, schedule):
    scores = {s: [] for s in range(audio.shape[0])}
    for rnd in schedule:
        for s, lo, hi in rnd:
            engine.push(s, audio[s, lo:hi])
        for ws in engine.step():
            scores[ws.stream].append(ws.p_uav)
    while True:
        scored = engine.step()
        if not scored:
            break
        for ws in scored:
            scores[ws.stream].append(ws.p_uav)
    return scores


def _assert_streams_bitwise(scores, events, ref_scores, ref_events, streams):
    for s in streams:
        np.testing.assert_array_equal(
            np.asarray(scores[s], np.float64),
            np.asarray(ref_scores[s], np.float64),
            err_msg=f"stream {s} scores diverged",
        )
        assert events[s] == ref_events[s], f"stream {s} events diverged"


@pytest.fixture(scope="module")
def lane_scene(detector):
    """Shared 6-stream scene + monolithic-engine baseline."""
    cfg, qp = detector
    rng = np.random.default_rng(51)
    audio, schedule = _scene(rng, 6, 5)
    mono = MonitorEngine(qp, cfg, n_streams=6, **SUP_KW)
    ref_scores = _drive(mono, audio, schedule)
    ref_events = mono.finalize()
    assert sum(len(e) for e in ref_events) > 0
    return audio, schedule, ref_scores, ref_events


# ---------------------------------------------------------------------------
# Headline conformance: lanes == sequential == monolithic, bitwise
# ---------------------------------------------------------------------------


def test_lane_fleet_bitwise_equals_sequential_and_monolithic(
        detector, lane_scene):
    audio, schedule, ref_scores, ref_events = lane_scene
    for n_workers in (2, 3, 6):
        seq = _fleet(detector, 6, n_workers)
        seq_scores = _drive(seq, audio, schedule)
        seq_events = seq.finalize()
        lanes = _fleet(detector, 6, n_workers, lanes="threads")
        lane_scores = _drive(lanes, audio, schedule)
        lane_events = lanes.finalize()
        _assert_streams_bitwise(
            seq_scores, seq_events, ref_scores, ref_events, range(6)
        )
        _assert_streams_bitwise(
            lane_scores, lane_events, ref_scores, ref_events, range(6)
        )
        # fleet counters agree too — lane mode is observationally identical
        np.testing.assert_array_equal(
            lanes.served_windows, seq.served_windows
        )
        np.testing.assert_array_equal(
            lanes.deferred_windows, seq.deferred_windows
        )
        assert lanes.windows_scored == seq.windows_scored
        assert lanes.round == seq.round
        lanes.close()


def test_lane_fleet_bitwise_equals_sequential_under_fault_plans(
        detector, lane_scene):
    """The chaos half of the headline: the same seeded fault plan replayed
    against the sequential and the lane-parallel fleet produces identical
    per-stream output, identical per-worker incident sequences, and (for
    streams untouched by lossy faults) identical output to the fault-free
    monolithic baseline."""
    audio, schedule, ref_scores, ref_events = lane_scene
    handcrafted = FaultPlan([
        Fault("raise_forward", round=1, worker=0, magnitude=2),
        Fault("stall_forward", round=2, worker=1, magnitude=5.0),
        Fault("kill_worker", round=3, worker=2),
        Fault("drop_chunk", round=1, stream=4),
        Fault("jitter_chunk", round=2, stream=0, magnitude=0.4),
    ])
    plans = [handcrafted] + [
        FaultPlan.generate(seed, n_streams=6, n_workers=3,
                           n_rounds=len(schedule), n_faults=5)
        for seed in (0, 1)
    ]
    for plan in plans:
        seq = _fleet(detector, 6, 3, faults=plan)
        seq_scores = _drive(seq, audio, schedule)
        seq_events = seq.finalize()
        lanes = _fleet(detector, 6, 3, faults=plan, lanes="threads")
        lane_scores = _drive(lanes, audio, schedule)
        lane_events = lanes.finalize()
        # lanes == sequential for EVERY stream, faulted ones included
        _assert_streams_bitwise(
            lane_scores, lane_events, seq_scores, seq_events, range(6)
        )
        # both == fault-free monolithic for streams no lossy fault touched
        clean = set(range(6)) - plan.affected_streams
        _assert_streams_bitwise(
            lane_scores, lane_events, ref_scores, ref_events, clean
        )
        # incidents agree per worker (lanes may interleave across workers)
        def per_worker(sup):
            out = {}
            for i in sup.incidents:
                out.setdefault(i["worker"], []).append((i["round"], i["kind"]))
            return out
        assert per_worker(lanes) == per_worker(seq)
        np.testing.assert_array_equal(
            lanes.faulted_chunks, seq.faulted_chunks
        )
        lanes.close()


def test_lane_push_defers_delivery_to_step(detector):
    """Lane-mode push is a non-blocking enqueue: delivery (journal, chunk
    faults, admission) happens at the top of the next step, and close()
    flushes anything still queued instead of dropping it."""
    sup = _fleet(detector, 2, 2, lanes="threads")
    win = np.zeros(features.N_SAMPLES, np.float32)
    assert sup.push(0, win) == 0
    assert len(sup._ingest) == 1
    assert all(len(w.journal) == 0 for w in sup.workers)  # not delivered yet
    with pytest.raises(ValueError, match="out of range"):
        sup.push(9, win)  # range errors still surface at push time
    scored = sup.step()
    assert [ws.stream for ws in scored] == [0]
    assert len(sup._ingest) == 0
    # queued ingest survives close() (delivered, not dropped)
    sup.push(1, win)
    sup.close()
    assert sup._ingest is None
    assert [ws.stream for ws in sup.step()] == [1]


def test_lanes_are_named_threads(detector):
    """Each worker's beat runs on its own named lane thread (the name ties
    faulthandler dumps and fault plans to the worker), not the caller."""
    sup = _fleet(detector, 2, 2, lanes="threads")
    seen = {}
    orig = sup._step_worker

    def spy(w):
        seen[w.idx] = threading.current_thread().name
        return orig(w)

    sup._step_worker = spy
    for s in range(2):
        sup.push(s, np.zeros(features.N_SAMPLES, np.float32))
    sup.step()
    assert seen == {0: "lane-0", 1: "lane-1"}
    health = sup.health()
    assert [h["lane"] for h in health] == ["lane-0", "lane-1"]
    sup.close()


def test_ingest_queue_is_thread_safe():
    q = IngestQueue()
    n_threads, per = 8, 200

    def feed(t):
        for i in range(per):
            q.append((t, i))

    threads = [threading.Thread(target=feed, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    items = q.drain()
    assert len(items) == n_threads * per
    assert len(q) == 0 and q.drain() == []
    # FIFO per producer
    for t in range(n_threads):
        assert [i for tt, i in items if tt == t] == list(range(per))


# ---------------------------------------------------------------------------
# Elasticity actuators: spawn / retire / retune
# ---------------------------------------------------------------------------


def test_spawn_and_retire_mid_scene_are_lossless(detector, lane_scene):
    """Scale up then back down mid-scene: the resized fleet's per-stream
    output stays bitwise equal to the monolithic engine, routing follows
    the streams, and fleet scalar totals are conserved across the split."""
    audio, schedule, ref_scores, ref_events = lane_scene
    sup = _fleet(detector, 6, 1)
    third = len(schedule) // 3
    scores = {s: [] for s in range(6)}

    def play(rounds):
        for rnd in rounds:
            for s, lo, hi in rnd:
                sup.push(s, audio[s, lo:hi])
            for ws in sup.step():
                scores[ws.stream].append(ws.p_uav)

    play(schedule[:third])
    idx = sup.spawn_worker()  # 6 streams on one worker -> split 3/3
    assert idx == 1 and sup.n_live_workers == 2
    assert sup.workers[0].streams == [0, 1, 2]
    assert sup.workers[1].streams == [3, 4, 5]
    assert sup._route[4] == (1, 1)
    assert [i["kind"] for i in sup.incidents] == ["spawn"]

    play(schedule[third : 2 * third])
    assert sup.retire_worker(1)  # fold it back
    assert sup.n_live_workers == 1
    assert sup.workers[0].streams == [0, 1, 2, 3, 4, 5]
    assert [i["kind"] for i in sup.incidents] == ["spawn", "retire"]

    play(schedule[2 * third :])
    while True:
        scored = sup.step()
        if not scored:
            break
        for ws in scored:
            scores[ws.stream].append(ws.p_uav)
    events = sup.finalize()
    _assert_streams_bitwise(scores, events, ref_scores, ref_events, range(6))
    # scalar totals conserved: the spun-off worker started zeroed
    assert sup.windows_scored == 6 * 5


def test_spawn_retire_edge_cases(detector):
    sup = _fleet(detector, 2, 2)
    # retiring below one live worker is refused
    assert sup.retire_worker() is True
    assert sup.retire_worker() is False
    assert sup.n_live_workers == 1
    # the survivor holds everything; a single-stream-per-worker fleet built
    # from 1-stream groups cannot spawn once each worker is down to 1 stream
    solo = _fleet(detector, 2, 2)
    assert solo.workers[0].streams == [0]
    assert solo.spawn_worker() is None  # no donor with >= 2 streams
    # spawning respects lanes: a lane-parallel fleet keeps working after it
    lanes = _fleet(detector, 4, 1, lanes="threads")
    idx = lanes.spawn_worker()
    assert idx == 1
    for s in range(4):
        lanes.push(s, np.zeros(features.N_SAMPLES, np.float32))
    assert sorted(ws.stream for ws in lanes.step()) == [0, 1, 2, 3]
    assert lanes.health()[idx]["lane"] == f"lane-{idx}"
    lanes.close()


def test_retune_admission_updates_every_live_worker(detector):
    sup = _fleet(
        detector, 4, 2,
        admission=AdmissionPolicy(max_per_stream_per_round=1, round_budget=2),
    )
    assert sup.admission.round_budget == 2
    new = dataclasses.replace(sup.admission, round_budget=8)
    sup.retune_admission(new)
    assert sup.admission.round_budget == 8
    for w in sup.workers:
        assert w.engine.admission.round_budget == 8
        assert w.engine.admission.max_streams is None  # fleet-level cap only
    # rebuilds inherit the retuned policy
    sup._revive(sup.workers[0])
    assert sup.workers[0].engine.admission.round_budget == 8


# ---------------------------------------------------------------------------
# The SLO loop: FleetController
# ---------------------------------------------------------------------------


def test_slo_target_validation():
    with pytest.raises(ValueError, match="min_workers"):
        SLOTarget(min_workers=0)
    with pytest.raises(ValueError, match="max_workers"):
        SLOTarget(min_workers=4, max_workers=2)
    with pytest.raises(ValueError, match="round_p95_ms"):
        SLOTarget(round_p95_ms=0.0)
    with pytest.raises(ValueError, match="max_defer_rate"):
        SLOTarget(max_defer_rate=-0.1)


def test_controller_latency_breach_spawns_and_headroom_retires(detector):
    """Unit-level decision ladder with injected latencies: a p95 breach over
    a full window spawns; sustained sub-margin latency retires."""
    sup = _fleet(detector, 4, 1)
    ctrl = FleetController(
        sup, SLOTarget(round_p95_ms=10.0, min_workers=1, max_workers=2),
        window=4, cooldown_rounds=0,
    )
    for _ in range(3):
        assert ctrl.step(50.0) is None  # window not full yet: no evidence
    action = ctrl.step(50.0)
    assert action is not None and action["kind"] == "spawn"
    assert sup.n_live_workers == 2
    for _ in range(4):
        last = ctrl.step(1.0)  # far under margin (0.5 * 10 ms)
    assert last is not None and last["kind"] == "retire"
    assert sup.n_live_workers == 1
    assert [a["kind"] for a in ctrl.actions] == ["spawn", "retire"]


def test_controller_retunes_budget_at_size_cap(detector):
    """At max_workers a defer-rate breach widens the admission budget
    instead of spawning."""
    sup = _fleet(
        detector, 4, 2,
        admission=AdmissionPolicy(round_budget=2),
    )
    ctrl = FleetController(
        sup, SLOTarget(max_defer_rate=0.2, min_workers=1, max_workers=2),
        window=2, cooldown_rounds=0,
    )
    W = features.N_SAMPLES
    rng = np.random.default_rng(61)
    # every stream dumps 3 windows; budget 2/worker defers the rest
    for s in range(4):
        sup.push(s, rng.standard_normal(3 * W).astype(np.float32))
    sup.step()
    action = ctrl.step(1.0)
    assert action is not None and action["kind"] == "retune"
    assert sup.admission.round_budget == 4
    for w in sup.workers:
        assert w.engine.admission.round_budget == 4


def test_controller_retires_stale_heartbeat_worker(detector):
    sup = _fleet(detector, 4, 2)
    ctrl = FleetController(
        sup, SLOTarget(max_heartbeat_age_s=30.0, min_workers=1, max_workers=4),
        window=2, cooldown_rounds=0,
    )
    for s in range(4):
        sup.push(s, np.zeros(features.N_SAMPLES, np.float32))
    sup.step()
    sup.workers[1].last_heartbeat -= 1000.0  # presumed hung
    action = ctrl.step(1.0)
    assert action is not None and action["kind"] == "retire_stale"
    assert action["worker"] == 1
    assert not sup.workers[1].alive
    assert sup.workers[0].streams == [0, 1, 2, 3]


def test_slo_loop_resizes_fleet_losslessly_under_bursty_arrivals(detector):
    """The acceptance-criteria SLO-loop test: under a bursty arrival
    schedule the controller scales the fleet up (spawn) against deferral
    pressure and back down (retire) when the backlog drains — and the
    resized fleet's per-stream output stays bitwise equal to a monolithic
    engine fed the identical schedule.  Autoscaling changes when windows
    are scored, never what they score."""
    cfg, qp = detector
    n_streams, burst_windows = 8, 3
    W = features.N_SAMPLES
    kw = dict(
        capacity_windows=burst_windows + 1,
        admission=AdmissionPolicy(max_per_stream_per_round=1),
    )
    rng = np.random.default_rng(71)
    audio = rng.standard_normal(
        (n_streams, burst_windows * W)
    ).astype(np.float32)

    def run(engine, ctrl=None):
        scores = {s: [] for s in range(n_streams)}
        # two bursty waves: every stream dumps a whole multi-window burst
        # at once, then the fleet drains it over quiet rounds
        for wave in range(2):
            for s in range(n_streams):
                lo = wave * burst_windows * W // 2
                hi = lo + burst_windows * W // 2
                engine.push(s, audio[s, lo:hi])
            for _ in range(6):  # drain rounds (quiet: no new arrivals)
                for ws in engine.step():
                    scores[ws.stream].append(ws.p_uav)
                if ctrl is not None:
                    ctrl.step(1.0)
        while True:
            scored = engine.step()
            if not scored:
                break
            for ws in scored:
                scores[ws.stream].append(ws.p_uav)
        return scores

    mono = MonitorEngine(qp, cfg, n_streams=n_streams, **kw, **SUP_KW)
    ref_scores = run(mono)
    ref_events = mono.finalize()

    sup = _fleet(detector, n_streams, 1, **kw)
    ctrl = FleetController(
        sup,
        SLOTarget(max_defer_rate=0.3, min_workers=1, max_workers=4),
        window=3, cooldown_rounds=1, scale_down_margin=0.5,
    )
    scores = run(sup, ctrl)
    events = sup.finalize()

    kinds = [a["kind"] for a in ctrl.actions]
    assert "spawn" in kinds, f"no scale-up under burst pressure: {kinds}"
    assert "retire" in kinds, f"no scale-down after drain: {kinds}"
    assert max(a["metrics"]["n_live"] for a in ctrl.actions) >= 2
    # losslessness: every window of every stream, bitwise
    assert sum(len(v) for v in scores.values()) == n_streams * burst_windows
    _assert_streams_bitwise(scores, events, ref_scores, ref_events,
                            range(n_streams))
