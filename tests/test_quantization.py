"""Unit + property tests for the quantisation core (paper eqs. 4-8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container: deterministic-example fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.core import quantization as Q


def arrays(min_size=8, max_size=256):
    return st.lists(
        st.floats(-8.0, 8.0, allow_nan=False, width=32), min_size=min_size, max_size=max_size
    ).map(lambda v: jnp.asarray(np.array(v, np.float32)))


class TestPwQ:
    @settings(max_examples=30, deadline=None)
    @given(arrays())
    def test_reconstruction_error_bounded(self, w):
        """PwQ at 8 bits reconstructs within the quantisation step size."""
        q = Q.pwq_quantize(w, 8)
        k = float(Q.pwq_scale(w, 8))
        if k == 0:
            return
        lo, hi = Q.default_clip_bounds(w, 8)
        step = (float(hi) - float(lo)) / 255.0 * k
        assert float(jnp.max(jnp.abs(q - w))) <= step * 0.51 + 1e-6

    @settings(max_examples=20, deadline=None)
    @given(arrays())
    def test_more_bits_never_worse(self, w):
        e8 = float(Q.pwq_error(w, 8))
        e16 = float(Q.pwq_error(w, 16))
        assert e16 <= e8 + 1e-5

    def test_idempotent_on_levels(self):
        w = jnp.linspace(-1, 1, 9)
        q1 = Q.pwq_quantize(w, 8)
        q2 = Q.pwq_quantize(q1, 8)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=2e-2)

    def test_zero_tensor(self):
        q = Q.pwq_quantize(jnp.zeros(16), 8)
        assert float(jnp.max(jnp.abs(q))) == 0.0


class TestPACT:
    @settings(max_examples=30, deadline=None)
    @given(arrays(), st.floats(0.5, 10.0))
    def test_pact_is_clip(self, x, alpha):
        """Paper eq. (7) == clip(x, 0, alpha)."""
        a = jnp.asarray(alpha, jnp.float32)
        np.testing.assert_allclose(
            np.asarray(Q.pact(x, a)), np.clip(np.asarray(x), 0, alpha), rtol=1e-5, atol=1e-5
        )

    def test_quantized_levels(self):
        a = jnp.asarray(6.0)
        xq = Q.pact_quantize(jnp.linspace(-2, 8, 101), a, 8)
        levels = np.asarray(xq) * 255.0 / 6.0
        np.testing.assert_allclose(levels, np.round(levels), atol=1e-3)

    def test_ste_gradient(self):
        x = jnp.asarray([-1.0, 0.5, 3.0, 7.0])
        a = jnp.asarray(6.0)
        g = jax.grad(lambda xx: Q.pact_ste(xx, a, 8).sum())(x)
        np.testing.assert_allclose(np.asarray(g), [0.0, 1.0, 1.0, 0.0])
        ga = jax.grad(lambda aa: Q.pact_ste(x, aa, 8).sum())(a)
        assert float(ga) == 1.0  # only x=7 >= alpha contributes


class TestDeploymentQuant:
    @settings(max_examples=20, deadline=None)
    @given(arrays(min_size=16))
    def test_int8_roundtrip_bound(self, w):
        t = Q.int8_symmetric(w)
        err = float(jnp.max(jnp.abs(t.dequantize() - w)))
        assert err <= float(t.scale.max()) * 0.5 + 1e-6

    @settings(max_examples=20, deadline=None)
    @given(arrays(min_size=16))
    def test_fxp8_scale_power_of_two(self, w):
        t = Q.fxp8_quantize(w)
        e = np.log2(float(t.scale.max()))
        assert abs(e - round(e)) < 1e-5

    @settings(max_examples=20, deadline=None)
    @given(arrays(min_size=16))
    def test_fxp8_scale_dominates_int8_scale(self, w):
        """The FXP8 scale is the smallest power of two >= amax/127, hence
        always >= the INT8 scale (the headroom loss).  (Pointwise error can
        still be *lower* for dyadic-valued tensors — see the statistical
        test below for the generic ordering.)"""
        if float(jnp.max(jnp.abs(w))) == 0.0:
            return
        si = float(Q.int8_symmetric(w).scale)
        sf = float(Q.fxp8_quantize(w).scale)
        assert sf >= si - 1e-12

    def test_fxp8_worse_than_int8_on_gaussians(self):
        """Generic (continuous) weights: FXP8 MSE >= INT8 MSE, on average."""
        rng = np.random.default_rng(0)
        wins = 0
        for _ in range(20):
            w = jnp.asarray(rng.standard_normal(512) * rng.uniform(0.1, 3), jnp.float32)
            ei = float(jnp.linalg.norm(Q.int8_symmetric(w).dequantize() - w))
            ef = float(jnp.linalg.norm(Q.fxp8_quantize(w).dequantize() - w))
            wins += ef >= ei
        assert wins >= 18

    def test_per_channel_scales(self):
        w = jnp.stack([jnp.ones(8) * 0.01, jnp.ones(8) * 100.0], axis=1)
        t = Q.int8_symmetric(w, axis=1)
        assert t.scale.shape == (1, 2)
        np.testing.assert_allclose(np.asarray(t.dequantize()), np.asarray(w), rtol=2e-2)

    def test_bf16_roundtrip(self):
        x = jnp.asarray([1.0, 1.0 + 2**-9])
        r = Q.bf16_round(x)
        assert float(r[0]) == 1.0
        assert float(r[1]) != float(x[1])  # mantissa truncated

    def test_precision_ordering_mse(self):
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
        mses = {p: Q.quantization_mse(w, p) for p in Q.Precision}
        assert mses[Q.Precision.FP32] == 0.0
        assert mses[Q.Precision.BF16] < mses[Q.Precision.INT8] < mses[Q.Precision.FXP8] * 1.001
