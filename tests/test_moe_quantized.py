"""MoE dispatch semantics + LM quantisation feature + serving queue."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # heavyweight tier: scripts/ci.sh --all

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.models import moe as MOE
from repro.models import transformer as T
from repro.models.quantized import (
    default_lm_policy,
    quantize_lm_params,
    quantized_fraction,
)


def _moe_cfg(**kw):
    base = dict(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2, n_kv_heads=2,
        head_dim=8, d_ff=32, vocab=64, pattern=("moe",), n_experts=4, top_k=2,
        param_dtype="float32", act_dtype="float32", remat=False,
    )
    base.update(kw)
    return ArchConfig(**base)


class TestMoE:
    def test_capacity_rounding(self):
        cfg = _moe_cfg()
        assert MOE.capacity(64, cfg) % 8 == 0
        assert MOE.capacity(64, cfg) >= 64 * 2 / 4

    def test_high_capacity_equals_dense_mixture(self):
        """With no drops, scatter-dispatch MoE == explicit per-expert dense
        computation weighted by the normalised top-k gates."""
        cfg = _moe_cfg(capacity_factor=16.0)
        from repro.models.layers import init_from_specs

        p = init_from_specs(jax.random.PRNGKey(0), MOE.moe_specs(cfg), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 16))
        out = MOE.moe_fwd(p, x, cfg)

        # reference: dense mixture
        from repro.models.layers import rmsnorm

        h = rmsnorm(p["norm"], x, cfg.norm_eps).reshape(10, 16)
        logits = h @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        gv, gi = jax.lax.top_k(probs, 2)
        gv = gv / gv.sum(-1, keepdims=True)
        expert_out = jnp.stack(
            [
                (jax.nn.silu(h @ p["wi_gate"][e]) * (h @ p["wi_up"][e])) @ p["wo"][e]
                for e in range(4)
            ]
        )  # (E, T, D)
        ref = jnp.zeros((10, 16))
        for k in range(2):
            ref += gv[:, k, None] * jnp.take_along_axis(
                expert_out, gi[:, k][None, :, None], axis=0
            )[0]
        ref = x + ref.reshape(2, 5, 16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)

    def test_capacity_drops_are_bounded(self):
        """Tiny capacity drops tokens (residual passthrough) but never NaNs."""
        cfg = _moe_cfg(capacity_factor=0.1)
        specs = MOE.moe_specs(cfg)
        from repro.models.layers import abstract_from_specs, init_from_specs

        p = init_from_specs(jax.random.PRNGKey(0), specs, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
        out = MOE.moe_fwd(p, x, cfg)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_load_balance_loss(self):
        logits = jnp.asarray(np.random.default_rng(0).standard_normal((64, 4)), jnp.float32)
        gi = jnp.argmax(logits, -1)
        lb = MOE.load_balance_loss(logits, gi, 4)
        assert float(lb) >= 1.0 - 1e-3  # >= 1 with equality at perfect balance


class TestQuantizedLM:
    def test_policy_pins_sensitive(self):
        cfg = get_config("rwkv6-7b").smoke()
        pol = default_lm_policy(cfg)
        assert pol.precision_for("groups/pos0/rwkv/w_lora_a").value == "bf16"
        assert pol.precision_for("groups/pos0/rwkv/wr").value == "int8"
        assert pol.precision_for("embed/tok").value == "bf16"

    @pytest.mark.parametrize("arch", ["gemma-2b", "olmoe-1b-7b", "rwkv6-7b", "zamba2-7b"])
    def test_quantized_forward_agrees(self, arch):
        cfg = get_config(arch).smoke()
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        qparams = quantize_lm_params(params, default_lm_policy(cfg))
        # zamba2 smoke: the sensitivity policy pins mamba w_in (SSM dynamics)
        # and the shared block dominates the tiny config -> lower floor
        floor = 0.1 if arch == "zamba2-7b" else 0.3
        assert quantized_fraction(qparams) > floor
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)}
        a = T.forward(params, batch, cfg)
        b = T.forward(qparams, batch, cfg)
        agree = float(jnp.mean(jnp.argmax(a, -1) == jnp.argmax(b, -1)))
        assert agree > 0.85, agree


def test_batched_server_smoke():
    from repro.launch.serve import BatchedServer, Request

    cfg = get_config("gemma-2b").smoke()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    server = BatchedServer(cfg, params, batch_slots=2, max_seq=48)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6 + i).astype(np.int32), max_new=4)
        for i in range(3)
    ]
    done = server.serve(reqs)
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)
    assert all((r.out >= 0).all() and (r.out < cfg.vocab).all() for r in done)
