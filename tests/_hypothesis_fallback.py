"""Minimal stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite must collect and run on a bare container (no network, no
dev extras).  This shim implements exactly the surface the property tests
use — ``given``, ``settings`` and the ``st.lists``/``st.floats``/
``st.integers``/``st.sampled_from``/``.map`` strategy combinators — by
running each property against a fixed batch of deterministic pseudo-random
examples.
With the real ``hypothesis`` installed (see requirements-dev.txt) the tests
import it instead and get true shrinking/property search.
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np

_N_EXAMPLES = 12


class _Strategy:
    def __init__(self, gen):
        self._gen = gen  # gen(rng) -> example value

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._gen(rng)))


def _floats(min_value=0.0, max_value=1.0, allow_nan=False, width=64, **_kw):
    del allow_nan, width
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _lists(elements: _Strategy, min_size=0, max_size=10, **_kw):
    def gen(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements._gen(rng) for _ in range(n)]

    return _Strategy(gen)


def _integers(min_value=0, max_value=100, **_kw):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: options[int(rng.integers(0, len(options)))])


st = types.SimpleNamespace(
    floats=_floats, lists=_lists, integers=_integers, sampled_from=_sampled_from
)


def settings(**_kw):
    """No-op decorator factory (no deadline/max_examples machinery here)."""

    def deco(fn):
        return fn

    return deco


def given(*strategies: _Strategy):
    """Run the wrapped test against ``_N_EXAMPLES`` deterministic draws."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(0)
            for _ in range(_N_EXAMPLES):
                drawn = tuple(s._gen(rng) for s in strategies)
                fn(*args, *drawn, **kwargs)

        # Hide the strategy-bound trailing parameters from pytest, which
        # would otherwise look them up as fixtures.
        params = list(inspect.signature(fn).parameters.values())
        kept = params[: len(params) - len(strategies)]
        wrapper.__signature__ = inspect.Signature(kept)
        return wrapper

    return deco
