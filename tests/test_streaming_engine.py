"""Streaming monitor engine: ring buffers, micro-batching, and the central
parity guarantee — windows streamed one at a time through the engine produce
bitwise-identical probabilities and identical track events to one batched
``accelerator_forward`` + scalar tracker over the same windows.

That guarantee rests on per-sample activation scales (each row quantises
independently of its co-batch), so this file is also the regression surface
for the per-tensor-scale bug.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import features
from repro.models import cnn1d
from repro.serving.accelerator import accelerator_forward
from repro.serving.batching import AdmissionPolicy
from repro.serving.engine import MonitorEngine, StreamRing
from repro.serving.tracker import track_stream

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

TRACK_KW = dict(ema_alpha=0.7, enter_threshold=0.02, exit_threshold=0.01, min_duration=1)


def _small_detector():
    cfg = cnn1d.CNNConfig(
        input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8
    )
    params = cnn1d.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# StreamRing
# ---------------------------------------------------------------------------


def test_ring_hop_aligned_windows():
    r = StreamRing(window=10, hop=10, capacity_windows=3)
    assert r.push(np.arange(7)) == 0
    assert r.ready == 0 and r.pop_window() is None
    r.push(np.arange(7, 25))
    assert r.ready == 2
    np.testing.assert_array_equal(r.pop_window(), np.arange(10))
    np.testing.assert_array_equal(r.pop_window(), np.arange(10, 20))
    assert r.pop_window() is None
    r.push(np.arange(25, 30))
    np.testing.assert_array_equal(r.pop_window(), np.arange(20, 30))


def test_ring_overlapping_hop():
    r = StreamRing(window=10, hop=5, capacity_windows=4)
    r.push(np.arange(20))
    assert r.ready == 3
    np.testing.assert_array_equal(r.pop_window(), np.arange(10))
    np.testing.assert_array_equal(r.pop_window(), np.arange(5, 15))
    np.testing.assert_array_equal(r.pop_window(), np.arange(10, 20))


def test_ring_wraparound_many_times():
    r = StreamRing(window=8, hop=8, capacity_windows=2)
    expect = 0
    for chunk in range(40):
        r.push(np.arange(expect + 0, expect + 0 + 8) % 1000)
        w = r.pop_window()
        np.testing.assert_array_equal(w, np.arange(expect, expect + 8) % 1000)
        expect += 8
    assert r.dropped == 0


def test_ring_overflow_drops_oldest_hops():
    r = StreamRing(window=10, hop=10, capacity_windows=2)
    r.push(np.zeros(20))
    assert r.push(np.ones(10)) == 10  # oldest window dropped, hop-aligned
    assert r.dropped == 10 and r.ready == 2
    np.testing.assert_array_equal(r.pop_window(), np.zeros(10))
    np.testing.assert_array_equal(r.pop_window(), np.ones(10))


def test_ring_giant_push_keeps_tail():
    r = StreamRing(window=10, hop=10, capacity_windows=2)
    dropped = r.push(np.arange(55))
    assert dropped == 40  # hop-aligned tail survives
    np.testing.assert_array_equal(r.pop_window(), np.arange(40, 50))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


def test_engine_rejects_mismatched_feature_dim():
    cfg, params = _small_detector()
    with pytest.raises(ValueError, match="feature dim"):
        MonitorEngine(params, cfg, n_streams=1, feature_kind="mfcc20")


def test_ring_and_engine_validate_with_real_exceptions():
    """Constructor validation raises ValueError, not assert — asserts vanish
    under ``python -O`` and the always-on monitor must keep its guardrails."""
    for bad in (dict(window=0, hop=1), dict(window=4, hop=0),
                dict(window=4, hop=2, capacity_windows=0)):
        with pytest.raises(ValueError):
            StreamRing(**bad)
    cfg, params = _small_detector()
    with pytest.raises(ValueError, match="n_streams"):
        MonitorEngine(params, cfg, n_streams=0, feature_kind="zcr")
    with pytest.raises(ValueError, match="batch_slots"):
        MonitorEngine(params, cfg, n_streams=1, feature_kind="zcr", batch_slots=0)


def test_engine_push_rejects_bad_stream_index():
    cfg, params = _small_detector()
    engine = MonitorEngine(params, cfg, n_streams=2, feature_kind="zcr")
    for bad in (-1, 2, 7):
        with pytest.raises(ValueError, match="out of range"):
            engine.push(bad, np.zeros(4, np.float32))


def test_ring_peek_then_advance_equals_pop():
    r = StreamRing(window=10, hop=5, capacity_windows=4)
    r.push(np.arange(20))
    np.testing.assert_array_equal(r.peek_window(), np.arange(10))
    np.testing.assert_array_equal(r.peek_window(), np.arange(10))  # no consume
    r.advance()
    np.testing.assert_array_equal(r.pop_window(), np.arange(5, 15))
    np.testing.assert_array_equal(r.peek_window(), np.arange(10, 20))
    r.advance()
    assert r.peek_window() is None
    with pytest.raises(ValueError, match="advance"):
        r.advance()


def test_step_requeues_on_forward_error():
    """The window-loss/desync regression: a forward that raises mid-round
    must leave rings and tracker untouched, and a retry must produce events
    bitwise identical to a never-faulted run."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(21)
    n_streams, n_win = 3, 5
    audio = rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)

    def run(fail_rounds):
        engine = MonitorEngine(
            params, cfg, n_streams=n_streams, feature_kind="zcr",
            batch_slots=2, **TRACK_KW,
        )
        real_forward = engine._forward
        calls = {"n": 0}

        def flaky(rows):
            calls["n"] += 1
            if calls["n"] in fail_rounds:
                raise RuntimeError("injected forward crash")
            return real_forward(rows)

        engine._forward = flaky
        for s in range(n_streams):
            engine.push(s, audio[s])
        scores: dict[int, list[float]] = {s: [] for s in range(n_streams)}
        done = 0
        while done < n_streams * n_win:
            heads = [r._r for r in engine._rings]
            ema = engine.tracker._ema.copy()
            idx = engine.tracker._idx.copy()
            try:
                scored = engine.step()
            except RuntimeError:
                # nothing consumed: ring read heads and tracker state unmoved
                assert [r._r for r in engine._rings] == heads
                np.testing.assert_array_equal(engine.tracker._ema, ema)
                np.testing.assert_array_equal(engine.tracker._idx, idx)
                continue
            for ws in scored:
                scores[ws.stream].append(ws.p_uav)
            done += len(scored)
        return scores, engine.finalize()

    clean_scores, clean_events = run(fail_rounds=())
    faulty_scores, faulty_events = run(fail_rounds={1, 3, 4})
    assert faulty_scores == clean_scores
    assert faulty_events == clean_events
    # per-stream window indices never desynced: n_win windows each
    assert all(len(v) == n_win for v in faulty_scores.values())


def test_streaming_parity_bitwise_probs_and_events():
    """The acceptance-criteria test: uneven chunked delivery through the
    engine == one batched forward + scalar tracker, bitwise/exactly."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(5)
    n_streams, n_win = 3, 5
    audio = rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)

    engine = MonitorEngine(
        params, cfg, n_streams=n_streams, feature_kind="zcr",
        batch_slots=2, **TRACK_KW,
    )
    cursors = [0] * n_streams
    scores: dict[int, list[float]] = {s: [] for s in range(n_streams)}
    while any(c < audio.shape[1] for c in cursors):
        for s in range(n_streams):
            n = int(rng.uniform(0.2, 1.9) * features.N_SAMPLES)
            engine.push(s, audio[s, cursors[s] : cursors[s] + n])
            cursors[s] += n
        for ws in engine.step():
            scores[ws.stream].append(ws.p_uav)
    for ws in engine.drain():
        scores[ws.stream].append(ws.p_uav)
    events = engine.finalize()
    assert engine.dropped_samples == 0

    total_events = 0
    for s in range(n_streams):
        feats = features.batch_features(
            audio[s].reshape(n_win, features.N_SAMPLES), "zcr"
        )
        # One batched forward over the whole stream at a different batch
        # size: per-sample activation scales make each row's result
        # independent of its co-batch.
        probs = np.asarray(accelerator_forward(params, jnp.asarray(feats), cfg))[:, 1]
        got = np.asarray(scores[s], np.float64)
        assert len(got) == n_win
        np.testing.assert_array_equal(got, probs.astype(np.float64))
        ref_events = track_stream(probs, **TRACK_KW)
        assert events[s] == ref_events
        total_events += len(ref_events)
    assert total_events > 0  # thresholds chosen so events actually occur


def test_engine_micro_batching_pads_dead_slots():
    cfg, params = _small_detector()
    engine = MonitorEngine(
        params, cfg, n_streams=5, feature_kind="zcr", batch_slots=4
    )
    rng = np.random.default_rng(0)
    for s in range(5):
        engine.push(s, rng.standard_normal(features.N_SAMPLES).astype(np.float32))
    scored = engine.step()
    assert len(scored) == 5
    # 5 ready windows / 4 slots -> two forward calls, 3 padded slots
    assert engine.forward_calls == 2
    assert engine.padded_slots == 3
    assert engine.step() == []  # nothing left buffered


def test_engine_on_device_features_streaming_parity():
    """Fused front-end leg of the parity guarantee: raw windows streamed
    through the engine in uneven chunks == one batched raw-window forward,
    bitwise — the feature bits are per-row inside the jitted program."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(9)
    n_streams, n_win = 3, 4
    audio = rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)
    audio *= (10.0 ** rng.uniform(-2, 2, size=(n_streams, 1))).astype(np.float32)

    engine = MonitorEngine(
        params, cfg, n_streams=n_streams, feature_kind="zcr",
        on_device_features=True, batch_slots=2, **TRACK_KW,
    )
    cursors = [0] * n_streams
    scores: dict[int, list[float]] = {s: [] for s in range(n_streams)}
    while any(c < audio.shape[1] for c in cursors):
        for s in range(n_streams):
            n = int(rng.uniform(0.2, 1.9) * features.N_SAMPLES)
            engine.push(s, audio[s, cursors[s] : cursors[s] + n])
            cursors[s] += n
        for ws in engine.step():
            scores[ws.stream].append(ws.p_uav)
    for ws in engine.drain():
        scores[ws.stream].append(ws.p_uav)

    qp = engine._qp
    assert qp.feature_kind == "zcr"
    for s in range(n_streams):
        wins = jnp.asarray(audio[s].reshape(n_win, features.N_SAMPLES))
        probs = np.asarray(
            accelerator_forward(qp, wins, cfg, raw_windows=True)
        )[:, 1]
        np.testing.assert_array_equal(
            np.asarray(scores[s], np.float64), probs.astype(np.float64)
        )


def test_engine_on_device_equals_manual_two_stage():
    """Fusion correctness: the in-graph front-end feeding the datapath is
    bitwise the same as extracting JAX features first and forwarding them."""
    from repro.data import features_jax

    cfg, params = _small_detector()
    rng = np.random.default_rng(2)
    wins = rng.standard_normal((4, features.N_SAMPLES)).astype(np.float32)
    engine = MonitorEngine(
        params, cfg, n_streams=4, feature_kind="zcr",
        on_device_features=True, batch_slots=4,
    )
    for s in range(4):
        engine.push(s, wins[s])
    scored = engine.step()
    feats = features_jax.batch_features_jax(wins, "zcr")
    two_stage = np.asarray(accelerator_forward(engine._qp, feats, cfg))[:, 1]
    got = np.asarray([ws.p_uav for ws in sorted(scored, key=lambda w: w.stream)])
    np.testing.assert_array_equal(got, two_stage.astype(np.float64))


def test_engine_rejects_artifact_without_feature_kind():
    """on_device_features needs the front-end baked into the artifact — a
    plain artifact must be rejected, not silently served on raw samples."""
    cfg, params = _small_detector()
    qp = cnn1d.export_quantized(params, cfg, mode="int8")
    assert qp.feature_kind is None
    with pytest.raises(ValueError, match="baked for"):
        MonitorEngine(
            qp, cfg, n_streams=1, feature_kind="zcr", on_device_features=True
        )


def test_engine_block_buffer_reuse_is_invisible():
    """The preallocated rotating dispatch buffers must behave exactly like
    the old fresh-np.zeros-per-chunk blocks: many rounds with varying ready
    counts (full blocks, partial tails after full blocks) stay bitwise equal
    to a per-stream batched reference, for both inflight depths."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(4)
    n_streams, n_win = 5, 4
    audio = rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)
    ref = {}
    for s in range(n_streams):
        feats = features.batch_features(
            audio[s].reshape(n_win, features.N_SAMPLES), "zcr"
        )
        ref[s] = np.asarray(
            accelerator_forward(params, jnp.asarray(feats), cfg)
        )[:, 1].astype(np.float64)
    for inflight in (1, 2):
        engine = MonitorEngine(
            params, cfg, n_streams=n_streams, feature_kind="zcr",
            batch_slots=2, inflight=inflight,
        )
        # round 1 fills both slots of the last block (5 ready -> 2+2+1: the
        # stale-tail case), later rounds rewrite previously-padded buffers
        scores: dict[int, list[float]] = {s: [] for s in range(n_streams)}
        for w in range(n_win):
            for s in range(n_streams):
                engine.push(s, audio[s, w * features.N_SAMPLES : (w + 1) * features.N_SAMPLES])
        for ws in engine.drain():
            scores[ws.stream].append(ws.p_uav)
        for s in range(n_streams):
            np.testing.assert_array_equal(np.asarray(scores[s], np.float64), ref[s])


def test_engine_dropped_samples_incremental_counter():
    """dropped_samples is maintained incrementally by push() and agrees with
    the per-ring ground truth."""
    cfg, params = _small_detector()
    engine = MonitorEngine(
        params, cfg, n_streams=2, feature_kind="zcr", capacity_windows=2
    )
    rng = np.random.default_rng(0)
    assert engine.dropped_samples == 0
    # overflow stream 0: capacity is 2 windows; push 4 windows' worth
    d = engine.push(0, rng.standard_normal(4 * features.N_SAMPLES).astype(np.float32))
    assert d > 0
    assert engine.dropped_samples == d == sum(r.dropped for r in engine._rings)
    d2 = engine.push(1, rng.standard_normal(3 * features.N_SAMPLES).astype(np.float32))
    assert engine.dropped_samples == d + d2 == sum(r.dropped for r in engine._rings)


def test_engine_serves_from_quantized_artifact():
    """Engine construction from a pre-quantised artifact does zero extra
    weight-quantisation work at serve time."""
    from repro.serving import quantized_params as qpm

    cfg, params = _small_detector()
    qp = cnn1d.export_quantized(params, cfg, mode="int8")
    engine = MonitorEngine(qp, cfg, n_streams=2, feature_kind="zcr")
    before = qpm.quantize_calls
    rng = np.random.default_rng(1)
    for s in range(2):
        engine.push(s, rng.standard_normal(2 * features.N_SAMPLES).astype(np.float32))
    assert len(engine.drain()) == 4
    assert qpm.quantize_calls == before  # weights untouched while serving


# ---------------------------------------------------------------------------
# Adaptive slot sizing + admission control (the shared dispatch core)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=0, max_value=10_000),
    st.sampled_from((2, 4)),
    st.integers(min_value=1, max_value=3),
)
def test_adaptive_slots_bitwise_equal_fixed_any_schedule(
    seed, batch_slots, n_streams
):
    """The elastic-batching property: whatever grow/shrink schedule the
    adaptive slot policy follows over a random push sequence, every
    stream's probability sequence and event list are bitwise identical to
    the fixed-slot engine — per-sample activation scales make each row
    independent of its co-batch, so block shape is unobservable."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(seed)
    n_win = int(rng.integers(2, 5))
    audio = rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)
    engines = [
        MonitorEngine(
            params, cfg, n_streams=n_streams, feature_kind="zcr",
            batch_slots=batch_slots, adaptive_slots=adaptive,
            capacity_windows=n_win + 1, **TRACK_KW,
        )
        for adaptive in (False, True)
    ]
    scores = [{s: [] for s in range(n_streams)} for _ in engines]
    total = audio.shape[1]
    cursors = [0] * n_streams
    while any(c < total for c in cursors):
        for s in range(n_streams):
            # identical uneven delivery to both engines
            chunk = int(rng.uniform(0.2, 2.3) * features.N_SAMPLES)
            lo, hi = cursors[s], min(total, cursors[s] + chunk)
            if lo < hi:
                for e in engines:
                    e.push(s, audio[s, lo:hi])
            cursors[s] = hi
        for e, sc in zip(engines, scores):
            for ws in e.step():
                sc[ws.stream].append(ws.p_uav)
    for e, sc in zip(engines, scores):
        for ws in e.drain():
            sc[ws.stream].append(ws.p_uav)
    for s in range(n_streams):
        np.testing.assert_array_equal(
            np.asarray(scores[0][s], np.float64),
            np.asarray(scores[1][s], np.float64),
        )
    assert engines[0].finalize() == engines[1].finalize()
    # and the adaptive engine never pads more than the fixed one
    assert engines[1].padded_slots <= engines[0].padded_slots


def test_adaptive_slots_dispatch_smaller_blocks():
    """1 live stream on an 8-slot engine: fixed pads 7/8 slots per round,
    adaptive dispatches 1-slot blocks (the headline waste the bench rows
    show at 1 stream)."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(3)
    audio = rng.standard_normal(3 * features.N_SAMPLES).astype(np.float32)
    fixed = MonitorEngine(
        params, cfg, n_streams=1, feature_kind="zcr", batch_slots=8, **TRACK_KW
    )
    adaptive = MonitorEngine(
        params, cfg, n_streams=1, feature_kind="zcr", batch_slots=8,
        adaptive_slots=True, **TRACK_KW,
    )
    assert adaptive.slot_policy.ladder == (1, 2, 4, 8)
    assert adaptive.precompile() == (1, 2, 4, 8)
    for e in (fixed, adaptive):
        e.push(0, audio)
        e.drain()
    assert fixed.padded_slots == 3 * 7
    assert adaptive.padded_slots == 0
    assert adaptive.slot_histogram == {1: 3}


def test_multi_window_rounds_bitwise_equal_classic_beat():
    """max_per_stream_per_round > 1 drains a backlog in fewer rounds but
    must feed each stream's windows to the tracker in the same order —
    scores and events stay bitwise identical to the one-window beat."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(11)
    n_streams, n_win = 3, 6
    audio = rng.standard_normal(
        (n_streams, n_win * features.N_SAMPLES)
    ).astype(np.float32)
    runs = []
    for adm in (None, AdmissionPolicy(max_per_stream_per_round=4)):
        engine = MonitorEngine(
            params, cfg, n_streams=n_streams, feature_kind="zcr",
            batch_slots=4, capacity_windows=n_win, admission=adm, **TRACK_KW,
        )
        for s in range(n_streams):
            engine.push(s, audio[s])
        scores = {s: [] for s in range(n_streams)}
        for ws in engine.drain():
            scores[ws.stream].append(ws.p_uav)
        runs.append((scores, engine.finalize(), engine.rounds))
    (sc_one, ev_one, rounds_one), (sc_multi, ev_multi, rounds_multi) = runs
    for s in range(n_streams):
        np.testing.assert_array_equal(
            np.asarray(sc_one[s], np.float64), np.asarray(sc_multi[s], np.float64)
        )
    assert ev_one == ev_multi
    assert rounds_multi < rounds_one  # the backlog drained in fewer rounds


def test_firehose_cannot_starve_trickle_stream():
    """Depth-fair round budget: a stream with a deep backlog never displaces
    another stream's first window of the round, so the trickle stream's
    window is always scored in the round it becomes ready."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(7)
    adm = AdmissionPolicy(max_per_stream_per_round=4, round_budget=4)
    engine = MonitorEngine(
        params, cfg, n_streams=2, feature_kind="zcr", batch_slots=4,
        capacity_windows=12, admission=adm, **TRACK_KW,
    )
    # firehose: 8 windows buffered up front; trickle: one window per round
    engine.push(0, rng.standard_normal(8 * features.N_SAMPLES).astype(np.float32))
    for _ in range(2):
        engine.push(1, rng.standard_normal(features.N_SAMPLES).astype(np.float32))
        served = {0: 0, 1: 0}
        for ws in engine.step():
            served[ws.stream] += 1
        assert served[1] == 1  # trickle served the round it arrived
        assert served[0] == 3  # firehose fills the rest of the budget
    assert engine.deferred_windows[0] > 0
    assert engine.deferred_windows[1] == 0
    np.testing.assert_array_equal(engine.served_windows, [6, 2])


def test_max_streams_admission_first_come():
    cfg, params = _small_detector()
    rng = np.random.default_rng(5)
    engine = MonitorEngine(
        params, cfg, n_streams=3, feature_kind="zcr", batch_slots=2,
        admission=AdmissionPolicy(max_streams=2), **TRACK_KW,
    )
    win = lambda: rng.standard_normal(features.N_SAMPLES).astype(np.float32)
    engine.push(0, win())
    engine.push(1, win())
    assert engine.push(2, win()) == 0  # over the cap: refused, not scored
    assert engine.refused_chunks[2] == 1
    np.testing.assert_array_equal(engine.admitted, [True, True, False])
    assert sorted(ws.stream for ws in engine.step()) == [0, 1]
    # refusal is sticky, and an unknown stream id still raises
    assert engine.push(2, win()) == 0
    assert engine.refused_chunks[2] == 2
    with pytest.raises(ValueError, match="out of range"):
        engine.push(3, win())


def test_engine_evicts_persistently_overflowing_stream():
    """A stream whose ring overflows in evict_overflow_rounds consecutive
    committed rounds is de-admitted; a stream that overflows once and
    recovers is not."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(9)
    engine = MonitorEngine(
        params, cfg, n_streams=2, feature_kind="zcr", batch_slots=2,
        capacity_windows=1,  # capacity == one window: easy to overflow
        admission=AdmissionPolicy(evict_overflow_rounds=2), **TRACK_KW,
    )
    win = lambda k: rng.standard_normal(k * features.N_SAMPLES).astype(np.float32)
    # round 1: stream 0 overflows (2 windows into capacity 1), stream 1 fine
    engine.push(0, win(2))
    engine.push(1, win(1))
    engine.step()
    assert engine.take_evictions() == []  # one bad round is not persistent
    # round 2: stream 0 overflows again -> evicted; stream 1 keeps serving
    engine.push(0, win(2))
    engine.push(1, win(1))
    engine.step()
    assert engine.take_evictions() == [0]
    np.testing.assert_array_equal(engine.admitted, [False, True])
    assert engine.push(0, win(1)) == 0 and engine.refused_chunks[0] == 1
    engine.push(1, win(1))
    assert [ws.stream for ws in engine.step()] == [1]


def test_ready_windows_incremental_matches_ring_scan():
    """The incremental ready-count must agree with a full ring scan at
    every point of an uneven push/step/overflow/restore sequence."""
    cfg, params = _small_detector()
    rng = np.random.default_rng(13)
    engine = MonitorEngine(
        params, cfg, n_streams=3, feature_kind="zcr", batch_slots=2,
        capacity_windows=2, **TRACK_KW,
    )

    def check():
        np.testing.assert_array_equal(
            engine.ready_windows(),
            np.array([r.ready for r in engine._rings], np.int64),
        )

    check()
    for _ in range(6):
        for s in range(3):
            n = int(rng.uniform(0.2, 2.6) * features.N_SAMPLES)
            engine.push(s, rng.standard_normal(n).astype(np.float32))
            check()
        engine.step()
        check()
    snap = engine.snapshot()
    fresh = MonitorEngine(
        params, cfg, n_streams=3, feature_kind="zcr", batch_slots=2,
        capacity_windows=2, **TRACK_KW,
    )
    fresh.restore(snap)
    np.testing.assert_array_equal(fresh.ready_windows(), engine.ready_windows())
