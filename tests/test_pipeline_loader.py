"""PrefetchingLoader lifecycle: the shutdown-deadlock regression.

The seed worker blocked forever in ``Queue.put`` once the queue filled, and
``close()`` only set a stop flag the worker could never reach — so shutdown
hung any caller that hadn't drained the queue first.
"""
import time

import numpy as np

from repro.data.pipeline import PrefetchingLoader


def _batch(step: int) -> dict:
    return {"x": np.full(4, step, np.float32)}


def test_close_returns_promptly_with_full_queue():
    """Regression: close() must unblock a worker parked in put() and join it."""
    ld = PrefetchingLoader(_batch, prefetch=2)
    deadline = time.time() + 5.0
    while ld._q.qsize() < 2 and time.time() < deadline:
        time.sleep(0.01)  # let the prefetch queue fill; worker now blocks
    t0 = time.perf_counter()
    ld.close()
    assert time.perf_counter() - t0 < 2.0
    assert not ld._thread.is_alive()


def test_close_idempotent_and_iter_terminates_after_close():
    ld = PrefetchingLoader(_batch, prefetch=1)
    time.sleep(0.05)
    ld.close()
    ld.close()
    assert list(ld) == []  # sentinel left behind ends any late consumer


def test_finite_stream_yields_all_batches_then_ends():
    n = 5
    ld = PrefetchingLoader(lambda s: _batch(s) if s < n else None, prefetch=2)
    got = [int(b["x"][0]) for b in ld]
    assert got == list(range(n))
    ld.close()
    assert not ld._thread.is_alive()


def test_batches_arrive_in_order_while_consuming():
    ld = PrefetchingLoader(_batch, prefetch=3)
    it = iter(ld)
    got = [int(next(it)["x"][0]) for _ in range(10)]
    assert got == list(range(10))
    ld.close()
    assert not ld._thread.is_alive()