"""Multi-device correctness of the distributed ops (subprocess: forced
8-device host platform; the main test process stays single-device)."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow  # heavyweight tier: scripts/ci.sh --all

ROOT = Path(__file__).resolve().parents[1]

A2A_SCRIPT = textwrap.dedent(
    """\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ArchConfig
    from repro.models import moe as MOE
    from repro.models.layers import init_from_specs
    from repro.distributed.sharding import ShardingRules, use_rules

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                     n_kv_heads=2, head_dim=8, d_ff=32, vocab=64, pattern=("moe",),
                     n_experts=8, top_k=2, capacity_factor=8.0,
                     param_dtype="float32", act_dtype="float32", remat=False)
    p = init_from_specs(jax.random.PRNGKey(0), MOE.moe_specs(cfg), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 16))
    rules = ShardingRules(mesh)
    with mesh, use_rules(rules):
        dense = jax.jit(lambda p, x: MOE.moe_fwd(p, x, cfg))(p, x)
        a2a = jax.jit(lambda p, x: MOE.moe_fwd_a2a(p, x, cfg))(p, x)
        g1 = jax.jit(jax.grad(lambda p, x: MOE.moe_fwd(p, x, cfg).sum()))(p, x)
        g2 = jax.jit(jax.grad(lambda p, x: MOE.moe_fwd_a2a(p, x, cfg).sum()))(p, x)
    out = {
        "fwd_err": float(jnp.max(jnp.abs(dense - a2a))),
        "grad_err": max(float(jnp.max(jnp.abs(g1[k] - g2[k]))) for k in ("wi_gate", "wo", "router")),
    }
    print("RESULT:" + json.dumps(out))
    """
)

GATHER_SCRIPT = textwrap.dedent(
    """\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.sharding import ShardingRules, use_rules
    from repro.distributed.embedding import embedding_gather

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()).reshape(2, 4), ("data", "model"))
    rules = ShardingRules(mesh)
    V, D = 64, 16
    table = jax.random.normal(jax.random.PRNGKey(0), (V, D))
    ids = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0, V)
    ref = jnp.take(table, ids, axis=0)
    with mesh, use_rules(rules):
        tbl = jax.device_put(table, rules.sharding(("vocab", "embed"), dims=(V, D)))
        out = jax.jit(embedding_gather)(tbl, ids)
    print("RESULT:" + json.dumps({"err": float(jnp.max(jnp.abs(out - ref)))}))
    """
)


def _run(script: str) -> dict:
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, timeout=600, cwd=ROOT
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    return json.loads(line[len("RESULT:"):])


def test_moe_a2a_equals_dense_multidevice():
    out = _run(A2A_SCRIPT)
    assert out["fwd_err"] < 2e-4, out
    assert out["grad_err"] < 1e-4, out


def test_vocab_parallel_embedding_gather():
    out = _run(GATHER_SCRIPT)
    assert out["err"] < 1e-6, out
