"""BatchedServer on the shared continuous-batching core: smoke decode,
queue-order preservation, partial final batches (dead-slot padding), and
adaptive slot sizing.

The LM decode path is *not* batch-composition independent (prompts are
left-padded to the batch's longest prompt with no pad masking), so unlike
the detector suites nothing here asserts cross-batch-size equality — the
contract under test is the queue/slot machinery: every request comes back,
in order, with exactly its ``max_new`` greedy tokens, regardless of how the
queue was cut into blocks.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed.sharding import ShardingRules, use_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import BatchedServer, Request
from repro.models import transformer as T


@pytest.fixture(scope="module")
def lm():
    """Smoke-sized gemma config + params inside the host mesh context."""
    cfg = get_config("gemma-2b").smoke()
    mesh = make_host_mesh()
    rules = ShardingRules(mesh)
    with mesh, use_rules(rules):
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        yield cfg, params, mesh, rules


def _requests(cfg, n, *, seed=0, max_new=5):
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, int(rng.integers(3, 12))).astype(np.int32),
            max_new=max_new,
        )
        for i in range(n)
    ]


def test_serve_smoke_decodes_every_request(lm):
    cfg, params, mesh, rules = lm
    with mesh, use_rules(rules):
        server = BatchedServer(cfg, params, batch_slots=2)
        done = server.serve(_requests(cfg, 4))
    assert len(done) == 4
    for r in done:
        assert r.out is not None and r.out.dtype == np.int32
        assert len(r.out) == r.max_new
        assert ((0 <= r.out) & (r.out < cfg.vocab)).all()


def test_serve_preserves_queue_order(lm):
    cfg, params, mesh, rules = lm
    with mesh, use_rules(rules):
        server = BatchedServer(cfg, params, batch_slots=3)
        done = server.serve(_requests(cfg, 7, seed=1))
    assert [r.rid for r in done] == list(range(7))


def test_serve_partial_final_batch_pads_dead_slots(lm):
    # 5 requests into 4 slots: one full block + one 1-live block whose dead
    # slots must be invisible in the results (no rid=-1 leaks, no extras)
    cfg, params, mesh, rules = lm
    with mesh, use_rules(rules):
        server = BatchedServer(cfg, params, batch_slots=4)
        done = server.serve(_requests(cfg, 5, seed=2))
    assert [r.rid for r in done] == list(range(5))
    assert all(r.rid >= 0 and len(r.out) == r.max_new for r in done)
    assert server.slot_histogram == {4: 2}


def test_serve_single_request_and_respects_per_request_max_new(lm):
    cfg, params, mesh, rules = lm
    with mesh, use_rules(rules):
        server = BatchedServer(cfg, params, batch_slots=4)
        reqs = _requests(cfg, 3, seed=3)
        reqs[0].max_new = 2
        reqs[2].max_new = 7
        done = server.serve(reqs)
        solo = server.serve(_requests(cfg, 1, seed=4))
    assert [len(r.out) for r in done] == [2, 5, 7]
    assert len(solo) == 1 and len(solo[0].out) == solo[0].max_new


def test_serve_deterministic_for_identical_batches(lm):
    # greedy decode over the same blocks must reproduce exactly (the slot
    # machinery adds no hidden state between serve() calls)
    cfg, params, mesh, rules = lm
    with mesh, use_rules(rules):
        server = BatchedServer(cfg, params, batch_slots=2)
        a = server.serve(_requests(cfg, 4, seed=5))
        b = server.serve(_requests(cfg, 4, seed=5))
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.out, rb.out)


def test_serve_adaptive_slots_shrink_tail_blocks(lm):
    cfg, params, mesh, rules = lm
    with mesh, use_rules(rules):
        server = BatchedServer(cfg, params, batch_slots=4, adaptive_slots=True)
        done = server.serve(_requests(cfg, 7, seed=6))
    assert [r.rid for r in done] == list(range(7))
    assert all(len(r.out) == r.max_new for r in done)
    # 7 requests -> one 4-block, one 2-block, one 1-block: zero dead slots
    assert server.slot_histogram == {4: 1, 2: 1, 1: 1}
    assert server._core.padded_slots == 0
