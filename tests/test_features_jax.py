"""On-device DSP front-end (repro.data.features_jax): parity + properties.

Two different contracts are pinned here, and they are deliberately of
different strength:

* **numpy vs JAX parity is tolerance-bounded, NOT bitwise.**  The numpy
  front-end is the float64 oracle; the JAX twin computes in float32 on the
  device.  Each feature kind gets an explicit max-abs-deviation bound
  (``features_jax.PARITY_ATOL``) on the unit-RMS-normalised vectors.  Do not
  "fix" these tests by asserting bitwise equality — it cannot and should not
  hold across the float64/float32 boundary.

* **within the JAX path, feature bits are per-row.**  Row i of the output is
  bitwise-unchanged by co-batch permutation, silence padding, and batch-size
  changes (``lax.map`` gives every row an identical fixed-shape program).
  This is the property the serving layer's streaming == batched == sharded
  guarantee rests on once the front-end is fused into the jitted program.

The standard DSP identities (Parseval, filterbank partition of unity, DCT
orthonormality) are re-run here against the JAX path's float32 constants.
"""
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container: deterministic-example fallback shim
    from _hypothesis_fallback import given, settings, st

from repro.data import acoustic, features, features_jax

KINDS = sorted(features.FEATURE_DIMS)


def _windows(n: int, seed: int, loudness_spread: bool = True) -> np.ndarray:
    """Mixed test corpus: noise, synthetic UAV, background — with a 10^4
    loudness spread (the micro-batching failure mode)."""
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        if i % 3 == 0:
            w = acoustic.synth_uav(rng)
        elif i % 3 == 1:
            w = acoustic.synth_background(rng)
        else:
            w = rng.standard_normal(features.N_SAMPLES)
        rows.append(np.asarray(w, np.float32))
    x = np.stack(rows)
    if loudness_spread:
        x *= (10.0 ** rng.uniform(-2, 2, size=(n, 1))).astype(np.float32)
    return x


# ---------------------------------------------------------------------------
# numpy (float64 oracle) vs JAX (float32) — tolerance-bounded parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_numpy_vs_jax_parity_tolerance(kind):
    """Per-kind tolerance bound of the float32 JAX path against the float64
    numpy oracle.  Tolerance, not bitwise — see module docstring."""
    w = _windows(6, seed=zlib.crc32(kind.encode()))  # deterministic per kind
    ref = features.batch_features(w, kind).astype(np.float64)
    got = np.asarray(features_jax.batch_features_jax(w, kind)).astype(np.float64)
    assert got.shape == ref.shape == (6, features.FEATURE_DIMS[kind])
    dev = np.abs(ref - got).max()
    assert dev < features_jax.PARITY_ATOL[kind], (
        f"{kind}: max|numpy - jax| = {dev:.3e} exceeds the documented "
        f"bound {features_jax.PARITY_ATOL[kind]:.0e}"
    )
    assert np.isfinite(got).all()


def test_silence_window_is_finite_not_parity():
    """The dead-slot padding case: an all-zero window must produce finite
    features on both paths (the in-graph front-end sees padded silence).

    Deliberately NOT a parity check: silence yields a *constant* raw feature
    vector, which zero-mean/unit-RMS normalisation maps to exactly 0 in the
    float64 oracle but — via the float32 mean's rounding residue, amplified
    by the 1/rms — to an arbitrary finite constant on the JAX path.  The
    engine discards dead-slot outputs, so finiteness is the whole contract
    here (PARITY_ATOL applies to real audio windows, which peak-normalise to
    a non-degenerate vector)."""
    z = np.zeros((1, features.N_SAMPLES), np.float32)
    for kind in KINDS:
        ref = features.batch_features(z, kind)
        got = np.asarray(features_jax.batch_features_jax(z, kind))
        assert np.isfinite(ref).all() and np.isfinite(got).all()


# ---------------------------------------------------------------------------
# DSP identities, re-run on the JAX path's constants/ops
# ---------------------------------------------------------------------------


def test_jax_mel_partition_of_unity():
    """Each float32 mel filter keeps unit area after the cast+transpose."""
    fb_t = features_jax._mel32(64)  # (bins, n_mels)
    assert fb_t.shape == (features.N_FFT // 2 + 1, 64)
    np.testing.assert_allclose(fb_t.sum(axis=0), 1.0, atol=1e-5)


def test_jax_dct_orthonormal():
    """The float32 DCT-II constant stays orthonormal to float32 precision."""
    d_t = features_jax._dct32(20, 64)  # (n_in, n_out), transposed
    np.testing.assert_allclose(d_t.T @ d_t, np.eye(20), atol=1e-5)


def test_jax_stft_parseval():
    """Parseval on the JAX STFT: per frame, the one-sided power spectrum
    (doubling the interior bins) equals N_FFT x the windowed-frame energy."""
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    x = rng.standard_normal(features.N_SAMPLES).astype(np.float32)
    p = np.asarray(features_jax._stft_power(jnp.asarray(x[None, :])))[0]
    assert p.shape[0] == 1 + features.N_SAMPLES // features.HOP
    assert (p >= 0).all()
    # reference windowed frames, same gather + window constants
    idx = features_jax._frame_idx(features.N_SAMPLES, features.N_FFT, features.HOP)
    xp = np.pad(x, (features.N_FFT // 2,) * 2, mode="reflect")
    frames = xp[idx] * features_jax._hann32(features.N_FFT)[None, :]
    energy = (frames.astype(np.float64) ** 2).sum(axis=1)
    one_sided = p[:, 0] + p[:, -1] + 2.0 * p[:, 1:-1].sum(axis=1)
    np.testing.assert_allclose(one_sided, features.N_FFT * energy, rtol=1e-4)


def test_jax_zcr_pure_tone_vs_noise():
    import jax.numpy as jnp

    t = np.arange(features.N_SAMPLES) / features.SR
    tone = np.sin(2 * np.pi * 100 * t).astype(np.float32)
    noise = np.random.default_rng(2).standard_normal(features.N_SAMPLES)
    z_tone = np.asarray(features_jax._zcr(jnp.asarray(tone[None, :])))
    z_noise = np.asarray(features_jax._zcr(jnp.asarray(noise[None, :], dtype=np.float32)))
    assert z_tone.mean() < z_noise.mean()


def test_rejects_unknown_kind():
    w = np.zeros((1, features.N_SAMPLES), np.float32)
    with pytest.raises(ValueError, match="unknown feature kind"):
        features_jax.feature_rows(w, "spectrogram2d")


# ---------------------------------------------------------------------------
# Row independence: feature bits never depend on the co-batch
# ---------------------------------------------------------------------------


def _assert_row_independent(batch: int, seed: int):
    """For every kind, row i's feature vector is bitwise-unchanged by
    (a) co-batch permutation, (b) silence padding to a larger batch, and
    (c) extraction at a different batch size."""
    w = _windows(batch, seed=seed)
    for kind in KINDS:
        base = np.asarray(features_jax.batch_features_jax(w, kind))
        rng = np.random.default_rng(seed + 1)
        perm = rng.permutation(batch)
        permuted = np.asarray(features_jax.batch_features_jax(w[perm], kind))
        np.testing.assert_array_equal(base[perm], permuted, err_msg=f"{kind} perm")
        padded_in = np.concatenate(
            [w, np.zeros((2, features.N_SAMPLES), np.float32)]
        )
        padded = np.asarray(features_jax.batch_features_jax(padded_in, kind))
        np.testing.assert_array_equal(base, padded[:batch], err_msg=f"{kind} pad")
        solo = np.asarray(features_jax.batch_features_jax(w[:1], kind))
        np.testing.assert_array_equal(base[:1], solo, err_msg=f"{kind} batch-of-1")


def test_row_independence_smoke():
    """Fast-tier leg of the row-independence guarantee: one deterministic
    batch, all kinds, all three co-batch transformations."""
    _assert_row_independent(batch=4, seed=7)


@pytest.mark.slow
@settings(deadline=None, max_examples=8)
@given(st.integers(2, 6), st.integers(0, 2**16))
def test_row_independence_property(batch, seed):
    """Property form over random batch sizes/content (each example compiles
    fresh batch shapes for every kind — full-tier only)."""
    _assert_row_independent(batch, seed)


def test_numpy_oracle_constants_are_cached():
    """The oracle path's constants are built once, not per window
    (mirroring mel_filterbank's cache)."""
    assert features.dct_ii(20, 64) is features.dct_ii(20, 64)
    assert features._hann(features.N_FFT) is features._hann(features.N_FFT)
    assert features._hann(1024) is not features._hann(512)
