"""Generate the data-driven tables of EXPERIMENTS.md from artifacts/.

Usage: PYTHONPATH=src python scripts/gen_experiments.py
Writes markdown fragments under artifacts/fragments/ which EXPERIMENTS.md
includes verbatim (regenerate after new dry-runs/hillclimbs).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.roofline import cell_roofline  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "artifacts" / "dryrun"
FRAG = ROOT / "artifacts" / "fragments"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "phi3.5-moe-42b-a6.6b", "olmoe-1b-7b", "phi4-mini-3.8b", "gemma3-12b",
    "h2o-danube-3-4b", "gemma-2b", "rwkv6-7b", "zamba2-7b", "hubert-xlarge",
    "internvl2-1b",
]


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/1e9:.2f}GB" if b >= 1e8 else f"{b/1e6:.1f}MB"


def fmt_t(t):
    return f"{t*1e3:.2f}" if t is not None else "-"


def load(tag=""):
    recs = {}
    for p in sorted(DRY.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("tag", "") != tag:
            continue
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def dryrun_table(recs) -> str:
    lines = [
        "| arch | shape | mesh | status | compile fit/cost (s) | per-dev FLOPs (cost) | coll bytes/chip | fit peak (TPU est) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mesh in ("pod_16x16", "multipod_2x16x16"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | |")
                    continue
                if r["status"] == "skip":
                    lines.append(f"| {arch} | {shape} | {mesh} | skip: {r['reason'][:42]} | | | | |")
                    continue
                fit = r["variants"].get("fit", {})
                cost = r["variants"].get("cost", {})
                if "error" in fit or "error" in cost:
                    err = (fit.get("error") or cost.get("error", ""))[:60]
                    lines.append(f"| {arch} | {shape} | {mesh} | ERROR {err} | | | | |")
                    continue
                peak = fit.get("memory", {}).get("tpu_peak_bytes_est")
                fits = "✓" if peak is not None and peak < 16e9 else "✗"
                has_cost = "compile_s" in cost
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | {fit.get('compile_s','-')}/{cost.get('compile_s','-')} "
                    f"| {cost['flops_per_device']:.2e} | {fmt_bytes(cost['collectives']['total_bytes'])} "
                    if has_cost
                    else f"| {arch} | {shape} | {mesh} | ok (fit-only) | {fit.get('compile_s','-')}/- | - | - "
                )
                lines[-1] += f"| {fmt_bytes(peak)} {fits} |"
    return "\n".join(lines)


def roofline_table(recs, mesh="pod_16x16") -> str:
    lines = [
        "| arch | shape | compute (ms) | memory (ms) | collective (ms) | dominant | useful % | roofline frac % | fits 16GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if not r or r.get("status") != "ok":
                continue
            c = cell_roofline(r)
            if not c:
                continue
            lines.append(
                f"| {arch} | {shape} | {fmt_t(c['t_compute_s'])} | {fmt_t(c['t_memory_s'])} "
                f"| {fmt_t(c['t_collective_s'])} | **{c['dominant']}** "
                f"| {c['useful_ratio']*100:.1f} | {c['roofline_fraction']*100:.1f} "
                f"| {'✓' if c['fits_16gb'] else '✗'} ({c['tpu_peak_gb']:.1f}GB) |"
            )
    return "\n".join(lines)


def main():
    FRAG.mkdir(parents=True, exist_ok=True)
    recs = load()
    (FRAG / "dryrun_table.md").write_text(dryrun_table(recs))
    (FRAG / "roofline_table.md").write_text(roofline_table(recs))
    n_ok = sum(1 for r in recs.values() if r["status"] == "ok")
    n_skip = sum(1 for r in recs.values() if r["status"] == "skip")
    n_err = len(recs) - n_ok - n_skip
    print(f"fragments written: {n_ok} ok, {n_skip} skip, {n_err} err, {len(recs)} total cells")


if __name__ == "__main__":
    main()
