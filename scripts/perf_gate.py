#!/usr/bin/env python
"""Perf-regression gate: compare a fresh bench JSON against the committed
baseline (``BENCH_kernels.json``) and exit nonzero on regression.

The gate compares speedup *ratios* (``speedup_vs_im2col``,
``speedup_vs_numpy``, …: any ``speedup_vs*`` field), never absolute
microseconds — container timing noise moves both sides of a ratio together,
so the ratio is stable where absolutes swing ~±30% run to run.  A fresh
ratio is a regression when it falls below ``baseline * (1 - band)``.

Rules:

* fresh row + baseline row both carry a ratio key  -> gated (band applies)
* fresh row absent from the baseline                -> allowed (new bench;
  reported so the baseline gets regenerated, never a failure)
* ``--require GLOB`` (repeatable): every glob must match at least one
  *gated-or-new* fresh row name — this is the bite that catches a bench
  silently dropping a row (the regression the old eyeball-diff missed)
* env fingerprint mismatch between fresh and baseline rows -> warning only
  (the fingerprint names the environment; a mismatch explains a surprise,
  it is not itself a failure)

Usage (what ``scripts/ci.sh`` runs)::

    SMOKE=1 BENCH_OUT=/tmp/fresh.json python -m benchmarks.bench_kernels
    python scripts/perf_gate.py --fresh /tmp/fresh.json \
        --require 'kernels/conv_layer_fused_*' \
        --require 'kernels/frontend_jax_*'
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import sys
from pathlib import Path

#: default noise band on ratio comparisons (PR-4 measurement: container
#: wall-clock noise is ~±30%; ratios cancel most of it, the band absorbs
#: the rest)
DEFAULT_BAND = 0.30

RATIO_PREFIX = "speedup_vs"


def _ratio_keys(rec: dict) -> list[str]:
    return sorted(k for k in rec if k.startswith(RATIO_PREFIX))


def compare(
    fresh: dict, baseline: dict, *, band: float = DEFAULT_BAND,
    require: list[str] | None = None,
) -> dict:
    """Pure comparison: returns ``{"failures": [...], "warnings": [...],
    "checked": [...], "new": [...]}`` — the CLI turns failures into exit 1."""
    failures: list[str] = []
    warnings: list[str] = []
    checked: list[str] = []
    new: list[str] = []
    for name, rec in sorted(fresh.items()):
        keys = _ratio_keys(rec)
        if not keys:
            continue
        base = baseline.get(name)
        if base is None:
            new.append(name)
            continue
        bfp, ffp = base.get("env_fingerprint"), rec.get("env_fingerprint")
        if bfp and ffp and bfp != ffp:
            warnings.append(
                f"{name}: env fingerprint changed {bfp} -> {ffp} "
                "(rows measured in different pinned environments)"
            )
        for key in keys:
            b, f = base.get(key), rec.get(key)
            if b is None or f is None:
                continue
            floor = b * (1.0 - band)
            if f < floor:
                failures.append(
                    f"{name}.{key}: {f:.3f} < {floor:.3f} "
                    f"(baseline {b:.3f}, band {band:.0%})"
                )
            else:
                checked.append(f"{name}.{key}: {f:.3f} vs baseline {b:.3f} ok")
    for pat in require or []:
        hits = [n for n in fresh if fnmatch.fnmatch(n, pat) and _ratio_keys(fresh[n])]
        if not hits:
            failures.append(
                f"required row pattern {pat!r} matched no fresh row with a "
                f"{RATIO_PREFIX}* field (bench silently dropped it?)"
            )
    return {"failures": failures, "warnings": warnings, "checked": checked, "new": new}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True, help="fresh bench JSON (BENCH_OUT)")
    ap.add_argument(
        "--baseline", default="BENCH_kernels.json",
        help="committed baseline JSON (default: %(default)s)",
    )
    ap.add_argument(
        "--band", type=float, default=DEFAULT_BAND,
        help="allowed fractional drop in a ratio before it fails "
             "(default: %(default)s)",
    )
    ap.add_argument(
        "--require", action="append", default=[], metavar="GLOB",
        help="fail unless at least one gated fresh row matches (repeatable)",
    )
    args = ap.parse_args(argv)

    fresh_path, base_path = Path(args.fresh), Path(args.baseline)
    if not fresh_path.exists():
        print(f"perf_gate: fresh file {fresh_path} missing", file=sys.stderr)
        return 2
    if not base_path.exists():
        print(f"perf_gate: baseline {base_path} missing", file=sys.stderr)
        return 2
    result = compare(
        json.loads(fresh_path.read_text()),
        json.loads(base_path.read_text()),
        band=args.band, require=args.require,
    )
    for line in result["checked"]:
        print(f"perf_gate: {line}")
    for name in result["new"]:
        print(f"perf_gate: {name}: new row (not in baseline) — allowed")
    for line in result["warnings"]:
        print(f"perf_gate: WARNING: {line}")
    for line in result["failures"]:
        print(f"perf_gate: FAIL: {line}", file=sys.stderr)
    if result["failures"]:
        print(
            f"perf_gate: {len(result['failures'])} failure(s) vs {base_path} "
            f"(band {args.band:.0%}). If the change is intentional, regenerate "
            "the baseline: python -m benchmarks.bench_kernels && SMOKE=1 "
            "BENCH_OUT=BENCH_kernels.json BENCH_MERGE=1 python -m benchmarks.bench_kernels",
            file=sys.stderr,
        )
        return 1
    print(
        f"perf_gate: OK — {len(result['checked'])} ratio(s) within "
        f"{args.band:.0%} of baseline, {len(result['new'])} new row(s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
