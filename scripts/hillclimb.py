"""Perf hillclimb driver: lower tagged variants of the three target cells and
record roofline deltas vs baseline.

Targets (selection in EXPERIMENTS.md §4.1):
  olmoe-1b-7b  x train_4k     — worst useful-FLOPs ratio (MoE dispatch)
  rwkv6-7b     x prefill_32k  — most collective-bound
  gemma-2b     x train_4k     — paper-technique representative (quant + embed)

Usage: PYTHONPATH=src python scripts/hillclimb.py [--only <tag>]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import sys  # noqa: E402
from pathlib import Path  # noqa: E402

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.dryrun import run_cell  # noqa: E402

VARIANTS = [
    # (arch, shape, tag, kwargs)
    ("olmoe-1b-7b", "train_4k", "a2a",
     dict(cfg_overrides={"moe_impl": "a2a"})),
    ("olmoe-1b-7b", "train_4k", "a2a_int8",
     dict(cfg_overrides={"moe_impl": "a2a"}, quantize=True)),
    ("rwkv6-7b", "prefill_32k", "residfix",
     dict()),  # code-level change: per-head GroupNorm + constrained WKV scan
    ("rwkv6-7b", "prefill_32k", "residfix_int8",
     dict(quantize=True)),
    ("gemma-2b", "train_4k", "shembed",
     dict(cfg_overrides={"sharded_embed_gather": True})),
    ("gemma-2b", "train_4k", "shembed_int8",
     dict(cfg_overrides={"sharded_embed_gather": True}, quantize=True)),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--variants", default="cost")
    args = ap.parse_args()
    for arch, shape, tag, kw in VARIANTS:
        if args.only and args.only != tag:
            continue
        print(f"\n##### {arch} x {shape} [{tag}] #####")
        run_cell(arch, shape, False, variants=tuple(args.variants.split(",")), tag=tag, **kw)


if __name__ == "__main__":
    main()
