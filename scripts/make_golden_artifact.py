"""Regenerate the committed golden serving artifacts under artifacts/golden/.

The golden set pins the *deployed* numerics across PRs: a seeded tiny
detector is baked into serving artifacts (one plain int8, one with the full
deployment configuration — structured prune + mixed per-layer precision),
and the expected class probabilities on a fixed input batch are stored next
to them.  ``tests/test_golden_artifact.py`` replays the artifacts through
``accelerator_forward`` and fails loudly on any drift.

Run this ONLY when a numerics change is intentional, then commit the diff:

    PYTHONPATH=src python scripts/make_golden_artifact.py
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.precision_policy import Precision, PrecisionPolicy  # noqa: E402
from repro.core.pruning import plan_prune  # noqa: E402
from repro.data import features  # noqa: E402
from repro.models import cnn1d  # noqa: E402
from repro.serving.accelerator import accelerator_forward  # noqa: E402
from repro.serving.quantized_params import quantize_params, save_artifact  # noqa: E402

GOLDEN = Path(__file__).resolve().parents[1] / "artifacts" / "golden"

#: seeded tiny detector — small enough to commit, big enough to exercise
#: every layer kind (conv stack, both denses, softmax head)
CFG = cnn1d.CNNConfig(input_len=features.FEATURE_DIMS["zcr"], channels=(4, 8), hidden=8)
PARAM_SEED = 42
INPUT_SEED = 1234
N_ROWS = 8
PRUNE_KEEP = 3
PRUNE_TRIM = 1


def build_cells(params):
    spec = plan_prune(
        params["conv1"]["w"], CFG.n_frames, keep=PRUNE_KEEP, trim_frames=PRUNE_TRIM
    )
    mixed = PrecisionPolicy(
        rules={"conv0/w": Precision.BF16, "dense1/w": Precision.FP32},
        default=Precision.INT8,
    )
    return {
        "int8": quantize_params(params, CFG, mode="int8"),
        "pruned_mixed": quantize_params(
            params, CFG, mode="int8", prune=spec, policy=mixed
        ),
        # on-device-features cell: the DSP front-end is part of the deployed
        # program, so its numerics are part of the pinned surface too
        "int8_ondevice": quantize_params(
            params, CFG, mode="int8", feature_kind="zcr"
        ),
    }


def main():
    GOLDEN.mkdir(parents=True, exist_ok=True)
    params = cnn1d.init_params(jax.random.PRNGKey(PARAM_SEED), CFG)
    rng = np.random.default_rng(INPUT_SEED)
    x = rng.standard_normal((N_ROWS, CFG.input_len)).astype(np.float32)
    x *= (10.0 ** rng.uniform(-2, 2, size=(N_ROWS, 1))).astype(np.float32)
    np.save(GOLDEN / "input.npy", x)
    # raw 0.8 s windows for the on-device-features cell (fused front-end)
    w = rng.standard_normal((N_ROWS, features.N_SAMPLES)).astype(np.float32)
    w *= (10.0 ** rng.uniform(-2, 2, size=(N_ROWS, 1))).astype(np.float32)
    np.save(GOLDEN / "input_windows.npy", w)
    for name, qp in build_cells(params).items():
        save_artifact(GOLDEN / f"detector_{name}.npz", qp)
        raw = qp.feature_kind is not None
        # interpret=True: the expected numbers are the interpreter-mode (CPU
        # reference) numerics, the sign-off surface the tests replay.
        probs = accelerator_forward(
            qp, jnp.asarray(w if raw else x), CFG,
            interpret=True, raw_windows=raw,
        )
        np.save(GOLDEN / f"expected_{name}.npy", np.asarray(probs))
        print(f"golden: wrote detector_{name}.npz + expected_{name}.npy")
    print(f"golden: artifacts under {GOLDEN}")


if __name__ == "__main__":
    main()
