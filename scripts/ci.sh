#!/usr/bin/env bash
# CI entrypoint: dev deps (best effort — the container may be offline), the
# fast test tier, then a ~30s benchmark smoke at the smallest shapes.
#
#   scripts/ci.sh         fast tier (-m "not slow"): < ~2 min
#   scripts/ci.sh --all   full tier-1 suite incl. @slow kernel-parity /
#                         multi-device / LM-architecture tests (~5-6 min)
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev extras are optional: the suite falls back to tests/_hypothesis_fallback.py.
pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "ci: pip install skipped (offline container); using test fallbacks"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=(-m "not slow")
if [[ "${1:-}" == "--all" ]]; then
  MARK=()  # full tier-1 verify (ROADMAP.md)
fi
# ${MARK[@]+...}: empty-array expansion is fatal under `set -u` on bash < 4.4
python -m pytest -x -q ${MARK[@]+"${MARK[@]}"}

# Benchmark smoke: smallest shapes only, proves the kernel + serving paths
# still run end-to-end (does not touch the committed BENCH_*.json files).
SMOKE=1 python -m benchmarks.bench_kernels
SMOKE=1 python -m benchmarks.bench_serving
