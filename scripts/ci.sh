#!/usr/bin/env bash
# CI entrypoint: dev deps (best effort — the container may be offline), the
# fast test tier, then a ~30s benchmark + sharded-driver smoke at the
# smallest shapes.
#
#   scripts/ci.sh         fast tier (-m "not slow"): < ~2 min
#   scripts/ci.sh --all   full tier-1 suite incl. @slow kernel-parity /
#                         multi-device / LM-architecture tests (~5-6 min)
#   scripts/ci.sh --cov   fast tier with statement coverage over the
#                         serving package (repro.serving) plus the deploy-
#                         time transform modules (repro.core.pruning,
#                         repro.core.precision_policy), fails under 85%
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev extras are optional: the suite falls back to tests/_hypothesis_fallback.py.
pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "ci: pip install skipped (offline container); using test fallbacks"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# All throwaway artifacts (bench JSON, fault plan, crash-restart state dir)
# are created up front and reaped by one EXIT trap, so no failure path —
# a red perf gate, a hung smoke, a mid-script ^C — leaks a /tmp file.
BENCH_FRESH="$(mktemp /tmp/ci_bench_fresh.XXXXXX.json)"
FAULT_PLAN="$(mktemp /tmp/ci_fault_plan.XXXXXX.json)"
STATE_DIR="$(mktemp -d /tmp/ci_state_dir.XXXXXX)"
trap 'rm -rf "$BENCH_FRESH" "$FAULT_PLAN" "$STATE_DIR"' EXIT

MARK=(-m "not slow")
COV=()
case "${1:-}" in
  --all)
    MARK=()  # full tier-1 verify (ROADMAP.md)
    ;;
  --cov)
    if python -c "import pytest_cov" 2>/dev/null; then
      COV=(--cov=repro.serving --cov=repro.serving.batching
           --cov=repro.serving.controller
           --cov=repro.core.pruning
           --cov=repro.core.precision_policy --cov=repro.data.features_jax
           --cov=repro.kernels.tiling
           --cov-report=term-missing --cov-fail-under=85)
    else
      echo "ci: pytest-cov unavailable (offline container); running without coverage" >&2
    fi
    ;;
esac
# ${ARR[@]+...}: empty-array expansion is fatal under `set -u` on bash < 4.4
python -m pytest -x -q ${MARK[@]+"${MARK[@]}"} ${COV[@]+"${COV[@]}"}

# Chaos suite under a hard wall-clock cap: a hung supervisor recovery (a
# revive loop that never converges, a stall that deadlocks a worker) is
# exactly the regression this suite exists to catch, and a hang must fail
# CI loudly, not eat the job timeout.  faulthandler dumps all thread stacks
# when `timeout` sends SIGINT so the hang site lands in the CI log.
timeout --signal=INT 300 python -X faulthandler -m pytest -x -q \
  tests/test_fault_tolerance.py tests/test_lane_fleet.py

# Benchmark smoke + perf gate: smallest shapes under the pinned bench env,
# written to a throwaway JSON, then the speedup *ratios* (fused-vs-im2col,
# jax-vs-numpy — ratios, because absolute µs swing ~±30% in the container)
# are gated against the committed BENCH_kernels.json.  --require makes the
# gate bite on a bench that silently drops a row.
SMOKE=1 BENCH_OUT="$BENCH_FRESH" python -m benchmarks.bench_kernels
python scripts/perf_gate.py --fresh "$BENCH_FRESH" \
  --require 'kernels/conv_layer_fused_*' \
  --require 'kernels/frontend_jax_*'
SMOKE=1 python -m benchmarks.bench_serving

# Sharded-driver smoke: the --shards path boots 2 simulated devices and
# must produce windows end-to-end (random weights: plumbing only, fast).
python -m repro.launch.monitor --seconds 2 --shards 2 --random

# Pruned-serving smoke: the deployed configuration (structured prune +
# mixed per-layer precision baked into the artifact) end-to-end through the
# monitor driver (random weights: plumbing only, fast).
python -m repro.launch.monitor --seconds 2 --prune 2 \
  --policy "conv0/w=bf16,dense1/w=fp32" --random

# On-device front-end smoke: raw-window dispatch with the DSP front-end
# fused into the jitted program (random weights: plumbing only, fast).
python -m repro.launch.monitor --seconds 2 --device-features --random

# High-stream adaptive smoke: 256 streams through the shared dispatch core
# on the adaptive slot ladder — proves the fleet-scale admission/fairness
# path boots and drains end-to-end, capped so a ladder-retrace or ready-
# scan regression fails loudly instead of eating the job timeout.
timeout --signal=INT 300 python -m repro.launch.monitor --seconds 2 \
  --streams 256 --adaptive-slots --random

# Fault-injection demo smoke: a seeded plan (crashes, stalls, kills, chunk
# faults) through the fleet supervisor; the driver must survive every
# incident and print the incident log (random weights: plumbing only).
python -m repro.serving.faults --seed 7 --streams 3 --workers 2 \
  --rounds 12 --out "$FAULT_PLAN"
timeout --signal=INT 300 python -m repro.launch.monitor --seconds 2 \
  --workers 2 --faults "$FAULT_PLAN" --random

# Concurrent-fleet smoke: all four workers' rounds run on named execution
# lanes with the SLO autoscaler closed over them.  A lane deadlock (a lane
# waiting on a join that never comes, an ingest-queue lock held across a
# round) hangs exactly here — the hard cap plus faulthandler turns that
# into a loud failure with every lane's stack in the log.
timeout --signal=INT 300 python -X faulthandler -m repro.launch.monitor \
  --seconds 2 --workers 4 --lanes threads --autoscale --random

# Chaos-on-lanes smoke: replay the same seeded fault plan through the
# lane-parallel supervisor — crash/stall/kill recovery and stream
# reassignment must hold when every worker steps on its own thread.
timeout --signal=INT 300 python -X faulthandler -m repro.launch.monitor \
  --seconds 2 --workers 2 --lanes threads --faults "$FAULT_PLAN" --random

# Crash-restart smoke: SIGKILL a durable (--state-dir) fleet mid-run, then
# restart from the same state dir with identical arguments — the driver
# must print the resume line and replay at least one WAL chunk.  The kill
# can (rarely) land in the instant after a checkpoint reset when the WAL
# is empty; that leg is retried, the resume line itself is not.
crash_restart_smoke() {
  local attempt pid log
  for attempt in 1 2 3; do
    rm -rf "$STATE_DIR"
    # Background the BARE python command: $! must be the python pid itself.
    # A compound command here would background a subshell, and the SIGKILL
    # would hit the subshell while the real process kept running.
    python -m repro.launch.monitor --seconds 6 --workers 2 \
      --state-dir "$STATE_DIR" --random >/dev/null 2>&1 &
    pid=$!
    for _ in $(seq 1 100); do  # wait for the first published checkpoint
      compgen -G "$STATE_DIR/fleet/ckpt-*.bin" >/dev/null && break
      sleep 0.1
    done
    sleep 0.3  # let a few more rounds commit, then kill mid-scene
    kill -9 "$pid" 2>/dev/null || true
    wait "$pid" 2>/dev/null || true
    log="$(timeout --signal=INT 300 python -X faulthandler \
      -m repro.launch.monitor --seconds 6 --workers 2 \
      --state-dir "$STATE_DIR" --random)"
    echo "$log" | grep -E "monitor: resumed from state dir at round [1-9]" \
      || { echo "ci: crash-restart smoke: no resume line" >&2; return 1; }
    if echo "$log" | grep -qE "replayed [1-9][0-9]* chunk"; then
      echo "ci: crash-restart smoke OK (attempt $attempt)"
      return 0
    fi
    echo "ci: crash-restart smoke: WAL empty at the kill instant" \
      "(attempt $attempt); retrying"
  done
  echo "ci: crash-restart smoke: no WAL replay in 3 attempts" >&2
  return 1
}
crash_restart_smoke
