#!/usr/bin/env bash
# CI entrypoint: dev deps (best effort — the container may be offline), the
# fast test tier, then a ~30s benchmark + sharded-driver smoke at the
# smallest shapes.
#
#   scripts/ci.sh         fast tier (-m "not slow"): < ~2 min
#   scripts/ci.sh --all   full tier-1 suite incl. @slow kernel-parity /
#                         multi-device / LM-architecture tests (~5-6 min)
#   scripts/ci.sh --cov   fast tier with statement coverage over the
#                         serving package (repro.serving) plus the deploy-
#                         time transform modules (repro.core.pruning,
#                         repro.core.precision_policy), fails under 85%
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev extras are optional: the suite falls back to tests/_hypothesis_fallback.py.
pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "ci: pip install skipped (offline container); using test fallbacks"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=(-m "not slow")
COV=()
case "${1:-}" in
  --all)
    MARK=()  # full tier-1 verify (ROADMAP.md)
    ;;
  --cov)
    if python -c "import pytest_cov" 2>/dev/null; then
      COV=(--cov=repro.serving --cov=repro.serving.batching
           --cov=repro.serving.controller
           --cov=repro.core.pruning
           --cov=repro.core.precision_policy --cov=repro.data.features_jax
           --cov=repro.kernels.tiling
           --cov-report=term-missing --cov-fail-under=85)
    else
      echo "ci: pytest-cov unavailable (offline container); running without coverage" >&2
    fi
    ;;
esac
# ${ARR[@]+...}: empty-array expansion is fatal under `set -u` on bash < 4.4
python -m pytest -x -q ${MARK[@]+"${MARK[@]}"} ${COV[@]+"${COV[@]}"}

# Chaos suite under a hard wall-clock cap: a hung supervisor recovery (a
# revive loop that never converges, a stall that deadlocks a worker) is
# exactly the regression this suite exists to catch, and a hang must fail
# CI loudly, not eat the job timeout.  faulthandler dumps all thread stacks
# when `timeout` sends SIGINT so the hang site lands in the CI log.
timeout --signal=INT 300 python -X faulthandler -m pytest -x -q \
  tests/test_fault_tolerance.py tests/test_lane_fleet.py

# Benchmark smoke + perf gate: smallest shapes under the pinned bench env,
# written to a throwaway JSON, then the speedup *ratios* (fused-vs-im2col,
# jax-vs-numpy — ratios, because absolute µs swing ~±30% in the container)
# are gated against the committed BENCH_kernels.json.  --require makes the
# gate bite on a bench that silently drops a row.
BENCH_FRESH="$(mktemp /tmp/ci_bench_fresh.XXXXXX.json)"
SMOKE=1 BENCH_OUT="$BENCH_FRESH" python -m benchmarks.bench_kernels
python scripts/perf_gate.py --fresh "$BENCH_FRESH" \
  --require 'kernels/conv_layer_fused_*' \
  --require 'kernels/frontend_jax_*'
rm -f "$BENCH_FRESH"
SMOKE=1 python -m benchmarks.bench_serving

# Sharded-driver smoke: the --shards path boots 2 simulated devices and
# must produce windows end-to-end (random weights: plumbing only, fast).
python -m repro.launch.monitor --seconds 2 --shards 2 --random

# Pruned-serving smoke: the deployed configuration (structured prune +
# mixed per-layer precision baked into the artifact) end-to-end through the
# monitor driver (random weights: plumbing only, fast).
python -m repro.launch.monitor --seconds 2 --prune 2 \
  --policy "conv0/w=bf16,dense1/w=fp32" --random

# On-device front-end smoke: raw-window dispatch with the DSP front-end
# fused into the jitted program (random weights: plumbing only, fast).
python -m repro.launch.monitor --seconds 2 --device-features --random

# High-stream adaptive smoke: 256 streams through the shared dispatch core
# on the adaptive slot ladder — proves the fleet-scale admission/fairness
# path boots and drains end-to-end, capped so a ladder-retrace or ready-
# scan regression fails loudly instead of eating the job timeout.
timeout --signal=INT 300 python -m repro.launch.monitor --seconds 2 \
  --streams 256 --adaptive-slots --random

# Fault-injection demo smoke: a seeded plan (crashes, stalls, kills, chunk
# faults) through the fleet supervisor; the driver must survive every
# incident and print the incident log (random weights: plumbing only).
FAULT_PLAN="$(mktemp /tmp/ci_fault_plan.XXXXXX.json)"
trap 'rm -f "$FAULT_PLAN"' EXIT
python -m repro.serving.faults --seed 7 --streams 3 --workers 2 \
  --rounds 12 --out "$FAULT_PLAN"
timeout --signal=INT 300 python -m repro.launch.monitor --seconds 2 \
  --workers 2 --faults "$FAULT_PLAN" --random

# Concurrent-fleet smoke: all four workers' rounds run on named execution
# lanes with the SLO autoscaler closed over them.  A lane deadlock (a lane
# waiting on a join that never comes, an ingest-queue lock held across a
# round) hangs exactly here — the hard cap plus faulthandler turns that
# into a loud failure with every lane's stack in the log.
timeout --signal=INT 300 python -X faulthandler -m repro.launch.monitor \
  --seconds 2 --workers 4 --lanes threads --autoscale --random

# Chaos-on-lanes smoke: replay the same seeded fault plan through the
# lane-parallel supervisor — crash/stall/kill recovery and stream
# reassignment must hold when every worker steps on its own thread.
timeout --signal=INT 300 python -X faulthandler -m repro.launch.monitor \
  --seconds 2 --workers 2 --lanes threads --faults "$FAULT_PLAN" --random
