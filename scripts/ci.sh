#!/usr/bin/env bash
# CI entrypoint: dev deps (best effort — the container may be offline),
# tier-1 tests, then a ~30s kernel-benchmark smoke at the smallest shape.
set -euo pipefail
cd "$(dirname "$0")/.."

# Dev extras are optional: the suite falls back to tests/_hypothesis_fallback.py.
pip install -q -r requirements-dev.txt 2>/dev/null \
  || echo "ci: pip install skipped (offline container); using test fallbacks"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Tier-1 verify (ROADMAP.md)
python -m pytest -x -q

# Benchmark smoke: smallest shapes only, proves the kernel paths still run
# end-to-end (does not touch the committed BENCH_kernels.json).
SMOKE=1 python -m benchmarks.bench_kernels
